"""Energy-proportional elastic serving demo (paper §5.2 / Fig 5+12).

A diurnal request trace (25x peak/trough, like the paper's deployed-server
trace) drives the unified :class:`repro.runtime.ClusterRuntime` loop on
the SoC-Cluster power model and on the TPU-pod mapping: arrivals are
recorded, the activation target is computed, the workload's concurrency
is *actually gated* to it, and energy is integrated per tick. Prints
energy + TpE for gated vs static all-units-on serving, then colocates
two tenants on one cluster through :class:`MultiTenantRuntime` (shared
power charged once, weighted-fair arbitration, runtime-level straggler
hedging).

    PYTHONPATH=src python examples/elastic_serving.py
"""
import numpy as np

from repro.core.cluster import soc_cluster, tpu_v5e_pod
from repro.core.energy import proportionality_index
from repro.core.scheduler import diurnal_trace
from repro.runtime import (ClusterRuntime, DLServingWorkload,
                           MultiTenantRuntime, ScalePolicy, Tenant,
                           TranscodingWorkload)
from repro.workloads.transcoding import VIDEOS


def multi_tenant_demo() -> None:
    """DL serving + live transcoding colocated on the 60-SoC cluster."""
    spec = soc_cluster()
    dl = DLServingWorkload.from_point("resnet-50", "fp32", "soc-gpu")
    video = TranscodingWorkload(VIDEOS[1], hw_codec=True)
    policy = lambda: ScalePolicy(cooldown_s=120.0, min_units=2,  # noqa: E731
                                 hedge_after_s=240.0)
    runtime = MultiTenantRuntime(spec, [
        Tenant("dl", dl, policy=policy(), weight=2.0),
        Tenant("video", video, policy=policy()),
    ], dt_s=60.0)
    n = 24 * 60
    traces = {
        "dl": diurnal_trace(peak_rps=dl.unit_rate * 30, hours=24, seed=1),
        # anti-phase: transcoding peaks 12 h after DL serving
        "video": np.roll(diurnal_trace(peak_rps=video.unit_rate * 30,
                                       hours=24, seed=2), n // 2),
    }
    tel = runtime.play_traces(traces, dt_s=60.0)
    print(f"\n=== {spec.name} multi-tenant (dl + video) ===")
    for name, p in tel.per_tenant.items():
        print(f"{name}: served {p.served:.0f}, "
              f"mean active {p.mean_active:.1f}, "
              f"unit energy {p.energy_j / 3.6e6:.2f} kWh, "
              f"hedged {p.hedged}, p99 {p.p99_latency_s:.1f}s")
    print(f"cluster: energy {tel.energy_j / 3.6e6:.2f} kWh "
          f"(shared {spec.p_shared:.0f} W charged once), "
          f"mean active {tel.mean_active:.1f}/{spec.n_units}")


def main() -> None:
    for spec in (soc_cluster(), tpu_v5e_pod(64)):
        print(f"\n=== {spec.name} ({spec.n_units} units, "
              f"peak {spec.peak_power:.0f} W, "
              f"PI={proportionality_index(spec):.3f}) ===")
        unit_rate = 10.0  # req/s per unit
        trace = diurnal_trace(peak_rps=unit_rate * spec.n_units * 0.8,
                              hours=24, dt_s=60.0)
        workload = DLServingWorkload(unit_rate=unit_rate,
                                     model="resnet-50", platform=spec.name)
        runtime = ClusterRuntime(spec, workload,
                                 policy=ScalePolicy(cooldown_s=120.0))
        tel = runtime.play_trace(trace, dt_s=60.0)
        static_energy = runtime.static_baseline_energy(
            utilization=float(trace.mean()) / (unit_rate * spec.n_units))
        print(f"offered: mean {trace.mean():.0f} rps, "
              f"peak {trace.max():.0f} rps (x"
              f"{trace.max()/max(trace.min(),1e-9):.0f} swing)")
        print(f"elastic: served {tel.served:.0f} reqs, "
              f"energy {tel.energy_j/3.6e6:.2f} kWh, "
              f"TpE {tel.tpe:.2f} req/J, "
              f"mean active {tel.mean_active:.1f}/{spec.n_units}, "
              f"scale events {tel.scale_events}, "
              f"p99 {tel.p99_latency_s:.1f}s")
        print(f"static (all units on): {static_energy/3.6e6:.2f} kWh -> "
              f"elastic saves "
              f"{(1 - tel.energy_j/static_energy):.0%} energy")
    multi_tenant_demo()


if __name__ == "__main__":
    main()
