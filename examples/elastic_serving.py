"""Energy-proportional elastic serving demo (paper §5.2 / Fig 5+12).

A diurnal request trace (25x peak/trough, like the paper's deployed-server
trace) drives the elastic scheduler on the SoC-Cluster power model and on
a monolithic-GPU model; prints energy + TpE for gated vs static serving.

    PYTHONPATH=src python examples/elastic_serving.py
"""
import numpy as np

from repro.core.cluster import a100_server, soc_cluster, tpu_v5e_pod
from repro.core.energy import account_trace, proportionality_index
from repro.core.scheduler import ElasticScheduler, ScalePolicy, diurnal_trace


def main() -> None:
    for spec in (soc_cluster(), tpu_v5e_pod(64)):
        print(f"\n=== {spec.name} ({spec.n_units} units, "
              f"peak {spec.peak_power:.0f} W, "
              f"PI={proportionality_index(spec):.3f}) ===")
        unit_rate = 10.0  # req/s per unit
        trace = diurnal_trace(peak_rps=unit_rate * spec.n_units * 0.8,
                              hours=24, dt_s=60.0)
        sched = ElasticScheduler(spec, unit_rate,
                                 policy=ScalePolicy(cooldown_s=120.0,
                                                    hedge_after_s=1.0))
        res = sched.simulate(trace, dt_s=60.0)
        static_power = spec.power(spec.n_units, trace.mean()
                                  / (unit_rate * spec.n_units))
        static_energy = static_power * len(trace) * 60.0
        print(f"offered: mean {trace.mean():.0f} rps, "
              f"peak {trace.max():.0f} rps (x"
              f"{trace.max()/max(trace.min(),1e-9):.0f} swing)")
        print(f"elastic: served {res.served:.0f} reqs, "
              f"energy {res.energy_j/3.6e6:.2f} kWh, "
              f"TpE {res.tpe:.2f} req/J, "
              f"mean active {res.active_units.mean():.1f}/{spec.n_units}, "
              f"hedged {res.hedged}, p99 {res.p99_latency_s:.2f}s")
        print(f"static (all units on): {static_energy/3.6e6:.2f} kWh -> "
              f"elastic saves "
              f"{(1 - res.energy_j/static_energy):.0%} energy")


if __name__ == "__main__":
    main()
