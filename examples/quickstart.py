"""Quickstart: pick an assigned architecture, build its reduced config,
train a few steps, then serve a few tokens — all on CPU.

    PYTHONPATH=src python examples/quickstart.py --arch internlm2-1.8b
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.config import (ServeConfig, TrainConfig, get_config,
                          list_configs, smoke_config)
from repro.serving.engine import ServingEngine
from repro.training.data import DataConfig, PrefetchingLoader
from repro.training.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=list_configs())
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = smoke_config(full)
    print(f"arch={args.arch} family={cfg.family} "
          f"full-size={full.num_params/1e9:.2f}B "
          f"(smoke: {cfg.num_params/1e6:.1f}M)")

    # --- train a few steps ---
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2,
                       total_steps=args.steps, remat="none")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      frontend_tokens=cfg.frontend_tokens,
                      frontend_dim=cfg.frontend_dim or cfg.d_model)
    hist = Trainer(cfg, tcfg).run(PrefetchingLoader(dcfg), steps=args.steps,
                                  log_every=5)
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"over {args.steps} steps")

    # --- serve ---
    engine = ServingEngine(cfg, ServeConfig(max_seq_len=64))
    engine.load(hist["params"])
    prompt = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
        jnp.int32)
    ve = None
    if cfg.frontend_tokens:
        ve = jnp.zeros((1, cfg.frontend_tokens,
                        cfg.frontend_dim or cfg.d_model), jnp.float32)
    out = engine.generate(prompt, 8, vision_embeds=ve)
    print("generated token ids:", np.asarray(out)[0].tolist())


if __name__ == "__main__":
    main()
