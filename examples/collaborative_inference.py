"""Reproduce the paper's §5.3 / Fig 13 collaborative-inference experiment
and its TPU-native upgrade.

Prints the three-way latency table (baseline TP, paper-pipelined TP, TPU
ring-overlap TP) for 1..5 units, then — if multiple fake devices are
requested via XLA_FLAGS — runs the real shard_map TP block both ways.

    PYTHONPATH=src python examples/collaborative_inference.py
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/collaborative_inference.py --exec
"""
import argparse

from repro.core.collaborative import (PAPER_FIG13, RESNET50_PROFILE,
                                      SOC_TCP, TPU_ICI, latency_breakdown)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--exec", action="store_true",
                    help="also run the shard_map TP block on this host")
    args = ap.parse_args()

    print(f"{'N':>2} {'base ms':>9} {'share':>6} {'pipe ms':>9} "
          f"{'share':>6} {'ring ms':>9} {'share':>7}")
    for n in range(1, 6):
        b = latency_breakdown(RESNET50_PROFILE, n, SOC_TCP)
        p = latency_breakdown(RESNET50_PROFILE, n, SOC_TCP, pipelined=True)
        r = latency_breakdown(RESNET50_PROFILE, n, TPU_ICI,
                              ring_overlap=True)
        print(f"{n:>2} {b['total_ms']:>9.1f} {b['comm_share']:>6.1%} "
              f"{p['total_ms']:>9.1f} {p['comm_share']:>6.1%} "
              f"{r['total_ms']:>9.2f} {r['comm_share']:>7.2%}")
    print(f"paper @N=5: comm share {PAPER_FIG13['comm_share_at_5']:.1%} -> "
          f"{PAPER_FIG13['comm_share_at_5_pipelined']:.1%} pipelined; "
          f"speedup {PAPER_FIG13['total_speedup_at_5']}x")

    if args.exec:
        import time
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core.collaborative import make_tp_block
        from repro.launch.mesh import make_mesh

        n = len(jax.devices())
        mesh = make_mesh((n,), ("model",))
        rng = np.random.default_rng(0)
        m, d, f = 64, 512, 2048
        x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
        w1 = jnp.asarray(rng.standard_normal((d, f)), jnp.float32) * 0.05
        w2 = jnp.asarray(rng.standard_normal((f, d)), jnp.float32) * 0.05
        for overlap in (False, True):
            fn = make_tp_block(mesh, d, f, overlap=overlap)
            out = fn(x, w1, w2)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(20):
                out = fn(x, w1, w2)
            jax.block_until_ready(out)
            dt = (time.perf_counter() - t0) / 20
            print(f"exec n={n} overlap={overlap}: {dt*1e6:.0f} us/call")


if __name__ == "__main__":
    main()
