"""Serve a small model with continuously-batched requests through the
unified :class:`repro.runtime.ClusterRuntime` API, with int8 weight-only
quantization optionally enabled (the paper's DSP-style serving mode).

    PYTHONPATH=src python examples/serve_lm.py --arch granite-moe-1b-a400m
"""
import argparse
import time

import numpy as np

from repro.config import ServeConfig, get_config, smoke_config
from repro.core.cluster import tpu_v5e_pod
from repro.runtime import ClusterRuntime, LMServingWorkload, ScalePolicy
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--int8", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    engine = ServingEngine(
        cfg, ServeConfig(max_seq_len=64, quantize_weights=args.int8))
    engine.init_random(0)
    workload = LMServingWorkload(engine, slots=args.slots,
                                 max_new_tokens=args.max_new_tokens)
    # one engine tick ≙ one decode step; a "unit" sustains ~0.25 req/s at
    # smoke scale, so a burst of submissions activates all slots
    runtime = ClusterRuntime(tpu_v5e_pod(args.slots), workload,
                             policy=ScalePolicy(min_units=1),
                             unit_rate=0.25)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
        runtime.submit(prompt)

    t0 = time.monotonic()
    tel = runtime.run(max_ticks=10000)
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.output) for r in tel.responses)
    print(f"{args.requests} requests x {args.max_new_tokens} tokens on "
          f"{args.slots} slots ({'int8' if args.int8 else 'bf16'} weights)")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s, {tel.ticks} engine ticks, "
          f"mean active units {tel.mean_active:.1f})")
    for r in tel.responses[:3]:
        print(f"  req {r.rid}: {r.output}")


if __name__ == "__main__":
    main()
