"""Serve a small model with continuously-batched requests (slot-based),
with int8 weight-only quantization optionally enabled (the paper's
DSP-style serving mode).

    PYTHONPATH=src python examples/serve_lm.py --arch granite-moe-1b-a400m
"""
import argparse
import time

import numpy as np

from repro.config import ServeConfig, get_config, smoke_config
from repro.serving.batcher import ContinuousBatcher
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--int8", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    engine = ServingEngine(
        cfg, ServeConfig(max_seq_len=64, quantize_weights=args.int8))
    engine.init_random(0)
    bat = ContinuousBatcher(engine, slots=args.slots)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
        bat.submit(prompt, max_new_tokens=args.max_new_tokens)
    reqs = list(bat.queue)

    t0 = time.monotonic()
    ticks = 0
    while bat.queue or any(a is not None for a in bat.active):
        bat.step()
        ticks += 1
        if ticks > 10000:
            break
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.generated) for r in reqs)
    print(f"{args.requests} requests x {args.max_new_tokens} tokens on "
          f"{args.slots} slots ({'int8' if args.int8 else 'bf16'} weights)")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s, {ticks} engine ticks)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.generated}")


if __name__ == "__main__":
    main()
