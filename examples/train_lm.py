"""End-to-end training driver: a ~100M-class LM for a few hundred steps
with checkpointing, resume, straggler-hedged data loading, and a loss
curve written to results/train_lm_history.json.

Default model: mamba2-130m at width 256 (≈19M params — CPU-tractable for
hundreds of steps; pass --full-width for the real 130M config if you have
the patience or a TPU).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import json
import os

from repro.config import TrainConfig, get_config
from repro.training.data import DataConfig, PrefetchingLoader
from repro.training.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--int8-adam", action="store_true")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m")
    if not args.full_width:
        cfg = cfg.replace(d_model=256, num_layers=12, vocab_size=8192)
    print(f"model: {cfg.num_params/1e6:.1f}M params "
          f"({'full' if args.full_width else 'reduced width'})")

    tcfg = TrainConfig(
        learning_rate=3e-3, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps, remat="none", scan_layers=True,
        opt_state_dtype="int8" if args.int8_adam else "fp32")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch)
    loader = PrefetchingLoader(dcfg, fetch_deadline_s=10.0)
    trainer = Trainer(cfg, tcfg, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    hist = trainer.run(loader, steps=args.steps, log_every=10)

    out = {
        "arch": "mamba2-130m(reduced)" if not args.full_width
        else "mamba2-130m",
        "params_m": cfg.num_params / 1e6,
        "steps": hist["step"],
        "loss": hist["loss"],
        "mean_step_s": sum(hist["step_time_s"]) / len(hist["step_time_s"]),
        "hedged_batches": loader.hedge_count,
    }
    os.makedirs("results", exist_ok=True)
    with open("results/train_lm_history.json", "w") as f:
        json.dump(out, f, indent=1)
    print(f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
          f"history -> results/train_lm_history.json")


if __name__ == "__main__":
    main()
