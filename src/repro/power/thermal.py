"""Discrete-time RC thermal network for the 2U SoC-Cluster envelope.

Three nodes per heat path, matching the prototype's physical stack
(§2.2): SoC die → PCB group (5 SoCs share one board and its spreader) →
rack inlet air. Each stage is a first-order RC:

    C_die · dT_die/dt = P_unit − (T_die − T_pcb) / R_die
    C_pcb · dT_pcb/dt = Σ_units (T_die − T_pcb)/R_die − (T_pcb − T_in)/R_pcb

The PCB→air resistance falls as the chassis fans spin up (the fan curve
rides on ``ClusterSpec.p_shared``: fan power is charged to the shared
rail, on top of the calibrated baseline). Each die carries a
**trip-point latch**: crossing ``t_trip_c`` forces the unit down to the
lowest OPP until it cools below ``t_release_c`` (hysteresis, like a
kernel's thermal governor). Frequency governors that want to *avoid*
the latch entirely ask :meth:`ThermalModel.max_sustainable_index` for
the highest OPP whose steady-state die temperature stays below the
release point.

Integration is explicit Euler with automatic sub-stepping (ticks are
1–60 s; the die time constant is ~1–2 min), so the model is stable for
any runtime ``dt_s``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterSpec, UnitSpec
from repro.power.opp import OPPTable, unit_power


@dataclass(frozen=True)
class ThermalParams:
    """Calibrated to the 2U/60-SoC prototype: passively-cooled phone
    silicon on shared PCBs under chassis airflow."""

    t_ambient_c: float = 25.0      # rack inlet air
    # die → PCB stage (per SoC; package + thin spreader)
    r_die_c_per_w: float = 8.0
    c_die_j_per_c: float = 12.0
    # PCB group → inlet air stage (board + spreader mass)
    r_pcb_c_per_w: float = 1.2     # at idle fan speed
    c_pcb_j_per_c: float = 400.0
    # fan curve: speed follows the hottest PCB, linearly between the two
    # setpoints; at full speed the PCB→air resistance shrinks to
    # ``fan_r_scale_min``·R and the fans draw ``fan_p_max_w`` extra on
    # the shared rail
    fan_t_low_c: float = 45.0
    fan_t_high_c: float = 70.0
    fan_r_scale_min: float = 0.55
    fan_p_max_w: float = 30.0
    # trip-point throttling (hysteresis latch per die)
    t_trip_c: float = 95.0
    t_release_c: float = 80.0


class ThermalModel:
    """Per-unit die and per-group PCB temperatures over a cluster."""

    def __init__(self, spec: ClusterSpec,
                 params: Optional[ThermalParams] = None) -> None:
        self.spec = spec
        self.params = params or ThermalParams()
        p = self.params
        assert p.t_release_c < p.t_trip_c, \
            "release point must sit below the trip point (hysteresis)"
        self._groups = spec.groups()
        self._group_of = [gi for gi, g in enumerate(self._groups)
                          for _ in g]
        self.t_die: List[float] = [p.t_ambient_c] * spec.n_units
        self.t_pcb: List[float] = [p.t_ambient_c] * len(self._groups)
        self.throttled: List[bool] = [False] * spec.n_units
        self.fan_frac = 0.0
        # chaos hook: a failed shared fan rail pins airflow at zero
        # (fan_frac = 0.0, so r_pcb_eff collapses to the no-airflow
        # r_pcb_c_per_w exactly); set per tick by the fleet chaos driver
        self.fan_failed = False

    # ------------------------------------------------------------------
    def _fan_frac(self) -> float:
        if self.fan_failed:
            return 0.0
        p = self.params
        hottest = max(self.t_pcb)
        span = max(p.fan_t_high_c - p.fan_t_low_c, 1e-9)
        return min(1.0, max(0.0, (hottest - p.fan_t_low_c) / span))

    def r_pcb_eff(self, fan_frac: Optional[float] = None) -> float:
        p = self.params
        f = self._fan_frac() if fan_frac is None else fan_frac
        return p.r_pcb_c_per_w * (1.0 - (1.0 - p.fan_r_scale_min) * f)

    @property
    def fan_power_w(self) -> float:
        return self.params.fan_p_max_w * self.fan_frac

    def max_die_temp_c(self) -> float:
        return max(self.t_die)

    def n_throttled(self) -> int:
        return sum(self.throttled)

    # ------------------------------------------------------------------
    def step(self, dt_s: float, unit_power_w: Sequence[float]) -> float:
        """Advance the network one tick under the given per-unit power
        draw; updates trip latches and returns the tick's fan power."""
        p = self.params
        assert len(unit_power_w) == self.spec.n_units
        self.fan_frac = self._fan_frac()
        r_pcb = self.r_pcb_eff(self.fan_frac)
        # sub-step at a quarter of the fastest time constant
        tau = min(p.r_die_c_per_w * p.c_die_j_per_c,
                  r_pcb * p.c_pcb_j_per_c)
        n_sub = max(1, int(dt_s / max(0.25 * tau, 1e-6)) + 1)
        h = dt_s / n_sub
        for _ in range(n_sub):
            flows = [0.0] * len(self._groups)
            for u in range(self.spec.n_units):
                f = (self.t_die[u] - self.t_pcb[self._group_of[u]]) \
                    / p.r_die_c_per_w
                flows[self._group_of[u]] += f
                self.t_die[u] += h * (unit_power_w[u] - f) / p.c_die_j_per_c
            for gi in range(len(self._groups)):
                out = (self.t_pcb[gi] - p.t_ambient_c) / r_pcb
                self.t_pcb[gi] += h * (flows[gi] - out) / p.c_pcb_j_per_c
        for u in range(self.spec.n_units):
            if self.throttled[u]:
                if self.t_die[u] <= p.t_release_c:
                    self.throttled[u] = False
            elif self.t_die[u] >= p.t_trip_c:
                self.throttled[u] = True
        return self.fan_power_w

    # ------------------------------------------------------------------
    def steady_die_temp_c(self, p_unit_w: float,
                          units_in_group: Optional[int] = None,
                          fan_frac: float = 1.0) -> float:
        """Steady-state die temperature when every unit in a group draws
        ``p_unit_w`` (worst case: full group) at the given fan speed."""
        n = self.spec.group_size if units_in_group is None \
            else units_in_group
        t_pcb = self.params.t_ambient_c \
            + n * p_unit_w * self.r_pcb_eff(fan_frac)
        return t_pcb + p_unit_w * self.params.r_die_c_per_w

    def max_sustainable_index(self, unit: UnitSpec, table: OPPTable,
                              util: float = 1.0) -> int:
        """Highest OPP a fully-loaded, fully-occupied group can hold
        forever without tripping (steady-state die temp at full fan stays
        below the *release* point, so the latch never ping-pongs). The
        lowest OPP is returned even when nothing is sustainable."""
        for idx in range(table.highest, table.lowest, -1):
            p_w = unit_power(unit, util, table[idx])
            if self.steady_die_temp_c(p_w) <= self.params.t_release_c:
                return idx
        return table.lowest


class VectorThermalModel(ThermalModel):
    """Array-backed thermal network — bitwise-identical to the scalar
    :class:`ThermalModel`.

    The per-unit Euler update is elementwise (IEEE float64 ops are
    identical whether issued one unit at a time or over a whole array)
    and the per-group heat flows are accumulated by ``np.bincount``,
    which adds weights in input order — the same ascending-unit order
    the scalar loop uses — so every temperature, latch, and fan value
    matches the scalar model bit for bit. Used by
    :class:`~repro.runtime.pool.VectorUnitPool` (``backend="vector"``).
    """

    def __init__(self, spec: ClusterSpec,
                 params: Optional[ThermalParams] = None) -> None:
        super().__init__(spec, params)
        self.t_die = np.asarray(self.t_die, float)
        self.t_pcb = np.asarray(self.t_pcb, float)
        self.throttled = np.zeros(spec.n_units, bool)
        self._group_idx = np.asarray(self._group_of, np.int64)
        self._scr_f: Optional[np.ndarray] = None
        self._scr_g: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    def _fan_frac(self) -> float:
        if self.fan_failed:
            return 0.0
        p = self.params
        hottest = float(self.t_pcb.max())
        span = max(p.fan_t_high_c - p.fan_t_low_c, 1e-9)
        return min(1.0, max(0.0, (hottest - p.fan_t_low_c) / span))

    def max_die_temp_c(self) -> float:
        return float(self.t_die.max())

    def n_throttled(self) -> int:
        return int(np.count_nonzero(self.throttled))

    # ------------------------------------------------------------------
    def step(self, dt_s: float, unit_power_w: Sequence[float]) -> float:
        p = self.params
        pw = np.asarray(unit_power_w, float)
        assert pw.shape == (self.spec.n_units,)
        self.fan_frac = self._fan_frac()
        r_pcb = self.r_pcb_eff(self.fan_frac)
        tau = min(p.r_die_c_per_w * p.c_die_j_per_c,
                  r_pcb * p.c_pcb_j_per_c)
        n_sub = max(1, int(dt_s / max(0.25 * tau, 1e-6)) + 1)
        h = dt_s / n_sub
        n_groups = len(self._groups)
        # scratch buffers (ufunc out= — same float ops, no allocations)
        f = self._scr_f
        if f is None:
            f = self._scr_f = np.empty(self.spec.n_units, float)
            self._scr_g = np.empty(n_groups, float)
        out = self._scr_g
        for _ in range(n_sub):
            np.subtract(self.t_die, self.t_pcb[self._group_idx], out=f)
            f /= p.r_die_c_per_w
            # weighted bincount adds in input order — the only numpy
            # group-sum whose accumulation is bitwise-identical to the
            # scalar loop (reduceat / reshape-sum reductions are not
            # strictly left-to-right)
            flows = np.bincount(self._group_idx, weights=f,
                                minlength=n_groups)
            np.subtract(pw, f, out=f)
            f *= h
            f /= p.c_die_j_per_c
            self.t_die += f
            np.subtract(self.t_pcb, p.t_ambient_c, out=out)
            out /= r_pcb
            np.subtract(flows, out, out=flows)
            flows *= h
            flows /= p.c_pcb_j_per_c
            self.t_pcb += flows
        # hysteresis latch: a throttled die stays latched until it cools
        # below the release point; an unlatched one trips at t_trip_c
        self.throttled = np.where(self.throttled,
                                  ~(self.t_die <= p.t_release_c),
                                  self.t_die >= p.t_trip_c)
        return self.fan_power_w
