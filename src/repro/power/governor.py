"""Pluggable frequency-scaling governors.

A frequency governor answers one question each tick, per tenant: *which
operating point should this tenant's units run at?* It composes with
the existing activation policy (:class:`~repro.runtime.policy.
UnitGovernor`): the activation side then sizes the unit count against
the chosen OPP's effective service rate, so the pair co-optimizes
"how many units × how fast each runs".

Governors mirror the Linux cpufreq vocabulary:

  * :class:`FixedFreqGovernor` — pin one OPP (``performance`` when
    pinned to the top of the table, ``powersave`` at the bottom);
  * :class:`RaceToIdleGovernor` — top OPP whenever there is work,
    nominal otherwise (finish fast, gate off sooner);
  * :class:`SchedutilGovernor` — the lowest-energy (OPP, unit-count)
    pair that still meets demand × headroom, found by exhaustive search
    over the (small) OPP table — this is where wide-and-slow beats
    narrow-and-fast when V² savings outweigh extra idle floors;
  * :class:`ThermalAwareGovernor` — wraps any of the above and clamps
    its choice to the thermally sustainable ceiling, trading peak speed
    for never tripping the throttle latch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Protocol, runtime_checkable

from repro.core.cluster import UnitSpec
from repro.power.opp import OPPTable


@dataclass
class FreqContext:
    """Everything a governor may consult for one tick's decision."""

    demand_rate: float               # windowed offered rate (req/s)
    unit_rate: float                 # nominal per-unit rate (req/s @ OPP_nom)
    headroom: float                  # ScalePolicy.headroom
    n_units: int                     # pool size available to the tenant
    table: OPPTable
    unit: UnitSpec
    min_units: int = 1
    max_sustainable: Optional[int] = None   # thermal ceiling (OPP index)
    backlog: bool = False            # tenant had queued work last tick
    p_gated_w: float = 0.0           # per-unit draw of a *non-active*
    #   unit (p_off when idle units are gated, p_idle otherwise) — part
    #   of schedutil's objective so wide-and-slow pays for the narrower
    #   option's cheaper floor


@runtime_checkable
class FreqGovernor(Protocol):
    """Structural protocol: one OPP index per tick."""

    def select(self, ctx: FreqContext) -> int:
        ...


class FixedFreqGovernor:
    """Pin every unit to one OPP (``None`` = the top of the table — the
    cpufreq ``performance`` governor)."""

    def __init__(self, index: Optional[int] = None) -> None:
        self.index = index

    def select(self, ctx: FreqContext) -> int:
        return ctx.table.highest if self.index is None \
            else ctx.table.clamp(self.index)


class RaceToIdleGovernor:
    """Sprint at the top OPP while there is demand or backlog, drop to
    nominal when idle: finishing sooner lets the activation side gate
    units off sooner."""

    def select(self, ctx: FreqContext) -> int:
        if ctx.demand_rate > 0.0 or ctx.backlog:
            return ctx.table.highest
        return ctx.table.nominal


class SchedutilGovernor:
    """Lowest-OPP-meeting-demand-with-headroom, jointly with the unit
    count: for each OPP, size the activation (ceil of demand × headroom
    over the OPP's effective rate), predict the tenant's unit power, and
    take the cheapest feasible pair. Ties break toward the lower OPP
    (less thermal pressure for the same energy)."""

    def __init__(self, headroom: Optional[float] = None) -> None:
        # None: inherit the activation policy's headroom from the context
        self.headroom = headroom
        # per-(table, unit) constants, memoized by identity — the runtime
        # hands the same table/unit objects every tick, and this method
        # is on the per-tick hot path of every DVFS simulation
        self._tbl = self._unit = None
        self._ps: "list[float]" = []
        self._spk: "list[float]" = []

    def select(self, ctx: FreqContext) -> int:
        need = ctx.demand_rate * (self.headroom if self.headroom is not None
                                  else ctx.headroom)
        if need <= 0.0:
            return ctx.table.lowest
        if self._tbl is not ctx.table or self._unit is not ctx.unit:
            span = ctx.unit.p_peak - ctx.unit.p_idle
            self._ps = [p.perf_scale for p in ctx.table.points]
            self._spk = [span * p.power_scale for p in ctx.table.points]
            self._tbl, self._unit = ctx.table, ctx.unit
        p_idle, gamma = ctx.unit.p_idle, ctx.unit.gamma
        best_idx, best_power = ctx.table.highest, math.inf
        for idx in range(len(self._ps)):
            eff_rate = ctx.unit_rate * self._ps[idx]
            n = max(ctx.min_units, math.ceil(need / eff_rate))
            if n > ctx.n_units:
                continue                      # can't meet demand this slow
            util = min(1.0, ctx.demand_rate / (n * eff_rate))
            # inlined unit_power(ctx.unit, util, table[idx]) — identical
            # association, with span * power_scale folded into _spk
            power = n * (p_idle + self._spk[idx] * util ** gamma) \
                + (ctx.n_units - n) * ctx.p_gated_w
            if power < best_power - 1e-12:
                best_idx, best_power = idx, power
        return best_idx


class ThermalAwareGovernor:
    """Clamp an inner governor's choice to the sustainable ceiling the
    thermal model reports, so units never hit the trip latch (flat
    sustained throughput instead of throttle-induced sag)."""

    def __init__(self, inner: Optional[FreqGovernor] = None) -> None:
        self.inner = inner or FixedFreqGovernor()

    def select(self, ctx: FreqContext) -> int:
        choice = self.inner.select(ctx)
        if ctx.max_sustainable is None:
            return choice
        return min(choice, ctx.max_sustainable)


GOVERNORS = {
    "fixed": FixedFreqGovernor,
    "race-to-idle": RaceToIdleGovernor,
    "schedutil": SchedutilGovernor,
    "thermal-aware": ThermalAwareGovernor,
}
