"""Per-unit operating-point (OPP) tables — the frequency axis of the
power model.

The paper's energy proportionality argument (§5.2) is about *how many*
units run; real mobile SoCs add a second axis — *how fast* each runs.
A Snapdragon 865 exposes per-cluster DVFS operating points: each point
pairs a clock frequency with the minimum supply voltage that sustains
it, and dynamic power follows P ≈ P_idle + k·f·V². Because V itself
rises with f, the top of the table costs super-linearly more energy per
unit of work than the middle — which is what makes the wide-and-slow
(more units, low OPP) vs narrow-and-fast (fewer units, high OPP) Pareto
non-trivial.

Everything here is expressed *relative to the nominal point* so it
composes with the calibrated :class:`~repro.core.cluster.UnitSpec`
wattages unchanged:

  * ``perf_scale``  = f / f_nom — service-rate multiplier;
  * ``power_scale`` = (f · V²) / (f_nom · V_nom²) — dynamic-power
    multiplier.

At the nominal OPP both scales are exactly 1.0 and
:func:`unit_power` reduces to ``UnitSpec.power`` — the power layer is
strictly additive by default.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence, Tuple

from repro.core.cluster import UnitSpec


@dataclass(frozen=True)
class OperatingPoint:
    """One DVFS point: frequency + normalized voltage + derived scales."""

    freq_mhz: float
    volt: float          # supply voltage normalized to the nominal point
    perf_scale: float    # service-rate multiplier vs nominal (≈ f/f_nom)
    power_scale: float   # dynamic-power multiplier vs nominal (f·V²)


@dataclass(frozen=True)
class OPPTable:
    """An ascending-frequency tuple of operating points.

    ``nominal`` indexes the point the :class:`UnitSpec` wattages were
    calibrated at (``perf_scale == power_scale == 1.0``); governors and
    throttling move units up and down this table.
    """

    points: Tuple[OperatingPoint, ...]
    nominal: int

    def __post_init__(self) -> None:
        assert self.points, "OPP table needs at least one point"
        freqs = [p.freq_mhz for p in self.points]
        assert freqs == sorted(freqs), "OPP table must ascend in frequency"
        assert 0 <= self.nominal < len(self.points)
        nom = self.points[self.nominal]
        assert abs(nom.perf_scale - 1.0) < 1e-9 \
            and abs(nom.power_scale - 1.0) < 1e-9, \
            "the nominal OPP must carry unit perf/power scales"

    def __len__(self) -> int:
        return len(self.points)

    def __getitem__(self, i: int) -> OperatingPoint:
        return self.points[i]

    def __iter__(self) -> Iterator[OperatingPoint]:
        return iter(self.points)

    @property
    def lowest(self) -> int:
        return 0

    @property
    def highest(self) -> int:
        return len(self.points) - 1

    def clamp(self, idx: int) -> int:
        return max(0, min(len(self.points) - 1, int(idx)))


def unit_power(unit: UnitSpec, util: float, opp: OperatingPoint) -> float:
    """Unit power at ``util`` on ``opp``: the calibrated idle floor plus
    the dynamic swing scaled by the OPP's f·V² factor (P ≈ P_idle +
    k·f·V²). At the nominal OPP this is exactly ``unit.power(util)``."""
    u = min(max(util, 0.0), 1.0)
    return unit.p_idle \
        + (unit.p_peak - unit.p_idle) * opp.power_scale * (u ** unit.gamma)


# ---------------------------------------------------------------------------
# Builders.
# ---------------------------------------------------------------------------
def build_table(freqs_mhz: Sequence[float], volts: Sequence[float],
                nominal: Optional[int] = None) -> OPPTable:
    """Build a table from raw (frequency, voltage) pairs; scales are
    normalized to the ``nominal`` point (default: the highest)."""
    assert len(freqs_mhz) == len(volts) and freqs_mhz, \
        "need matching, non-empty freq/volt lists"
    n = len(freqs_mhz) - 1 if nominal is None else nominal
    f_nom, v_nom = float(freqs_mhz[n]), float(volts[n])
    pts = tuple(
        OperatingPoint(
            freq_mhz=float(f), volt=float(v) / v_nom,
            perf_scale=float(f) / f_nom,
            power_scale=(float(f) / f_nom) * (float(v) / v_nom) ** 2)
        for f, v in zip(freqs_mhz, volts))
    return OPPTable(points=pts, nominal=n)


def single_opp_table(freq_mhz: float = 2841.6) -> OPPTable:
    """The degenerate no-DVFS table: one nominal point. A pool configured
    with this behaves bit-for-bit like one with no power layer at all."""
    return OPPTable(points=(OperatingPoint(freq_mhz, 1.0, 1.0, 1.0),),
                    nominal=0)


# Snapdragon 865 prime-cluster (Kryo 585 Gold Prime) operating points.
# Frequencies are the kernel's freq-table steps; voltages follow the
# near-linear V(f) ramp of the 7 nm bin, normalized to the 2841.6 MHz
# point the paper's 8 W full-load calibration was measured at.
SD865_FREQS_MHZ = (844.8, 1420.8, 1804.8, 2227.2, 2841.6)
SD865_VOLTS = (0.65, 0.737, 0.80, 0.88, 1.0)


def sd865_opp_table() -> OPPTable:
    """The calibrated SD865 table (nominal = 2841.6 MHz, the point
    behind ``soc_cluster()``'s 8 W per-SoC peak)."""
    return build_table(SD865_FREQS_MHZ, SD865_VOLTS)


def opp_table_for_unit(unit: UnitSpec, n_points: int = 5,
                       f_min_frac: float = 0.4, v_min: float = 0.6,
                       f_nom_mhz: float = 1000.0) -> OPPTable:
    """Generic table builder for any :class:`UnitSpec` (a GPU's clock
    ladder, a TPU chip's SKU steps): ``n_points`` evenly-spaced
    frequencies from ``f_min_frac``·f_nom to f_nom, voltage ramping
    linearly from ``v_min`` to 1.0. The top point is nominal, so the
    unit's calibrated wattages are reproduced exactly there."""
    assert n_points >= 1 and 0.0 < f_min_frac <= 1.0 and 0.0 < v_min <= 1.0
    assert unit.p_peak > unit.p_idle, \
        f"{unit.name}: no dynamic power range to scale"
    if n_points == 1:
        return single_opp_table(f_nom_mhz)
    fracs = [f_min_frac + (1.0 - f_min_frac) * i / (n_points - 1)
             for i in range(n_points)]
    freqs = [f * f_nom_mhz for f in fracs]
    volts = [v_min + (1.0 - v_min) * (f - fracs[0]) / (1.0 - fracs[0])
             for f in fracs]
    return build_table(freqs, volts)
