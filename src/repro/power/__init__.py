"""``repro.power`` — thermal-aware DVFS: the frequency axis of the
cluster power model.

Three pieces, composing with the runtime stack:

  * :mod:`repro.power.opp` — per-unit operating-point tables
    (frequency → perf-scale, power via P ≈ P_idle + k·f·V²); a
    calibrated SD865 table plus a generic builder for any
    :class:`~repro.core.cluster.UnitSpec`;
  * :mod:`repro.power.thermal` — a discrete-time RC thermal network
    (SoC die → PCB group → rack inlet, fan curve on the shared rail)
    with trip-point throttling that forces hot units down the table;
  * :mod:`repro.power.governor` — pluggable frequency policies
    (``fixed``, ``race-to-idle``, ``schedutil``, ``thermal-aware``)
    that compose with the activation-count policy in
    :class:`~repro.runtime.policy.UnitGovernor`.

Attach a table (and optionally thermal params) to a runtime and pick a
governor per tenant::

    from repro.power import (sd865_opp_table, ThermalParams,
                             SchedutilGovernor)
    from repro.runtime import ClusterRuntime, ScalePolicy

    rt = ClusterRuntime(soc_cluster(), workload,
                        policy=ScalePolicy(freq_governor=SchedutilGovernor()),
                        opp_table=sd865_opp_table(),
                        thermal=ThermalParams())

With no table configured (the default) nothing changes: the power layer
is strictly additive.
"""
from repro.power.governor import (GOVERNORS, FixedFreqGovernor, FreqContext,
                                  FreqGovernor, RaceToIdleGovernor,
                                  SchedutilGovernor, ThermalAwareGovernor)
from repro.power.opp import (OperatingPoint, OPPTable, build_table,
                             opp_table_for_unit, sd865_opp_table,
                             single_opp_table, unit_power)
from repro.power.thermal import (ThermalModel, ThermalParams,
                                 VectorThermalModel)

__all__ = [
    "OperatingPoint", "OPPTable", "build_table", "opp_table_for_unit",
    "sd865_opp_table", "single_opp_table", "unit_power",
    "ThermalModel", "ThermalParams", "VectorThermalModel",
    "FreqContext", "FreqGovernor", "FixedFreqGovernor",
    "RaceToIdleGovernor", "SchedutilGovernor", "ThermalAwareGovernor",
    "GOVERNORS",
]
