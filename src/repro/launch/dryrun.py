import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import json
import time
import traceback
from typing import Any, ClassVar, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (ALL_SHAPES, SHAPES, ModelConfig, ServeConfig,
                          ShapeSpec, TrainConfig, get_config,
                          shape_applicable)
from repro.configs import ASSIGNED_ARCHS
from repro.distributed.sharding import serve_rules, train_rules, use_sharding
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import model as lm
from repro.models.transformer import block_period
from repro.roofline.analysis import (model_flops, parse_collectives,
                                     roofline_from_artifacts)
from repro.training.train_loop import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# ---------------------------------------------------------------------------
# FLOPs/bytes accounting note: XLA's cost_analysis counts a while-loop body
# ONCE, so a scan-over-blocks lowering under-reports flops/bytes/collectives
# by ~the trip count. The dry-run therefore does three lowerings per cell:
#   (a) the production scan build      -> memory_analysis ("fits" proof),
#                                         compile-succeeds proof, HLO;
#   (b) a depth-p unrolled probe       -> cost1/collectives1;
#   (c) a depth-2p unrolled probe      -> cost2/collectives2;
# and extrapolates  X_total = X1 + (nb - 1) * (X2 - X1)  where p is the
# hybrid block period and nb = num_layers / p. The probes run at full width,
# batch and sequence — only depth is reduced — so the per-body delta is the
# true per-block cost including remat and resharding.
# ---------------------------------------------------------------------------


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _memory_analysis_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_nonalias_bytes"] = (
            out.get("argument_size_in_bytes", 0.0)
            + out.get("output_size_in_bytes", 0.0)
            + out.get("temp_size_in_bytes", 0.0)
            - out.get("alias_size_in_bytes", 0.0))
    return out


def _probe_cfg(cfg: ModelConfig, depth: int) -> ModelConfig:
    pattern = None
    if cfg.layer_pattern is not None:
        pattern = tuple(cfg.layer_kinds()[:depth])
    return cfg.replace(num_layers=depth, layer_pattern=pattern)


def _lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                opts: Dict[str, Any], scan: bool):
    """Build + lower one step. Returns (lowered, step_kind, tokens)."""
    if shape.kind == "train":
        tcfg = S.default_train_config(cfg)
        over = {k: opts[k] for k in
                ("remat", "opt_state_dtype", "microbatches",
                 "grad_compression", "loss_chunk") if k in opts}
        tcfg = TrainConfig(**{**tcfg.__dict__, **over,
                              "scan_layers": scan})
        rules = train_rules()
        if "rules_override" in opts:
            rules = rules.override(**opts["rules_override"])
        step = make_train_step(cfg, tcfg)

        def fn(params, opt_state, batch):
            with use_sharding(mesh, rules):
                return step(params, opt_state, batch)

        params_sds = lm.param_shapes(cfg)
        params_sh = S.params_shardings(cfg, mesh, rules)
        opt_sh, opt_sds = S.opt_shardings(cfg, tcfg, mesh, rules)
        batch_sds = S.train_batch_specs(cfg, shape)
        batch_sh = S.batch_shardings(batch_sds, mesh, rules)
        jfn = jax.jit(fn, in_shardings=(params_sh, opt_sh, batch_sh),
                      donate_argnums=(0, 1))
        lowered = jfn.lower(params_sds, opt_sds, batch_sds)
        return lowered, "train", shape.global_batch * shape.seq_len

    scfg = S.default_serve_config(cfg, shape)
    if "serve_fsdp" in opts:
        scfg = ServeConfig(**{**scfg.__dict__,
                              "serve_fsdp": opts["serve_fsdp"]})
    rules = serve_rules(scfg.serve_fsdp, batch1=shape.global_batch == 1)
    if "rules_override" in opts:
        rules = rules.override(**opts["rules_override"])
    params_sds = lm.param_shapes(cfg)
    params_sh = S.params_shardings(cfg, mesh, rules)

    if shape.kind == "prefill":
        def fn(params, batch):
            with use_sharding(mesh, rules):
                return lm.prefill(params, cfg, batch, scan=scan,
                                  max_len=shape.seq_len)

        batch_sds = S.prefill_batch_specs(cfg, shape)
        batch_sh = S.batch_shardings(batch_sds, mesh, rules)
        jfn = jax.jit(fn, in_shardings=(params_sh, batch_sh))
        lowered = jfn.lower(params_sds, batch_sds)
        return lowered, "prefill", shape.global_batch * shape.seq_len

    # decode
    cache_dtype = jnp.dtype(opts.get("kv_cache_dtype", cfg.dtype))

    def fn(params, tokens, caches, pos):
        with use_sharding(mesh, rules):
            return lm.decode_step(params, cfg, tokens, caches, pos,
                                  scan=scan)

    tok_sds, caches_sds, pos_sds = S.decode_input_specs(
        cfg, shape, cache_dtype)
    tok_sh = jax.sharding.NamedSharding(
        mesh, S.resolve_spec(tok_sds.shape, ("batch", None), rules, mesh))
    caches_sh = S.cache_shardings(cfg, caches_sds, mesh, rules)
    pos_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    jfn = jax.jit(fn, in_shardings=(params_sh, tok_sh, caches_sh, pos_sh),
                  donate_argnums=(2,))
    lowered = jfn.lower(params_sds, tok_sds, caches_sds, pos_sds)
    return lowered, "decode", shape.global_batch


def _cost_and_collectives(compiled) -> Tuple[Dict[str, float], Any]:
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return ({k: float(v) for k, v in cost.items()
             if isinstance(v, (int, float))}, coll)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             opts: Optional[Dict[str, Any]] = None,
             probes: bool = True, verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; returns roofline and
    memory artifacts. ``opts`` carries hillclimb overrides."""
    opts = dict(opts or {})
    cfg = get_config(arch)
    if opts.get("model_overrides"):
        cfg = cfg.replace(**opts.pop("model_overrides"))
    if opts.get("moe_dispatch") and cfg.moe is not None:
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe,
                                          dispatch=opts["moe_dispatch"]))
    shape = SHAPES[shape_name]
    sanctioned, skip_note = shape_applicable(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    # (a) production scan build.
    t0 = time.monotonic()
    lowered, step_kind, tokens = _lower_cell(cfg, shape, mesh, opts=opts,
                                             scan=True)
    t_lower = time.monotonic() - t0
    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0
    mem = _memory_analysis_dict(compiled)
    cost_scan, coll_scan = _cost_and_collectives(compiled)

    # (b)+(c) depth probes for loop-corrected counts. The microbatch
    # accumulation loop is *also* a lax.scan, so probes run at
    # microbatches=1 with global_batch/mb and results scale by mb
    # (the optimizer tail is O(N) — negligible next to O(N*D)).
    p = block_period(cfg)
    nb = cfg.num_layers // p
    probe_info: Dict[str, Any] = {"period": p, "blocks": nb}
    if probes and nb > 1:
        mb = 1
        probe_shape = shape
        probe_opts = dict(opts)
        if step_kind == "train":
            tc = S.default_train_config(cfg)
            mb = int(opts.get("microbatches", tc.microbatches))
            if mb > 1:
                probe_shape = ShapeSpec(shape.name,
                                        shape.seq_len,
                                        shape.global_batch // mb,
                                        shape.kind)
                probe_opts["microbatches"] = 1
        probe_info["mb_multiplier"] = mb
        cfg1, cfg2 = _probe_cfg(cfg, p), _probe_cfg(cfg, 2 * p)
        l1, _, _ = _lower_cell(cfg1, probe_shape, mesh, opts=probe_opts,
                               scan=False)
        c1 = l1.compile()
        cost1, coll1 = _cost_and_collectives(c1)
        l2, _, _ = _lower_cell(cfg2, probe_shape, mesh, opts=probe_opts,
                               scan=False)
        c2 = l2.compile()
        cost2, coll2 = _cost_and_collectives(c2)

        def extrap(x1: float, x2: float) -> float:
            # Per-block delta clamped at >= 0: tiny decode graphs can
            # compile to *cheaper* 2p-depth modules (fusion luck), and a
            # negative body would extrapolate below zero.
            return mb * (x1 + (nb - 1) * max(x2 - x1, 0.0))

        flops = extrap(cost1.get("flops", 0.0), cost2.get("flops", 0.0))
        bytes_acc = extrap(cost1.get("bytes accessed", 0.0),
                           cost2.get("bytes accessed", 0.0))
        coll_wire = {}
        kinds = set(coll1.wire_bytes) | set(coll2.wire_bytes)
        for k in kinds:
            coll_wire[k] = extrap(coll1.wire_bytes.get(k, 0.0),
                                  coll2.wire_bytes.get(k, 0.0))
        probe_info.update({
            "probe1_flops": cost1.get("flops", 0.0),
            "probe2_flops": cost2.get("flops", 0.0),
            "scan_reported_flops": cost_scan.get("flops", 0.0),
        })
        cost = {"flops": flops, "bytes accessed": bytes_acc}

        class _C:  # minimal CollectiveStats-alike
            wire_bytes = coll_wire
            counts: ClassVar[Dict[str, int]] = {
                k: coll_scan.counts.get(k, 0) for k in kinds}
            result_bytes: ClassVar[Dict[str, int]] = {}

            @property
            def total_wire_bytes(self):
                return sum(coll_wire.values())

        coll = _C()
    else:
        cost, coll = cost_scan, coll_scan

    mf = model_flops(cfg.num_active_params, tokens, step_kind)
    terms = roofline_from_artifacts(
        arch=arch, shape=shape_name, mesh_name=_mesh_name(multi_pod),
        step_kind=step_kind, chips=chips, cost=cost, collectives=coll,
        model_flops_total=mf, memory_analysis=mem,
        note=("" if sanctioned else f"bonus cell ({skip_note})"))

    result = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod),
        "chips": chips, "step_kind": step_kind,
        "sanctioned": sanctioned, "skip_note": skip_note,
        "opts": {k: v for k, v in opts.items() if k != "rules_override"},
        "lower_s": t_lower, "compile_s": t_compile,
        "probe": probe_info,
        "cost_analysis": {k: float(v) for k, v in cost.items()},
        "memory_analysis": mem,
        "collectives": {
            "counts": dict(coll.counts),
            "wire_bytes": dict(coll.wire_bytes),
            "total_wire_bytes": float(coll.total_wire_bytes),
        },
        "roofline": json.loads(terms.to_json()),
    }
    if verbose:
        r = result["roofline"]
        print(f"[{arch} x {shape_name} x {_mesh_name(multi_pod)}] "
              f"compile {t_compile:.1f}s | "
              f"compute {r['compute_s']*1e3:.2f}ms "
              f"memory {r['memory_s']*1e3:.2f}ms "
              f"collective {r['collective_s']*1e3:.2f}ms "
              f"-> {r['bound']}-bound, roofline frac "
              f"{r['roofline_fraction']:.3f} | "
              f"mem/device {mem.get('total_nonalias_bytes', 0)/2**30:.2f} GiB",
              flush=True)
    return result


def save_result(result: Dict[str, Any], tag: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = (f"{result['arch']}__{result['shape']}__{result['mesh']}"
             f"{suffix}.json").replace("/", "_")
    path = os.path.join(RESULTS_DIR, fname)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def result_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape}__{_mesh_name(multi_pod)}"
                        f"{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell on this mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--include-bonus", action="store_true",
                    help="also compile spec-skippable long_500k cells")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the depth-probe lowerings (memory/compile "
                         "proof only; flops will be scan-underreported)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opts", default="{}",
                    help="JSON dict of hillclimb overrides")
    args = ap.parse_args()
    opts = json.loads(args.opts)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in ALL_SHAPES:
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for multi_pod in meshes:
        for arch, shape_name in cells:
            cfg = get_config(arch)
            ok, note = shape_applicable(cfg, SHAPES[shape_name])
            if args.all and not ok and not args.include_bonus:
                print(f"[{arch} x {shape_name}] SKIP (sanctioned): {note}",
                      flush=True)
                continue
            if args.skip_existing and os.path.exists(
                    result_path(arch, shape_name, multi_pod, args.tag)):
                print(f"[{arch} x {shape_name} x {_mesh_name(multi_pod)}] "
                      f"cached", flush=True)
                continue
            try:
                res = run_cell(arch, shape_name, multi_pod=multi_pod,
                               opts=dict(opts), probes=not args.no_probes)
                save_result(res, tag=args.tag)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape_name, multi_pod, repr(e)))
                print(f"[{arch} x {shape_name} x "
                      f"{_mesh_name(multi_pod)}] FAILED: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         f"{[(f[0], f[1], f[2]) for f in failures]}")
    print("dry-run complete: all cells compiled.")


if __name__ == "__main__":
    main()
