"""ShapeDtypeStruct input stand-ins + sharding resolution for every
(arch x shape x step-kind) cell. No device allocation happens here."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import (ModelConfig, ServeConfig, ShapeSpec, TrainConfig)
from repro.distributed.sharding import RuleSet, resolve_spec
from repro.models import model as lm
from repro.training.optimizer import init_opt_state, opt_state_specs

SDS = jax.ShapeDtypeStruct
Params = Any

BATCH_LOGICAL = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "mask": ("batch", "seq"),
    "vision_embeds": ("batch", "seq", "embed_act"),
}


def default_train_config(cfg: ModelConfig) -> TrainConfig:
    """Per-arch training policy: bigger models get full remat, gradient
    accumulation, and int8 Adam moments (the state-compression trick that
    lets the 398B/778B configs approach 16 GB/chip HBM)."""
    n = cfg.num_params
    big = n > 30e9
    if n > 100e9:
        mb = 16
    elif n > 3e9:
        mb = 8
    else:
        mb = 1
    return TrainConfig(
        # 4k-seq training materializes O(s^2) attention scores on the
        # reference path — remat pays for itself from ~0.1B up.
        remat="full" if n > 0.1e9 else "none",
        scan_layers=True,
        opt_state_dtype="int8" if big else "fp32",
        microbatches=mb,
    )


def default_serve_config(cfg: ModelConfig, shape: ShapeSpec) -> ServeConfig:
    return ServeConfig(
        max_batch=shape.global_batch,
        serve_fsdp=cfg.num_params > 30e9,
        max_seq_len=shape.seq_len,
    )


# ---------------------------------------------------------------------------
# Batch SDS.
# ---------------------------------------------------------------------------
def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    ft = cfg.frontend_tokens
    b, s = shape.global_batch, shape.seq_len - ft
    batch = {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
        "mask": SDS((b, s), jnp.float32),
    }
    if ft:
        batch["vision_embeds"] = SDS((b, ft, cfg.frontend_dim or cfg.d_model),
                                     jnp.float32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    ft = cfg.frontend_tokens
    b, s = shape.global_batch, shape.seq_len - ft
    batch = {"tokens": SDS((b, s), jnp.int32)}
    if ft:
        batch["vision_embeds"] = SDS((b, ft, cfg.frontend_dim or cfg.d_model),
                                     jnp.float32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec,
                       cache_dtype=None) -> Tuple[SDS, Any, SDS]:
    """(tokens, caches, pos) stand-ins for one serve_step: a single new
    token against a seq_len-deep cache."""
    b = shape.global_batch
    dtype = cache_dtype or jnp.dtype(cfg.dtype)
    caches = jax.eval_shape(
        lambda: lm.init_caches(cfg, b, shape.seq_len, dtype))
    tokens = SDS((b, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return tokens, caches, pos


# ---------------------------------------------------------------------------
# Sharding resolution over SDS trees.
# ---------------------------------------------------------------------------
def _resolve_tree(sds_tree, logical_tree, mesh, rules: RuleSet):
    def f(sds, logical):
        spec = resolve_spec(sds.shape, tuple(logical), rules, mesh)
        return jax.sharding.NamedSharding(mesh, spec)
    return jax.tree.map(
        f, sds_tree, logical_tree,
        is_leaf=lambda t: isinstance(t, SDS) or (
            isinstance(t, tuple) and not isinstance(t, SDS)))


def params_shardings(cfg: ModelConfig, mesh, rules: RuleSet):
    shapes = lm.param_shapes(cfg)
    specs = lm.param_specs(cfg)
    return jax.tree.map(
        lambda sds, sp: jax.sharding.NamedSharding(
            mesh, resolve_spec(sds.shape, tuple(sp), rules, mesh)),
        shapes, specs, is_leaf=lambda t: isinstance(t, SDS))


def _map_with_spec(sds_tree, spec_tree, mesh, rules):
    return jax.tree.map(
        lambda sds, sp: jax.sharding.NamedSharding(
            mesh, resolve_spec(sds.shape, tuple(sp), rules, mesh)),
        sds_tree, spec_tree, is_leaf=lambda t: isinstance(t, SDS))


def opt_shardings(cfg: ModelConfig, tcfg: TrainConfig, mesh, rules: RuleSet):
    params_sds = lm.param_shapes(cfg)
    opt_sds = jax.eval_shape(lambda p: init_opt_state(p, tcfg), params_sds)
    specs = opt_state_specs(lm.param_specs(cfg), tcfg)
    return _map_with_spec(opt_sds, specs, mesh, rules), opt_sds


def batch_shardings(batch_sds, mesh, rules: RuleSet):
    return {
        k: jax.sharding.NamedSharding(
            mesh, resolve_spec(v.shape, BATCH_LOGICAL[k], mesh=mesh,
                               rules=rules))
        for k, v in batch_sds.items()
    }


def cache_shardings(cfg: ModelConfig, caches_sds, mesh, rules: RuleSet):
    specs = lm.cache_specs(cfg)
    return _map_with_spec(caches_sds, specs, mesh, rules)
