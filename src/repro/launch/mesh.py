"""Production mesh construction (function — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # axis_types / AxisType landed after jax 0.4; default (Auto) semantics
    # are what we want on both old and new jax.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    return _mesh(shape, axes)
