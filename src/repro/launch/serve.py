"""Serving launcher: continuous-batched generation at smoke scale, with the
energy-proportional autoscaler accounting for the run."""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, get_config, smoke_config
from repro.core.cluster import tpu_v5e_pod
from repro.core.scheduler import ScalePolicy
from repro.serving.autoscaler import ServingAutoscaler
from repro.serving.batcher import ContinuousBatcher
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--int8-weights", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    scfg = ServeConfig(max_seq_len=args.prompt_len + args.max_new_tokens + 8,
                       quantize_weights=args.int8_weights)
    engine = ServingEngine(cfg, scfg)
    engine.init_random(0)
    bat = ContinuousBatcher(engine, slots=args.slots)
    scaler = ServingAutoscaler(tpu_v5e_pod(8), unit_rate_rps=4.0,
                               policy=ScalePolicy(min_units=1))

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        scaler.record_arrival(time.monotonic() - t0)
        bat.submit(prompt, max_new_tokens=args.max_new_tokens)
    reqs = list(bat.queue)
    ticks = 0
    while (bat.queue or any(a is not None for a in bat.active)) \
            and ticks < 10000:
        served = bat.step()
        scaler.tick(time.monotonic() - t0, served)
        ticks += 1
    dt = time.monotonic() - t0
    rep = scaler.report()
    print(json.dumps({
        "arch": args.arch,
        "requests": args.requests,
        "ticks": ticks,
        "wall_s": dt,
        "tokens_generated": sum(len(r.generated) for r in reqs),
        "tokens_per_s": sum(len(r.generated) for r in reqs) / dt,
        "autoscaler": {
            "mean_active_units": rep.mean_active,
            "energy_j_modeled": rep.energy_j,
            "scale_events": rep.scale_events,
        },
        "sample_output": [int(t) for t in reqs[0].generated[:8]],
    }, indent=1))


if __name__ == "__main__":
    main()
