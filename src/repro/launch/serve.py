"""Serving launcher: continuous-batched generation at smoke scale, run
through the :class:`~repro.runtime.ClusterRuntime` request-lifecycle API
(activation gating + energy accounting, paper §5.2)."""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.config import ServeConfig, get_config, smoke_config
from repro.core.cluster import tpu_v5e_pod
from repro.runtime import ClusterRuntime, LMServingWorkload, ScalePolicy
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--int8-weights", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    scfg = ServeConfig(max_seq_len=args.prompt_len + args.max_new_tokens + 8,
                       quantize_weights=args.int8_weights)
    engine = ServingEngine(cfg, scfg)
    engine.init_random(0)
    workload = LMServingWorkload(engine, slots=args.slots,
                                 max_new_tokens=args.max_new_tokens)
    # a "unit" sustains ~0.25 req/s at smoke scale: a burst of submissions
    # scales slots up, and the window decay scales them back down
    runtime = ClusterRuntime(tpu_v5e_pod(8), workload,
                             policy=ScalePolicy(min_units=1),
                             unit_rate=0.25)

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        runtime.submit(prompt)
    tel = runtime.run(max_ticks=10000)
    dt = time.monotonic() - t0
    tokens = sum(len(r.output) for r in tel.responses)
    print(json.dumps({
        "arch": args.arch,
        "requests": args.requests,
        "served": tel.served,
        "ticks": tel.ticks,
        "wall_s": dt,
        "tokens_generated": tokens,
        "tokens_per_s": tokens / dt,
        "telemetry": {
            "mean_active_units": tel.mean_active,
            "energy_j_modeled": tel.energy_j,
            "tpe": tel.tpe,
            "scale_events": tel.scale_events,
            "p99_latency_ticks": tel.p99_latency_s,
        },
        "sample_output": [int(t) for t in tel.responses[0].output[:8]]
        if tel.responses else [],
    }, indent=1))


if __name__ == "__main__":
    main()
