"""Training launcher.

Smoke-scale (CPU) end-to-end runs use ``--smoke``; production meshes are
exercised through ``repro.launch.dryrun``. Demonstrates checkpoint/resume
(kill and re-run with the same --ckpt-dir) and straggler-hedged data
loading.
"""
from __future__ import annotations

import argparse
import json
import logging


from repro.config import TrainConfig, get_config, smoke_config
from repro.launch.specs import default_train_config
from repro.training.data import DataConfig, PrefetchingLoader
from repro.training.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--opt-state-dtype", default="fp32",
                    choices=["fp32", "int8"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(levelname)s %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    base = default_train_config(cfg)
    tcfg = TrainConfig(**{**base.__dict__,
                          "learning_rate": args.lr,
                          "total_steps": args.steps,
                          "warmup_steps": max(args.steps // 10, 1),
                          "opt_state_dtype": args.opt_state_dtype,
                          "microbatches": 1 if args.smoke else base.microbatches,
                          "remat": "none" if args.smoke else base.remat})
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.batch,
                      frontend_tokens=cfg.frontend_tokens,
                      frontend_dim=cfg.frontend_dim or cfg.d_model)
    loader = PrefetchingLoader(dcfg)
    trainer = Trainer(cfg, tcfg, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every)
    hist = trainer.run(loader, steps=args.steps, log_every=args.log_every)
    print(json.dumps({
        "arch": args.arch,
        "steps": len(hist["loss"]),
        "first_loss": hist["loss"][0],
        "last_loss": hist["loss"][-1],
        "mean_step_s": sum(hist["step_time_s"]) / len(hist["step_time_s"]),
        "hedged_batches": loader.hedge_count,
    }, indent=1))


if __name__ == "__main__":
    main()
