"""mamba2-130m [ssm]: 24L d=768 attention-free, vocab=50280, ssm_state=128;
SSD (state-space duality). [arXiv:2405.21060; unverified]

Mamba-2 defaults: expand=2 => d_inner=1536, headdim=64 => 24 SSD heads,
d_conv=4.
"""
from repro.config import MambaConfig, ModelConfig, register


@register("mamba2-130m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, headdim=64),
        tie_embeddings=True,
        source="arXiv:2405.21060 / hf:state-spaces/mamba2-130m",
    )
