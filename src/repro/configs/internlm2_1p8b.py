"""internlm2-1.8b [dense]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
[arXiv:2403.17297; hf]
"""
from repro.config import ModelConfig, register


@register("internlm2-1.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        head_dim=128,
        source="arXiv:2403.17297 / hf:internlm/internlm2-1_8b",
    )
