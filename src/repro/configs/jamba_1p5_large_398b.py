"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2; Mamba+attn 1:7 interleave.
[arXiv:2403.19887; hf]

Layer pattern: blocks of 8 with attention at position 4 (1 attn : 7 mamba,
per the Jamba paper); MoE every 2nd layer (period=2 reproduces the 398B
headline — derivation in DESIGN.md §6).
"""
from repro.config import ATTN, MAMBA, MambaConfig, ModelConfig, MoEConfig, register


@register("jamba-1.5-large-398b")
def config() -> ModelConfig:
    pattern = []
    for i in range(72):
        pattern.append(ATTN if i % 8 == 4 else MAMBA)
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        head_dim=128,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                      period=2, offset=1),
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, headdim=128),
        layer_pattern=tuple(pattern),
        source="arXiv:2403.19887 / hf:ai21labs/AI21-Jamba-1.5-Large",
    )
