"""internvl2-1b [vlm]: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151655;
InternViT + InternLM2/Qwen2-0.5B backbone. [arXiv:2404.16821; hf]

Backbone only per spec: the InternViT frontend is a STUB — input_specs()
provides precomputed patch embeddings (frontend_tokens positions of
frontend_dim) that are prepended to the token sequence.
"""
from repro.config import ModelConfig, register


@register("internvl2-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        head_dim=64,
        qkv_bias=True,           # Qwen2-family backbone
        frontend_tokens=256,     # one ViT tile worth of patch embeddings
        frontend_dim=896,
        source="arXiv:2404.16821 / hf:OpenGVLab/InternVL2-1B",
    )
