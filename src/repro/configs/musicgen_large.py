"""musicgen-large [audio]: 48L d=2048 32H (kv=32 => MHA) d_ff=8192
vocab=2048; decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only per spec: the EnCodec frontend is a STUB — input_specs()
provides precomputed frame embeddings / token ids in the 2048-entry codebook
vocabulary.
"""
from repro.config import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        head_dim=64,
        frontend_tokens=0,      # tokens come pre-quantized (EnCodec stub)
        source="arXiv:2306.05284 / hf:facebook/musicgen-large",
    )
