"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1; early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Implemented literally as assigned (every layer MoE, 128e top-1, no shared
expert); the resulting ~0.78T total parameters are recorded in DESIGN.md §6.
"""
from repro.config import ModelConfig, MoEConfig, register


@register("llama4-maverick-400b-a17b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=202048,
        head_dim=128,
        moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192, period=1),
        rope_theta=500000.0,
        source="hf:meta-llama/Llama-4-Scout-17B-16E (shape-assigned variant)",
    )
