"""granite-moe-1b-a400m [moe]: 24L d=1024 16H (GQA kv=8) d_ff(expert)=512
vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.config import ModelConfig, MoEConfig, register


@register("granite-moe-1b-a400m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=8,
        d_ff=0,  # every layer is MoE; no dense FFN
        vocab_size=49155,
        head_dim=64,
        moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512, period=1),
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
