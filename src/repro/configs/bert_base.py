"""bert-base (the paper's own DL-serving workload, §3/§5): 12L d=768 12H
(MHA) d_ff=3072 vocab=30522; encoder-only.
[arXiv:1810.04805; hf:tfhub bert_en_uncased_L-12_H-768_A-12]

Encoder-only => no decode shapes; used by the paper-reproduction benchmark
suite (Fig 11/12, Table 5), not by the 40-cell dry-run table.
"""
from repro.config import ModelConfig, register


@register("bert-base")
def config() -> ModelConfig:
    return ModelConfig(
        name="bert-base",
        family="dense",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=30522,
        head_dim=64,
        source="arXiv:1810.04805 (paper workload, encoder-only)",
    )
