"""Assigned architecture configs (importing this package registers them)."""
from repro.configs import (  # noqa: F401
    granite_moe_1b_a400m,
    llama4_maverick_400b_a17b,
    stablelm_12b,
    phi3_medium_14b,
    qwen2_72b,
    internlm2_1p8b,
    musicgen_large,
    mamba2_130m,
    internvl2_1b,
    jamba_1p5_large_398b,
    bert_base,
)

ASSIGNED_ARCHS = (
    "granite-moe-1b-a400m",
    "llama4-maverick-400b-a17b",
    "stablelm-12b",
    "phi3-medium-14b",
    "qwen2-72b",
    "internlm2-1.8b",
    "musicgen-large",
    "mamba2-130m",
    "internvl2-1b",
    "jamba-1.5-large-398b",
)
