"""Hardware constants for the roofline analysis (deployment target:
TPU v5e), plus the paper platforms for cross-regime comparisons."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float       # FLOP/s
    hbm_bw: float                # bytes/s
    ici_link_bw: float           # bytes/s per link
    hbm_bytes: float


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_bytes=16 * 1024**3,
)

# The paper's units, through the same lens (per-unit).
SD865 = ChipSpec(
    name="sd865",
    peak_flops_bf16=1.2e12,      # Adreno 650 fp16 ~1.2 TFLOPS
    hbm_bw=34.1e9,               # LPDDR5 quad-channel
    ici_link_bw=0.125e9 * 0.903,  # 1 GbE PCB port at measured TCP eff.
    hbm_bytes=12 * 1024**3,
)

A40 = ChipSpec(
    name="a40",
    peak_flops_bf16=149.7e12,    # bf16 w/ sparsity off
    hbm_bw=696e9,
    ici_link_bw=8e9,             # PCIe4 x16 effective
    hbm_bytes=48 * 1024**3,
)
