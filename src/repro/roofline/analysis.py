"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch x shape x mesh) cell, per the methodology:

    compute    = HLO_FLOPs_per_chip        / peak_FLOP/s
    memory     = HLO_bytes_per_chip        / HBM_bw
    collective = collective_wire_bytes     / link_bw        (per chip)

``compiled.cost_analysis()`` reports flops/bytes of the *per-device* SPMD
module, so terms are per-chip directly (equivalent to the prompt's
HLO_FLOPs_total / (chips x peak)).

Collective bytes are not in cost_analysis: we parse the post-partitioning
HLO (``compiled.as_text()``), find every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, read the result shape and
replica-group size, and convert to ring wire bytes per chip:

    all-gather      (A-1)/A * result_bytes          (received)
    all-reduce      2 (A-1)/A * result_bytes        (RS + AG)
    reduce-scatter  (A-1)/A * A * result_bytes      (operand streamed)
    all-to-all      (A-1)/A * result_bytes
    collective-permute  result_bytes
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.roofline.hw import TPU_V5E, ChipSpec

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(shape_str: str) -> float:
    """'f32[128,1024]' -> bytes. Tuple shapes handled by summing parts."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    return 2  # collective-permute etc.


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    result_bytes: Dict[str, float] = field(default_factory=dict)
    wire_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        # Match op lines: "%name = TYPE[SHAPE] all-reduce(...)" etc.
        m = re.search(r"=\s*([^=]*?)\s+(all-gather|all-reduce|reduce-scatter"
                      r"|all-to-all|collective-permute)(-start|-done)?\(", s)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        kind = m.group(2)
        shape_str = m.group(1)
        rb = _shape_bytes(shape_str)
        a = _group_size(s)
        if kind == "all-gather":
            wire = rb * (a - 1) / a
        elif kind == "all-reduce":
            wire = 2 * rb * (a - 1) / a
        elif kind == "reduce-scatter":
            wire = rb * (a - 1)          # operand = A x result
        elif kind == "all-to-all":
            wire = rb * (a - 1) / a
        else:  # collective-permute
            wire = rb
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.result_bytes[kind] = stats.result_bytes.get(kind, 0.0) + rb
        stats.wire_bytes[kind] = stats.wire_bytes.get(kind, 0.0) + wire
    return stats


# ---------------------------------------------------------------------------
@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    step_kind: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops_total: float
    useful_flops_ratio: float     # MODEL_FLOPS / (flops_per_chip * chips)
    roofline_fraction: float      # bound-term share of the sum? see note
    collective_detail: Dict[str, float] = field(default_factory=dict)
    memory_analysis: Dict[str, float] = field(default_factory=dict)
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def roofline_from_artifacts(*, arch: str, shape: str, mesh_name: str,
                            step_kind: str, chips: int,
                            cost: Dict[str, float],
                            collectives: CollectiveStats,
                            model_flops_total: float,
                            memory_analysis: Optional[Dict[str, float]] = None,
                            chip: ChipSpec = TPU_V5E,
                            note: str = "") -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collectives.total_wire_bytes
    compute_s = flops / chip.peak_flops_bf16
    memory_s = hbm / chip.hbm_bw
    collective_s = coll / chip.ici_link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=terms.get)  # type: ignore[arg-type]
    total_hlo_flops = flops * chips
    useful = (model_flops_total / total_hlo_flops
              if total_hlo_flops > 0 else 0.0)
    # roofline fraction: useful model-FLOPs time over the dominating term
    # (an MFU-style bound: what fraction of the bottleneck's time would a
    # perfect implementation of the model math need).
    ideal_s = (model_flops_total / chips) / chip.peak_flops_bf16
    frac = ideal_s / max(terms[bound], 1e-30)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, step_kind=step_kind,
        chips=chips, flops_per_chip=flops, hbm_bytes_per_chip=hbm,
        collective_wire_bytes_per_chip=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bound=bound, model_flops_total=model_flops_total,
        useful_flops_ratio=useful, roofline_fraction=min(frac, 1.0),
        collective_detail=dict(collectives.wire_bytes),
        memory_analysis=memory_analysis or {},
        note=note,
    )


def model_flops(num_params_active: float, tokens: float,
                step_kind: str) -> float:
    """MODEL_FLOPS: 6 N D for a train step (fwd+bwd), 2 N D forward-only
    (prefill / decode-per-step)."""
    if step_kind == "train":
        return 6.0 * num_params_active * tokens
    return 2.0 * num_params_active * tokens
