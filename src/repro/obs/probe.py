"""Per-tick fleet probes: zero-cost when off, row-based when on.

A :class:`ProbeRegistry` fans one per-tick emission out to its sinks.
Engines hold a single ``obs`` reference and perform exactly one
``is None`` check per tick when observability is not configured — the
"probes off = no measurable cost" half of the overhead contract. When
on, the scalar and vector engines emit one row per tick; the jax
engine's jitted scan stays pure and its rows are expanded host-side
after ``lax.scan`` (``Fleet._obs_expand_jax``), so enabling probes
never perturbs simulation arithmetic on any backend.

A row is a ``{metric: (n_racks,) array}`` mapping — one numpy op per
metric per tick, not per-rack Python objects — which is what keeps the
probes-on vector tick rate within the perf-gated 5% budget
(``obs/fleet_probe_overhead_ratio`` in ``benchmarks/BENCH_baseline.json``).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Mapping, Sequence

import numpy as np

__all__ = ["PROBE_METRICS", "MetricSink", "MemorySink", "CallbackSink",
           "ProbeRegistry"]

#: Standard per-tick fleet metrics (per-rack arrays). Thermal metrics
#: are only emitted by fleets with a thermal model; ``max_temp_c`` is
#: NaN for racks without one.
PROBE_METRICS: Dict[str, str] = {
    "power_w": "rack power incl. shared rail (W)",
    "queued": "requests waiting after the tick",
    "active_units": "powered units (incl. hedge borrows)",
    "waking_units": "units mid wake transition (0 in the fleet "
                    "engines' instantaneous-activation model)",
    "utilization": "fraction of powered capacity used",
    "opp_index": "operating point selected this tick (0 for racks "
                 "without an OPP table)",
    "hedge_units": "straggler-hedge units borrowed this tick",
    "max_temp_c": "hottest die (NaN for racks without a thermal model)",
    "throttled_units": "trip-latched dies",
}


class MetricSink:
    """Receives per-tick rows. Subclass and override ``on_tick``."""

    def bind(self, rack_names: Sequence[str]) -> None:
        """Called once, before the first row, with the rack labels."""
        self.rack_names = list(rack_names)

    def on_tick(self, t: float, dt_s: float,
                metrics: Mapping[str, np.ndarray]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Flush/teardown hook for streaming sinks."""


class MemorySink(MetricSink):
    """Accumulates rows in memory; the default sink for tests, traces,
    and reports. ``history()`` stacks each metric into a
    ``(ticks, racks)`` array."""

    def __init__(self) -> None:
        self.rack_names: List[str] = []
        self._t: List[float] = []
        self._dt: List[float] = []
        self._rows: Dict[str, List[np.ndarray]] = {}

    def on_tick(self, t: float, dt_s: float,
                metrics: Mapping[str, np.ndarray]) -> None:
        self._t.append(t)
        self._dt.append(dt_s)
        for name, row in metrics.items():
            self._rows.setdefault(name, []).append(row)

    @property
    def n_ticks(self) -> int:
        return len(self._t)

    def times(self) -> np.ndarray:
        return np.asarray(self._t, float)

    def dts(self) -> np.ndarray:
        return np.asarray(self._dt, float)

    def history(self) -> Dict[str, np.ndarray]:
        """``{metric: (ticks, racks)}`` stacked history."""
        return {name: np.stack(rows) for name, rows in self._rows.items()}

    def last(self) -> Dict[str, np.ndarray]:
        """The most recent row per metric (Prometheus-style gauges)."""
        return {name: rows[-1] for name, rows in self._rows.items() if rows}


class CallbackSink(MetricSink):
    """Adapts a plain callable ``fn(t, dt_s, metrics)`` into a sink."""

    def __init__(self, fn: Callable[[float, float,
                                     Mapping[str, np.ndarray]], None]) -> None:
        self.rack_names: List[str] = []
        self._fn = fn

    def on_tick(self, t: float, dt_s: float,
                metrics: Mapping[str, np.ndarray]) -> None:
        self._fn(t, dt_s, metrics)


class ProbeRegistry:
    """Routes per-tick rows from an engine to every registered sink."""

    def __init__(self, sinks: Sequence[MetricSink] = ()) -> None:
        self.rack_names: List[str] = []
        self._sinks: List[MetricSink] = list(sinks)

    def add_sink(self, sink: MetricSink) -> MetricSink:
        self._sinks.append(sink)
        if self.rack_names:
            sink.bind(self.rack_names)
        return sink

    @property
    def active(self) -> bool:
        """True when at least one sink is listening — engines skip row
        construction entirely when this is False."""
        return bool(self._sinks)

    def bind(self, rack_names: Sequence[str]) -> None:
        self.rack_names = list(rack_names)
        for sink in self._sinks:
            sink.bind(rack_names)

    def emit_tick(self, t: float, dt_s: float,
                  metrics: Mapping[str, np.ndarray]) -> None:
        for sink in self._sinks:
            sink.on_tick(t, dt_s, metrics)

    def close(self) -> None:
        for sink in self._sinks:
            sink.close()
