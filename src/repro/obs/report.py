"""``python -m repro.obs.report`` — one-shot observability run report.

Drives a fig16-style mini-fleet (mixed SoC + Xeon racks, optionally
the full DVFS + thermal stack) through a diurnal trace with the whole
observability surface attached, then writes, under ``--out-dir``:

  * ``report.md`` / ``report.html`` — run summary, energy attribution
    table, SLO alert list, probe extremes;
  * ``trace.json`` — Chrome trace-event JSON (open in Perfetto);
  * ``metrics.jsonl`` — the per-tick probe stream;
  * ``prometheus.txt`` — last-tick gauges in text exposition format;
  * ``attribution.json`` — the full rack x tenant x cause ledger.

The attribution parity contract is asserted inline: the replayed
ledger total must equal the telemetry's ``energy_j`` bitwise on the
scalar/vector backends (within the fig16 jax tolerance on ``--backend
jax``), so a passing report is itself a parity check. CI runs this as
a smoke test and uploads the HTML + trace as artifacts.
"""
from __future__ import annotations

import argparse
import html as _html
import json
import os
from typing import List, Optional, Sequence

import numpy as np

from repro.obs import (EnergyLedger, FleetObs, LatencyBurnRule, MemorySink,
                       ProbeRegistry, QueueBlowupRule, SloPolicy,
                       ThrottleStormRule, TraceConfig, TraceRecorder,
                       validate_chrome_trace)
from repro.obs.export import (write_attribution_json, write_chrome_trace,
                              write_metrics_jsonl, write_prometheus)

#: fig16's documented jax tolerance (the engine reorders float ops)
JAX_RTOL = 1e-9


def _build_fleet(backend: str, n_soc: int, n_cpu: int, dvfs: bool,
                 obs: FleetObs, dt_s: float) -> "object":
    from repro.core.cluster import edge_server_cpu, soc_cluster
    from repro.fleet import Fleet, JoinShortestQueueRouter, homogeneous_fleet
    from repro.power import SchedutilGovernor, ThermalParams, sd865_opp_table
    from repro.runtime import ScalePolicy

    policy = ScalePolicy(
        cooldown_s=300.0, min_units=1,
        freq_governor=SchedutilGovernor() if dvfs else None)
    racks = homogeneous_fleet(
        soc_cluster(), n_soc, 30.0, policy=policy,
        opp_table=sd865_opp_table() if dvfs else None,
        thermal=ThermalParams() if dvfs else None)
    if n_cpu:
        racks += homogeneous_fleet(
            edge_server_cpu(), n_cpu, 9.0,
            policy=ScalePolicy(cooldown_s=300.0, min_units=1))
    return Fleet(racks, router=JoinShortestQueueRouter(), dt_s=dt_s,
                 backend=backend, obs=obs)


def _markdown(tel: "object", ledger: EnergyLedger, sink: MemorySink,
              trace_events: int, backend: str) -> str:
    s = tel.summary()  # type: ignore[attr-defined]
    alerts = tel.alerts  # type: ignore[attr-defined]
    hist = sink.history() if sink.n_ticks else {}
    lines: List[str] = [
        "# Fleet observability report",
        "",
        f"Backend `{backend}` · {int(s['racks'])} racks · "
        f"{int(s['ticks'])} ticks · router `{tel.router}`"  # type: ignore[attr-defined]
        f" · drained={bool(s['drained'])}",
        "",
        "## Run summary",
        "",
        "| metric | value |",
        "|---|---:|",
    ]
    for key in ("served", "energy_kwh", "tpe", "mean_power_w",
                "peak_power_w", "mean_active_units", "p50_latency_s",
                "p95_latency_s", "p99_latency_s", "proportionality",
                "monthly_electricity_usd"):
        lines.append(f"| {key} | {s[key]:.4g} |")
    lines += ["", "## Energy attribution (exact ledger)", ""]
    tol = ("bitwise" if ledger.tolerance is None
           else f"rtol {ledger.tolerance:g}")
    lines.append(f"Replay contract vs `energy_j`: **{tol}** "
                 f"(verified inline by this report).")
    lines += ["", ledger.to_markdown(), ""]
    lines += ["## SLO alerts", ""]
    if alerts:
        lines += ["| rule | severity | window | worst | threshold |",
                  "|---|---|---|---:|---:|"]
        for a in alerts:
            lines.append(
                f"| {a.rule} | {a.severity} | "
                f"[{a.t_start:.0f}s, {a.t_end:.0f}s) | "
                f"{a.worst_value:.4g} | {a.threshold:.4g} |")
    else:
        lines.append("No alerts fired.")
    if hist:
        lines += ["", "## Probe extremes", "",
                  "| metric | min | max |", "|---|---:|---:|"]
        for metric in sorted(hist):
            rows = hist[metric]
            with np.errstate(invalid="ignore"):
                lo, hi = np.nanmin(rows), np.nanmax(rows)
            lines.append(f"| {metric} | {lo:.4g} | {hi:.4g} |")
    lines += ["", "## Artifacts", "",
              f"- `trace.json` — {trace_events} chrome-trace events "
              "(open at https://ui.perfetto.dev)",
              "- `metrics.jsonl` — per-tick probe stream",
              "- `prometheus.txt` — last-tick text exposition",
              "- `attribution.json` — full rack x tenant x cause ledger",
              ""]
    return "\n".join(lines)


def _md_to_html(md: str) -> str:
    """Minimal markdown → HTML (headers, tables, inline code, bold) —
    enough for the artifacts viewer, no external dependency."""
    out: List[str] = ["<!doctype html><html><head><meta charset='utf-8'>",
                      "<title>Fleet observability report</title><style>",
                      "body{font-family:sans-serif;margin:2em;max-width:60em}",
                      "table{border-collapse:collapse}",
                      "td,th{border:1px solid #999;padding:0.3em 0.8em}",
                      "code{background:#eee;padding:0 0.2em}",
                      "</style></head><body>"]
    in_table = False

    def inline(text: str) -> str:
        text = _html.escape(text)
        for mark, tag in (("**", "b"), ("`", "code")):
            parts = text.split(mark)
            if len(parts) > 2:
                rebuilt = parts[0]
                for j, part in enumerate(parts[1:], 1):
                    rebuilt += (f"<{tag}>" if j % 2 else f"</{tag}>") + part
                if len(parts) % 2:  # balanced marks only
                    text = rebuilt
        return text

    for line in md.splitlines():
        if line.startswith("|"):
            cells = [c.strip() for c in line.strip("|").split("|")]
            if all(set(c) <= {"-", ":", " "} and c for c in cells):
                continue  # separator row
            if not in_table:
                out.append("<table>")
                in_table = True
            out.append("<tr>" + "".join(
                f"<td>{inline(c)}</td>" for c in cells) + "</tr>")
            continue
        if in_table:
            out.append("</table>")
            in_table = False
        if line.startswith("#"):
            level = len(line) - len(line.lstrip("#"))
            out.append(f"<h{level}>{inline(line.lstrip('# '))}</h{level}>")
        elif line.startswith("- "):
            out.append(f"<p>• {inline(line[2:])}</p>")
        elif line.strip():
            out.append(f"<p>{inline(line)}</p>")
    if in_table:
        out.append("</table>")
    out.append("</body></html>")
    return "\n".join(out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="fig16-style mini-run with the full observability "
                    "surface; writes a markdown/HTML report + artifacts")
    ap.add_argument("--backend", default="vector",
                    choices=("scalar", "vector", "jax"))
    ap.add_argument("--out-dir", default="obs_report")
    ap.add_argument("--soc", type=int, default=8,
                    help="SoC-cluster racks (default 8)")
    ap.add_argument("--cpu", type=int, default=2,
                    help="Xeon edge racks (default 2)")
    ap.add_argument("--hours", type=float, default=2.0,
                    help="diurnal trace length (default 2 h)")
    ap.add_argument("--dvfs", action="store_true",
                    help="attach schedutil + SD865 OPP table + RC "
                         "thermal network to the SoC racks")
    ap.add_argument("--load", type=float, default=0.5,
                    help="trace peak as a fraction of fleet capacity")
    ap.add_argument("--sample-every", type=int, default=1,
                    help="trace-span request sampling stride")
    args = ap.parse_args(argv)

    from repro.fleet import diurnal_trace

    dt_s = 60.0
    sink = MemorySink()
    ledger = EnergyLedger()
    slo = SloPolicy([
        LatencyBurnRule(target_s=3.0 * dt_s, window_s=30 * dt_s),
        ThrottleStormRule(max_throttled_units=0),
        QueueBlowupRule(max_queued=50),
    ])
    obs = FleetObs(probes=ProbeRegistry([sink]), ledger=ledger, slo=slo)
    fleet = _build_fleet(args.backend, args.soc, args.cpu, args.dvfs,
                         obs, dt_s)
    trace = args.load * fleet.capacity_rps * diurnal_trace(
        peak_rps=1.0, hours=args.hours, dt_s=dt_s, seed=7)
    tel = fleet.play_trace(trace)

    # the parity contract, asserted inline
    replay = ledger.total_energy_j()
    if args.backend == "jax":
        err = abs(replay - tel.energy_j) / max(abs(tel.energy_j), 1e-30)
        assert err <= JAX_RTOL, \
            f"ledger replay off by rel {err:.3e} (> {JAX_RTOL})"
    else:
        assert replay == tel.energy_j, \
            f"ledger replay {replay!r} != energy_j {tel.energy_j!r}"

    os.makedirs(args.out_dir, exist_ok=True)
    rec = TraceRecorder(config=TraceConfig(sample_every=args.sample_every))
    rec.record_fleet(tel, sink)
    chrome = rec.to_chrome_trace()
    problems = validate_chrome_trace(chrome)
    assert not problems, f"invalid chrome trace: {problems[:5]}"
    write_chrome_trace(os.path.join(args.out_dir, "trace.json"), chrome)
    write_metrics_jsonl(os.path.join(args.out_dir, "metrics.jsonl"), sink)
    write_prometheus(os.path.join(args.out_dir, "prometheus.txt"), sink,
                     tel.alerts)
    write_attribution_json(
        os.path.join(args.out_dir, "attribution.json"), ledger)
    md = _markdown(tel, ledger, sink, len(chrome["traceEvents"]),
                   args.backend)
    with open(os.path.join(args.out_dir, "report.md"), "w") as fh:
        fh.write(md)
    with open(os.path.join(args.out_dir, "report.html"), "w") as fh:
        fh.write(_md_to_html(md))
    with open(os.path.join(args.out_dir, "summary.json"), "w") as fh:
        json.dump({k: float(v) for k, v in tel.summary().items()}, fh,
                  indent=2)
    print(f"report: {os.path.join(args.out_dir, 'report.md')} "
          f"(+ html, trace.json, metrics.jsonl, prometheus.txt, "
          f"attribution.json)")
    print(f"energy {tel.energy_j:.1f} J, ledger replay {replay:.1f} J, "
          f"{len(tel.alerts)} alert(s), "
          f"{len(chrome['traceEvents'])} trace events")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
