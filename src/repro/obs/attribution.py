"""Exact energy attribution: every joule gets a rack, tenant, and cause.

``EnergyLedger`` records, per tick, the same floating-point leaves the
power integrals accumulate — shared rail, fan rail, per-tenant active
compute at each OPP, hedge borrows, and the off/idle rest floor — in
the same order. Replaying those leaves through the same expression
tree (``rack_energy_j`` / ``total_energy_j``) therefore reproduces the
pool's / vector engine's ``energy_j`` integral **bitwise** on the
scalar and vector backends; the jax backend replays rows emitted from
the jitted scan and is compared within the engine's documented
tolerance (``ledger.tolerance``, relative) because XLA may fuse the
per-tick expression differently.

Two recording surfaces feed one ledger:

  * :meth:`record_pool_tick` — called from ``UnitPool.charge`` (both
    pool backends) when a ledger is attached via
    ``pool.attach_ledger``. Leaves arrive per tenant: the per-OPP
    ``count x unit_power`` products in ascending-OPP order (exactly
    ``_power_from_opp_counts``'s accumulation) plus the borrowed
    ``extra``-unit product; waking-unit counts split the rest floor
    into idle vs wake-transition energy.
  * :meth:`record_fleet_tick` — called once per tick by the vector
    fleet engine (and by the host-side jax expansion) with per-rack
    arrays mirroring ``_VectorFleetEngine.tick``'s power expression:
    ``total = (shared + fan) + (active + hedge) + rest``.

Replay is the parity contract; the *cause* split (:meth:`by_cause`)
additionally carves derived components out of the recorded leaves —
the throttle-floor share of active compute (trip-latched dies metered
at the lowest OPP) and the wake-transition share of the rest floor —
and is computed with ``math.fsum``, so per-cause totals match the
replayed total to ~1 ulp per tick, not bitwise. Tests pin the bitwise
contract on the replay and a 1e-9 relative bound on the split.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["EnergyLedger", "CAUSES"]

#: The causes a joule can be attributed to.
CAUSES = (
    "shared",          # per-rack shared rail (fans at rest, switch, BMC)
    "fan",             # thermal-model fan rail (rides the shared rail)
    "active",          # active compute at each unit's effective OPP
    "hedge",           # borrowed straggler-hedge units
    "throttle_floor",  # trip-latched dies metered at the lowest OPP
    "wake",            # waking units held at the rest floor
    "idle",            # powered-off / gated-idle floor
)

# One active-compute leaf: (cause label, watts, unit count). Pool leaves
# use "active:opp{k}" / "hedge" labels; watts is the *product* c_k * w_k
# exactly as the charge loop accumulated it.
_Leaf = Tuple[str, float, int]
# One tenant's leaves for one pool tick:
# (tenant, leaves, floor_units, floor_w) — floor_units trip-latched
# active dies, metered at floor_w each (derived cause split only).
_Group = Tuple[str, List[_Leaf], int, float]


@dataclass
class _PoolTick:
    t: float
    dt_s: float
    shared_w: float
    fan_w: float
    groups: List[_Group]
    rest_w: float
    rest_units: int
    waking_units: int


@dataclass
class _FleetTick:
    t: float
    dt_s: float
    fan_w: np.ndarray
    active_w: np.ndarray
    hedge_w: np.ndarray
    rest_w: np.ndarray
    hedge_units: np.ndarray
    rest_units: np.ndarray
    waking_units: Optional[np.ndarray]
    floor_units: Optional[np.ndarray]  # trip-latched active dies
    floor_w: Optional[np.ndarray]      # per-die floor-OPP draw


@dataclass
class EnergyLedger:
    """Per rack x tenant x cause energy breakdown with bitwise replay.

    ``tolerance`` is ``None`` for the bitwise scalar/vector contract;
    the jax path sets it to the engine's documented relative tolerance
    (the fig16 parity budget) — queries behave identically, only the
    strength of the ``energy_j`` comparison promised to callers
    differs.
    """

    tolerance: Optional[float] = None
    _pool_order: List[str] = field(default_factory=list)
    _pool_base: Dict[str, float] = field(default_factory=dict)
    _pool_ticks: Dict[str, List[_PoolTick]] = field(default_factory=dict)
    _fleet_names: List[str] = field(default_factory=list)
    _fleet_shared_w: Optional[np.ndarray] = None
    _fleet_ticks: List[_FleetTick] = field(default_factory=list)

    # -- recording: pool surface ----------------------------------------
    def register_pool(self, rack: str, base_energy_j: float = 0.0) -> None:
        """Start metering a pool under rack label ``rack``. The replay
        starts from ``base_energy_j`` (the pool's integral at attach
        time), so attaching mid-run still reproduces ``energy_j``."""
        if rack not in self._pool_base:
            self._pool_order.append(rack)
            self._pool_base[rack] = float(base_energy_j)
            self._pool_ticks[rack] = []

    def record_pool_tick(self, rack: str, t: float, dt_s: float, *,
                         shared_w: float, fan_w: float,
                         groups: Sequence[_Group], rest_w: float,
                         rest_units: int, waking_units: int) -> None:
        """One ``UnitPool.charge`` tick's leaves (see module docstring)."""
        self._pool_ticks[rack].append(_PoolTick(
            t=t, dt_s=dt_s, shared_w=shared_w, fan_w=fan_w,
            groups=list(groups), rest_w=rest_w,
            rest_units=rest_units, waking_units=waking_units))

    # -- recording: fleet surface ----------------------------------------
    def register_fleet(self, rack_names: Sequence[str],
                       shared_w: np.ndarray) -> None:
        """Start metering a fleet engine: per-rack names and the static
        per-rack shared-rail draw (``p_shared``)."""
        self._fleet_names = list(rack_names)
        self._fleet_shared_w = np.asarray(shared_w, float)

    def record_fleet_tick(self, t: float, dt_s: float, *,
                          fan_w: np.ndarray, active_w: np.ndarray,
                          hedge_w: np.ndarray, rest_w: np.ndarray,
                          hedge_units: np.ndarray, rest_units: np.ndarray,
                          waking_units: Optional[np.ndarray] = None,
                          floor_units: Optional[np.ndarray] = None,
                          floor_w: Optional[np.ndarray] = None) -> None:
        """One vector-engine (or expanded jax) tick, as per-rack arrays.

        ``active_w + hedge_w`` must equal the engine's ``p_units``
        elementwise-bitwise: for OPP-table racks ``active_w`` is the
        engine's ``p_act`` and ``hedge_w`` its ``h_f * w_req`` term
        (replayed as the same binary add); for table-less racks
        ``active_w`` is ``powered_f * w_req`` and ``hedge_w`` is 0.0
        (``x + 0.0`` is bitwise ``x`` for the non-negative draws here).
        """
        assert self._fleet_shared_w is not None, \
            "register_fleet() before record_fleet_tick()"
        self._fleet_ticks.append(_FleetTick(
            t=t, dt_s=dt_s, fan_w=fan_w, active_w=active_w,
            hedge_w=hedge_w, rest_w=rest_w, hedge_units=hedge_units,
            rest_units=rest_units, waking_units=waking_units,
            floor_units=floor_units, floor_w=floor_w))

    # -- replay (the bitwise contract) ------------------------------------
    def _replay_pool(self, rack: str) -> float:
        """Replay one pool's ticks through ``UnitPool.charge``'s exact
        accumulation tree: per-tenant leaf sums in recorded order, then
        ``((shared + fan) + p_units) + rest``, integrated tick by tick."""
        e = self._pool_base[rack]
        for tk in self._pool_ticks[rack]:
            p_units = 0.0
            for _tenant, leaves, _fu, _fw in tk.groups:
                p = 0.0
                for _cause, w, _n in leaves:
                    p += w
                p_units += p
            total = tk.shared_w + tk.fan_w + p_units + tk.rest_w
            e += total * tk.dt_s
        return e

    def _replay_fleet(self) -> np.ndarray:
        """Replay the fleet ticks through ``_VectorFleetEngine.tick``'s
        exact per-rack expression ``((shared + fan) + p_units) + rest``."""
        shared = self._fleet_shared_w
        assert shared is not None
        e = np.zeros(len(self._fleet_names))
        for tk in self._fleet_ticks:
            p_units = tk.active_w + tk.hedge_w
            total = shared + tk.fan_w + p_units + tk.rest_w
            e += total * tk.dt_s
        return e

    def rack_energy_j(self) -> Dict[str, float]:
        """Replayed energy integral per rack — bitwise-equal to each
        pool's / engine's per-rack ``energy_j`` on scalar/vector."""
        out: Dict[str, float] = {}
        for rack in self._pool_order:
            out[rack] = self._replay_pool(rack)
        if self._fleet_names:
            fe = self._replay_fleet()
            for i, name in enumerate(self._fleet_names):
                out[name] = float(fe[i])
        return out

    def total_energy_j(self) -> float:
        """Replayed fleet/pool total. Rack energies are combined with a
        left-to-right builtin sum in registration order — the same
        reduction ``FleetTelemetry.energy_j`` performs over per-rack
        telemetry — so the fleet total is also bitwise."""
        total = 0.0
        for rack in self._pool_order:
            total += self._replay_pool(rack)
        if self._fleet_names:
            for e in self._replay_fleet():
                total += float(e)
        return total

    # -- derived splits (fsum; ~1 ulp per tick, not bitwise) ---------------
    def by_rack_tenant_cause(self) -> Dict[str, Dict[str, Dict[str, float]]]:
        """``{rack: {tenant: {cause: joules}}}``. Fleet racks host one
        fluid tenant, recorded under the rack's own name."""
        out: Dict[str, Dict[str, Dict[str, List[float]]]] = {}

        def _add(rack: str, tenant: str, cause: str, j: float) -> None:
            out.setdefault(rack, {}).setdefault(tenant, {}) \
               .setdefault(cause, []).append(j)

        for rack in self._pool_order:
            for tk in self._pool_ticks[rack]:
                _add(rack, "-", "shared", tk.shared_w * tk.dt_s)
                if tk.fan_w:
                    _add(rack, "-", "fan", tk.fan_w * tk.dt_s)
                for tenant, leaves, fu, fw in tk.groups:
                    thr_w = fu * fw
                    act_w = 0.0
                    for cause, w, _n in leaves:
                        if cause == "hedge":
                            _add(rack, tenant, "hedge", w * tk.dt_s)
                        else:
                            act_w += w
                    if thr_w:
                        _add(rack, tenant, "throttle_floor", thr_w * tk.dt_s)
                    _add(rack, tenant, "active", (act_w - thr_w) * tk.dt_s)
                rest_j = tk.rest_w * tk.dt_s
                if tk.rest_units > 0 and tk.waking_units > 0:
                    wake_j = rest_j * (tk.waking_units / tk.rest_units)
                    _add(rack, "-", "wake", wake_j)
                    _add(rack, "-", "idle", rest_j - wake_j)
                else:
                    _add(rack, "-", "idle", rest_j)
        if self._fleet_names:
            shared = self._fleet_shared_w
            assert shared is not None
            for tk in self._fleet_ticks:
                thr_w = np.zeros(len(self._fleet_names))
                if tk.floor_units is not None and tk.floor_w is not None:
                    thr_w = tk.floor_units * tk.floor_w
                rest_j = tk.rest_w * tk.dt_s
                wake_frac = np.zeros(len(self._fleet_names))
                if tk.waking_units is not None:
                    nz = tk.rest_units > 0
                    wake_frac[nz] = tk.waking_units[nz] / tk.rest_units[nz]
                for i, rack in enumerate(self._fleet_names):
                    _add(rack, rack, "shared", float(shared[i]) * tk.dt_s)
                    if tk.fan_w[i]:
                        _add(rack, rack, "fan", float(tk.fan_w[i]) * tk.dt_s)
                    if thr_w[i]:
                        _add(rack, rack, "throttle_floor",
                             float(thr_w[i]) * tk.dt_s)
                    _add(rack, rack, "active",
                         float(tk.active_w[i] - thr_w[i]) * tk.dt_s)
                    if tk.hedge_w[i]:
                        _add(rack, rack, "hedge",
                             float(tk.hedge_w[i]) * tk.dt_s)
                    wj = float(rest_j[i]) * float(wake_frac[i])
                    if wj:
                        _add(rack, rack, "wake", wj)
                    _add(rack, rack, "idle", float(rest_j[i]) - wj)
        return {
            rack: {
                tenant: {cause: math.fsum(js) for cause, js in causes.items()}
                for tenant, causes in tenants.items()
            }
            for rack, tenants in out.items()
        }

    def by_cause(self) -> Dict[str, float]:
        """Fleet-wide joules per cause (fsum over racks and tenants)."""
        parts: Dict[str, List[float]] = {}
        for tenants in self.by_rack_tenant_cause().values():
            for causes in tenants.values():
                for cause, j in causes.items():
                    parts.setdefault(cause, []).append(j)
        return {cause: math.fsum(parts.get(cause, [0.0])) for cause in CAUSES
                if cause in parts}

    def by_tenant(self) -> Dict[str, float]:
        """Joules attributed to each tenant's own units (active + hedge
        + throttle floor; the shared/fan/idle rails are rack-level)."""
        parts: Dict[str, List[float]] = {}
        for tenants in self.by_rack_tenant_cause().values():
            for tenant, causes in tenants.items():
                if tenant == "-":
                    continue
                parts.setdefault(tenant, []).extend(causes.values())
        return {tenant: math.fsum(js) for tenant, js in parts.items()}

    # -- presentation -----------------------------------------------------
    @property
    def n_ticks(self) -> int:
        pool = max((len(v) for v in self._pool_ticks.values()), default=0)
        return max(pool, len(self._fleet_ticks))

    def to_records(self) -> List[Dict[str, object]]:
        """Flat ``{rack, tenant, cause, joules}`` rows (JSONL export)."""
        rows: List[Dict[str, object]] = []
        for rack, tenants in self.by_rack_tenant_cause().items():
            for tenant, causes in tenants.items():
                for cause, j in causes.items():
                    rows.append({"rack": rack, "tenant": tenant,
                                 "cause": cause, "joules": j})
        return rows

    def to_markdown(self) -> str:
        """Fleet-wide per-cause table plus the replay total."""
        by_cause = self.by_cause()
        total = self.total_energy_j()
        lines = ["| cause | energy (J) | share |",
                 "|---|---:|---:|"]
        for cause in CAUSES:
            if cause not in by_cause:
                continue
            j = by_cause[cause]
            share = j / total if total else 0.0
            lines.append(f"| {cause} | {j:.3f} | {100.0 * share:.2f}% |")
        lines.append(f"| **total (replayed)** | **{total:.3f}** | 100.00% |")
        return "\n".join(lines)
