"""SLO burn-rate alerting over fleet telemetry, streaming or post-hoc.

Rules consume one tick at a time (``SloPolicy.on_tick``) so a live
driver can alert mid-run; ``SloPolicy.evaluate`` replays a finished
:class:`~repro.fleet.telemetry.FleetTelemetry` through the *same*
streaming path, so both modes share one code path and produce
identical alerts. ``Fleet`` attaches the post-hoc result to
``FleetTelemetry.alerts`` when an :class:`~repro.obs.FleetObs` with an
``slo`` policy is configured.

Consecutive violating ticks merge into one :class:`Alert` window
carrying the worst observed value. Rules are deterministic functions
of the telemetry — no wall clock, no randomness — so alert lists are
reproducible run to run.
"""
from __future__ import annotations

from bisect import insort
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Alert", "SloRule", "LatencyBurnRule", "EnergyBudgetRule",
           "ThrottleStormRule", "QueueBlowupRule", "ShedStormRule",
           "SloPolicy"]


@dataclass
class Alert:
    """One violation window of one rule."""

    rule: str
    severity: str
    t_start: float
    t_end: float          # end of the last violating tick
    worst_value: float
    threshold: float
    message: str

    def to_record(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "t_start": self.t_start, "t_end": self.t_end,
                "worst_value": self.worst_value,
                "threshold": self.threshold, "message": self.message}


class SloRule:
    """Streaming rule: ``observe`` one tick, return the violating
    ``(value, threshold)`` pair or ``None``. ``reset`` clears run
    state so one rule instance can evaluate many runs."""

    name = "rule"
    severity = "warning"
    #: human-readable unit of ``value`` for alert messages
    unit = ""

    def reset(self) -> None:
        """Clear per-run state (override when the rule keeps any)."""

    def observe(self, t: float, dt_s: float,
                tick: Mapping[str, Any]) -> Optional[Tuple[float, float]]:
        raise NotImplementedError


class LatencyBurnRule(SloRule):
    """Rolling p95 request latency vs target over a sliding window.

    Completions stream in via ``tick["responses"]``; the window holds
    ``(finish_s, latency_s)`` of every completion in the last
    ``window_s`` seconds. Fires once at least ``min_count`` requests
    are in the window and their p95 exceeds ``target_s``.
    """

    name = "latency_burn"
    severity = "critical"
    unit = "s"

    def __init__(self, target_s: float, window_s: float = 3600.0,
                 min_count: int = 10) -> None:
        self.target_s = float(target_s)
        self.window_s = float(window_s)
        self.min_count = int(min_count)
        self._win: List[Tuple[float, float]] = []

    def reset(self) -> None:
        self._win = []

    def observe(self, t: float, dt_s: float,
                tick: Mapping[str, Any]) -> Optional[Tuple[float, float]]:
        for resp in tick.get("responses", ()):
            # keep the window sorted by finish time: responses from
            # different racks arrive interleaved
            insort(self._win, (float(resp.finish_s), float(resp.latency_s)))
        horizon = t + dt_s - self.window_s
        drop = 0
        for fin, _lat in self._win:
            if fin >= horizon:
                break
            drop += 1
        if drop:
            del self._win[:drop]
        if len(self._win) < self.min_count:
            return None
        lats = np.array([lat for _fin, lat in self._win])
        p95 = float(np.percentile(lats, 95))
        if p95 > self.target_s:
            return p95, self.target_s
        return None


class EnergyBudgetRule(SloRule):
    """Energy-budget burn rate: cumulative joules vs the prorated
    budget. A burn rate of 1.0 means "on budget for the horizon";
    fires when it exceeds ``max_burn`` after ``min_elapsed_s``."""

    name = "energy_budget_burn"
    severity = "warning"
    unit = "x budget"

    def __init__(self, budget_j: float, horizon_s: float,
                 max_burn: float = 1.0, min_elapsed_s: float = 0.0) -> None:
        self.budget_j = float(budget_j)
        self.horizon_s = float(horizon_s)
        self.max_burn = float(max_burn)
        self.min_elapsed_s = float(min_elapsed_s)
        self._energy_j = 0.0
        self._elapsed_s = 0.0

    def reset(self) -> None:
        self._energy_j = 0.0
        self._elapsed_s = 0.0

    def observe(self, t: float, dt_s: float,
                tick: Mapping[str, Any]) -> Optional[Tuple[float, float]]:
        power = np.asarray(tick["power_w"], float)
        self._energy_j += float(power.sum()) * dt_s  # reprolint: ok[RPL001] alerting roll-up, never enters the parity-compared telemetry
        self._elapsed_s += dt_s
        if self._elapsed_s < max(self.min_elapsed_s, dt_s):
            return None
        prorated = self.budget_j * (self._elapsed_s / self.horizon_s)
        burn = self._energy_j / prorated if prorated > 0 else 0.0
        if burn > self.max_burn:
            return burn, self.max_burn
        return None


class ThrottleStormRule(SloRule):
    """Fleet-wide trip-latched die count above a ceiling — a thermal
    storm where capacity silently degrades to the floor OPP."""

    name = "throttle_storm"
    severity = "critical"
    unit = "units"

    def __init__(self, max_throttled_units: int = 0) -> None:
        self.max_throttled_units = int(max_throttled_units)

    def observe(self, t: float, dt_s: float,
                tick: Mapping[str, Any]) -> Optional[Tuple[float, float]]:
        thr = tick.get("throttled_units")
        if thr is None:
            return None
        total = int(np.asarray(thr).sum())  # reprolint: ok[RPL001] int64 counts: integer addition is exact in any order
        if total > self.max_throttled_units:
            return float(total), float(self.max_throttled_units)
        return None


class QueueBlowupRule(SloRule):
    """Total queued requests above a ceiling — offered load outrunning
    activation (or a router hot-spotting one rack)."""

    name = "queue_blowup"
    severity = "warning"
    unit = "requests"

    def __init__(self, max_queued: int) -> None:
        self.max_queued = int(max_queued)

    def observe(self, t: float, dt_s: float,
                tick: Mapping[str, Any]) -> Optional[Tuple[float, float]]:
        queued = tick.get("queued")
        if queued is None:
            return None
        total = int(np.asarray(queued).sum())  # reprolint: ok[RPL001] int64 counts: integer addition is exact in any order
        if total > self.max_queued:
            return float(total), float(self.max_queued)
        return None


class ShedStormRule(SloRule):
    """Admission-shed burn rate above a ceiling: mean shed rps over a
    sliding window vs ``max_shed_rps``. Graceful degradation is
    supposed to shed *briefly* under a flash crowd — a sustained shed
    rate means the fleet is underprovisioned (or a breaker is stuck
    open) and operators should know. Reads the per-tick ``shed_cost``
    the degrade control plane emits; inert on fleets without one."""

    name = "shed_storm"
    severity = "critical"
    unit = "rps"

    def __init__(self, max_shed_rps: float, window_s: float = 3600.0) -> None:
        self.max_shed_rps = float(max_shed_rps)
        self.window_s = float(window_s)
        self._win: List[Tuple[float, float]] = []

    def reset(self) -> None:
        self._win = []

    def observe(self, t: float, dt_s: float,
                tick: Mapping[str, Any]) -> Optional[Tuple[float, float]]:
        shed = tick.get("shed_cost")
        if shed is None:
            return None
        self._win.append((t, float(shed)))
        horizon = t + dt_s - self.window_s
        drop = 0
        for tw, _mass in self._win:
            if tw >= horizon:
                break
            drop += 1
        if drop:
            del self._win[:drop]
        span = len(self._win) * dt_s
        if span <= 0.0:
            return None
        total = 0.0
        for _tw, mass in self._win:
            total += mass
        rate = total / span
        if rate > self.max_shed_rps:
            return rate, self.max_shed_rps
        return None


class _OpenWindow:
    __slots__ = ("t_start", "t_end", "worst", "threshold")

    def __init__(self, t: float, dt_s: float, value: float,
                 threshold: float) -> None:
        self.t_start = t
        self.t_end = t + dt_s
        self.worst = value
        self.threshold = threshold


class SloPolicy:
    """A set of rules evaluated in lockstep, merging violation windows.

    Streaming: call ``on_tick`` per tick, then ``finalize`` to close
    any still-open windows. Post-hoc: ``evaluate(telemetry)`` replays
    a finished run through the same path.
    """

    def __init__(self, rules: Sequence[SloRule]) -> None:
        self.rules = list(rules)
        self._open: Dict[str, _OpenWindow] = {}
        self._alerts: List[Alert] = []

    def reset(self) -> None:
        for rule in self.rules:
            rule.reset()
        self._open = {}
        self._alerts = []

    def _close(self, rule: SloRule, win: _OpenWindow) -> None:
        self._alerts.append(Alert(
            rule=rule.name, severity=rule.severity,
            t_start=win.t_start, t_end=win.t_end,
            worst_value=win.worst, threshold=win.threshold,
            message=(f"{rule.name}: worst {win.worst:.4g} {rule.unit} "
                     f"vs threshold {win.threshold:.4g} {rule.unit} over "
                     f"[{win.t_start:.0f}s, {win.t_end:.0f}s)"),
        ))

    def on_tick(self, t: float, dt_s: float,
                tick: Mapping[str, Any]) -> None:
        """Feed one tick. ``tick`` carries per-rack arrays (power_w,
        queued, throttled_units where available) plus the tick's newly
        completed ``responses``."""
        for rule in self.rules:
            hit = rule.observe(t, dt_s, tick)
            win = self._open.get(rule.name)
            if hit is not None:
                value, threshold = hit
                if win is None:
                    self._open[rule.name] = _OpenWindow(
                        t, dt_s, value, threshold)
                else:
                    win.t_end = t + dt_s
                    win.worst = max(win.worst, value)
            elif win is not None:
                self._close(rule, self._open.pop(rule.name))

    def finalize(self) -> List[Alert]:
        """Close open windows and return every alert, in time order."""
        for rule in self.rules:
            win = self._open.pop(rule.name, None)
            if win is not None:
                self._close(rule, win)
        self._alerts.sort(key=lambda a: (a.t_start, a.rule))
        return list(self._alerts)

    def evaluate(self, tel: Any) -> List[Alert]:
        """Post-hoc: replay a :class:`FleetTelemetry` through the
        streaming path (responses bucketed into their finish tick)."""
        self.reset()
        times = np.asarray(tel.time_s, float)
        ticks = len(times)
        if ticks == 0:
            return []
        dt = float(times[1] - times[0]) if ticks > 1 else 1.0
        # bucket completions by finish tick; clamp strays into range
        buckets: List[List[Any]] = [[] for _ in range(ticks)]
        for rack_tel in tel.per_rack:
            for resp in rack_tel.responses:
                i = int(np.searchsorted(times, resp.finish_s, side="right")) - 1
                buckets[min(max(i, 0), ticks - 1)].append(resp)
        thr_rows: Optional[np.ndarray] = None
        thr_cols = [
            (r, rack_tel.throttled_units)
            for r, rack_tel in enumerate(tel.per_rack)
            if len(rack_tel.throttled_units)
        ]
        if thr_cols:
            thr_rows = np.zeros((ticks, tel.n_racks))
            for r, col in thr_cols:
                thr_rows[:, r] = col
        shed_t = np.asarray(getattr(tel, "shed_cost_t", []), float)
        degrade_on = bool(getattr(tel, "degrade_on", False))
        for i in range(ticks):
            tick: Dict[str, Any] = {
                "power_w": tel.power_w[:, i],
                "queued": tel.queued[:, i],
                "responses": buckets[i],
            }
            if thr_rows is not None:
                tick["throttled_units"] = thr_rows[i]
            if degrade_on and i < len(shed_t):
                tick["shed_cost"] = float(shed_t[i])
            self.on_tick(float(times[i]), dt, tick)
        return self.finalize()
