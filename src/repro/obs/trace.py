"""Request-lifecycle traces as Chrome trace-event JSON (Perfetto).

:class:`TraceRecorder` turns a finished
:class:`~repro.fleet.telemetry.FleetTelemetry` (plus, optionally, a
probe :class:`~repro.obs.probe.MemorySink` history) into the Chrome
``traceEvents`` format: open ``ui.perfetto.dev`` (or
``chrome://tracing``) and load the saved JSON.

Per sampled request, one rack-thread track carries the lifecycle
spans: an outer ``request`` slice (submit → serve done) containing a
``queue`` slice (waiting in the rack's FIFO) and a ``serve`` slice
(the final tick's fluid drain — the fluid model serves a request
within one tick, so the serve span is ``min(dt, latency)`` wide, an
explicitly documented approximation). Routing is an instant event at
submission; hedge fires are instant events on the rack that borrowed
a unit. Per-rack counter tracks (power, queue depth, active units,
throttled dies) ride alongside from the probe history or, where
absent, from the telemetry itself.

Sampling is deterministic — request ``rid % sample_every == 0`` — so
traces are reproducible and reprolint-clean (no RNG).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["TraceConfig", "TraceRecorder", "build_chrome_trace",
           "validate_chrome_trace"]

#: trace-event phases this exporter emits
_PH_META, _PH_COMPLETE, _PH_COUNTER, _PH_INSTANT = "M", "X", "C", "i"


@dataclass
class TraceConfig:
    """Knobs bounding trace size (Perfetto handles ~1e6 events)."""

    sample_every: int = 1          # keep rids where rid % sample_every == 0
    max_spans_per_rack: int = 2000
    counter_stride: int = 1        # emit every Nth tick's counters
    counters: Tuple[str, ...] = ("power_w", "queued", "active_units",
                                 "throttled_units")


@dataclass
class TraceRecorder:
    """Accumulates trace events; ``record_fleet`` ingests one run."""

    config: TraceConfig = field(default_factory=TraceConfig)
    events: List[Dict[str, Any]] = field(default_factory=list)

    def _meta(self, pid: int, tid: int, what: str, name: str) -> None:
        self.events.append({"ph": _PH_META, "pid": pid, "tid": tid,
                            "name": what, "args": {"name": name}})

    def record_fleet(self, tel: Any,
                     probes: Optional[Any] = None) -> None:
        """Ingest one :class:`FleetTelemetry` (and optional
        :class:`MemorySink`) worth of spans, instants, and counters."""
        cfg = self.config
        names = list(tel.rack_names) or [
            f"rack{r}" for r in range(tel.n_racks)]
        self._meta(1, 0, "process_name",
                   f"fleet ({tel.router}, backend={tel.backend})")
        for r, name in enumerate(names):
            self._meta(1, r + 1, "thread_name", name)
        times = np.asarray(tel.time_s, float)
        dt = float(times[1] - times[0]) if len(times) > 1 else 1.0
        # --- request lifecycle spans (deterministic rid sampling) -------
        for r, rack_tel in enumerate(tel.per_rack):
            tid = r + 1
            kept = 0
            for resp in rack_tel.responses:
                if resp.rid % cfg.sample_every:
                    continue
                if kept >= cfg.max_spans_per_rack:
                    break
                kept += 1
                sub_us = resp.arrival_s * 1e6
                fin_us = resp.finish_s * 1e6
                lat_us = max(fin_us - sub_us, 0.0)
                serve_us = min(dt * 1e6, lat_us)
                args = {"rid": resp.rid, "rack": names[r],
                        "latency_s": resp.latency_s}
                self.events.append({
                    "ph": _PH_INSTANT, "name": "route", "cat": "router",
                    "pid": 1, "tid": tid, "ts": sub_us, "s": "t",
                    "args": args})
                self.events.append({
                    "ph": _PH_COMPLETE, "name": "request", "cat": "request",
                    "pid": 1, "tid": tid, "ts": sub_us, "dur": lat_us,
                    "args": args})
                if lat_us > serve_us:
                    self.events.append({
                        "ph": _PH_COMPLETE, "name": "queue", "cat": "queue",
                        "pid": 1, "tid": tid, "ts": sub_us,
                        "dur": lat_us - serve_us, "args": args})
                self.events.append({
                    "ph": _PH_COMPLETE, "name": "serve", "cat": "serve",
                    "pid": 1, "tid": tid, "ts": fin_us - serve_us,
                    "dur": serve_us, "args": args})
        # --- per-rack counter tracks ------------------------------------
        series = self._series(tel, probes)
        for metric, rows in series.items():
            if metric not in cfg.counters:
                continue
            for i in range(0, rows.shape[0], cfg.counter_stride):
                ts_us = float(times[i]) * 1e6 if i < len(times) else 0.0
                for r, name in enumerate(names):
                    v = float(rows[i, r])
                    if not np.isfinite(v):
                        continue
                    self.events.append({
                        "ph": _PH_COUNTER, "name": f"{metric}/{name}",
                        "pid": 1, "ts": ts_us, "args": {metric: v}})
        # --- hedge fires as instants ------------------------------------
        hedge = series.get("hedge_units")
        if hedge is not None:
            ticks_idx, racks_idx = np.nonzero(hedge > 0)
            for i, r in zip(ticks_idx.tolist(), racks_idx.tolist()):
                self.events.append({
                    "ph": _PH_INSTANT, "name": "hedge_fire", "cat": "hedge",
                    "pid": 1, "tid": r + 1,
                    "ts": float(times[i]) * 1e6, "s": "t",
                    "args": {"rack": names[r],
                             "borrowed": int(hedge[i, r])}})
        # --- chaos fault windows as instants ----------------------------
        # one instant at each event's start (and, for bounded windows,
        # one at its end) on the afflicted rack's track, so fault
        # injection lines up visually with the latency/power response
        for rec in getattr(tel, "chaos_events", []) or []:
            r = int(rec.get("rack", 0))
            if not 0 <= r < len(names):
                continue
            kind = str(rec.get("kind", "fault"))
            args = {"rack": names[r], **rec}
            if not np.isfinite(args.get("end_s", 0.0)):
                args["end_s"] = None  # open-ended fault, keep strict JSON
            self.events.append({
                "ph": _PH_INSTANT, "name": f"chaos_{kind}", "cat": "chaos",
                "pid": 1, "tid": r + 1,
                "ts": float(rec.get("start_s", 0.0)) * 1e6, "s": "t",
                "args": args})
            end_s = float(rec.get("end_s", np.inf))
            if np.isfinite(end_s):
                self.events.append({
                    "ph": _PH_INSTANT, "name": f"chaos_{kind}_clear",
                    "cat": "chaos", "pid": 1, "tid": r + 1,
                    "ts": end_s * 1e6, "s": "t", "args": args})
        # --- circuit-breaker transitions as instants --------------------
        # the degrade control plane derives these from the breaker state
        # matrix (one shared code path for every backend); plotting them
        # on the rack's track shows open/half/close lining up with the
        # queue-delay and chaos signals that caused them
        state_names = {0: "closed", 1: "open", 2: "half_open"}
        for ev in getattr(tel, "breaker_events", []) or []:
            rack = str(ev.get("rack", ""))
            try:
                tid = names.index(rack) + 1
            except ValueError:
                continue
            state = state_names.get(int(ev.get("state", 0)), "unknown")
            self.events.append({
                "ph": _PH_INSTANT, "name": f"breaker_{state}",
                "cat": "degrade", "pid": 1, "tid": tid,
                "ts": float(ev.get("t_s", 0.0)) * 1e6, "s": "t",
                "args": {"rack": rack,
                         "state": state,
                         "prev": state_names.get(
                             int(ev.get("prev", 0)), "unknown")}})

    @staticmethod
    def _series(tel: Any, probes: Optional[Any]) -> Dict[str, np.ndarray]:
        """(ticks, racks) series: probe history when available, the
        telemetry's own arrays otherwise."""
        if probes is not None and getattr(probes, "n_ticks", 0):
            return dict(probes.history())
        out = {
            "power_w": np.asarray(tel.power_w, float).T,
            "queued": np.asarray(tel.queued, float).T,
            "active_units": np.asarray(tel.active_units, float).T,
        }
        ticks = out["power_w"].shape[0]
        thr = np.full((ticks, tel.n_racks), np.nan)
        any_thr = False
        for r, rack_tel in enumerate(tel.per_rack):
            if len(rack_tel.throttled_units):
                thr[:, r] = rack_tel.throttled_units
                any_thr = True
        if any_thr:
            out["throttled_units"] = thr
        return out

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome_trace(), fh)


def build_chrome_trace(tel: Any, probes: Optional[Any] = None,
                       config: Optional[TraceConfig] = None
                       ) -> Dict[str, Any]:
    """One-shot: telemetry (+ optional probe history) → chrome trace."""
    rec = TraceRecorder(config=config or TraceConfig())
    rec.record_fleet(tel, probes)
    return rec.to_chrome_trace()


def validate_chrome_trace(trace: Mapping[str, Any]) -> List[str]:
    """Schema check against the trace-event format; returns a list of
    violations (empty = valid). Covers what Perfetto's importer
    requires: the ``traceEvents`` array, per-event ``ph``/``pid``, a
    numeric ``ts`` on timed events, ``dur >= 0`` on complete events,
    and JSON-serializability of the whole document."""
    errors: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents must be a list"]
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as exc:
        errors.append(f"not JSON-serializable: {exc}")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in (_PH_META, _PH_COMPLETE, _PH_COUNTER, _PH_INSTANT,
                      "B", "E", "b", "e", "n", "s", "t", "f"):
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        if "pid" not in ev:
            errors.append(f"event {i}: missing pid")
        if "name" not in ev:
            errors.append(f"event {i}: missing name")
        if ph != _PH_META:
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)) or not np.isfinite(ts):
                errors.append(f"event {i}: bad ts {ts!r}")
        if ph == _PH_COMPLETE:
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or not dur >= 0.0:
                errors.append(f"event {i}: complete event needs dur >= 0")
        if ph == _PH_COUNTER and not isinstance(ev.get("args"), dict):
            errors.append(f"event {i}: counter event needs args")
        if ph == _PH_INSTANT and ev.get("s", "t") not in ("g", "p", "t"):
            errors.append(f"event {i}: bad instant scope {ev.get('s')!r}")
    return errors
