"""Exporters: JSONL metric streams, Prometheus text exposition, chrome
traces, and attribution tables.

These writers format *finished* observability state — they are not on
any engine hot path and are deliberately exempt from the reprolint
parity gate (see ``tools/reprolint/config.py``): they never compute
new telemetry, only serialize what the probes/ledger recorded.
:mod:`repro.obs.attribution` stays parity-critical; this module does
not.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Mapping, Optional

import numpy as np

from repro.obs.attribution import EnergyLedger
from repro.obs.probe import MemorySink
from repro.obs.slo import Alert

__all__ = ["metric_records", "write_jsonl", "write_metrics_jsonl",
           "prometheus_text", "write_prometheus", "write_chrome_trace",
           "write_attribution_json"]

_PROM_PREFIX = "repro_fleet"


def metric_records(sink: MemorySink) -> Iterable[Dict[str, Any]]:
    """One JSON-able record per (tick, metric): ``{t, dt_s, metric,
    values: {rack: value}}``."""
    if not sink.n_ticks:
        return
    times = sink.times()
    dts = sink.dts()
    names = sink.rack_names
    hist = sink.history()
    for i in range(sink.n_ticks):
        for metric, rows in hist.items():
            vals = {
                names[r] if r < len(names) else f"rack{r}": _scalar(rows[i, r])
                for r in range(rows.shape[1])
            }
            yield {"t": float(times[i]), "dt_s": float(dts[i]),
                   "metric": metric, "values": vals}


def _scalar(v: Any) -> Any:
    """numpy scalar → plain python (NaN → None for strict JSON)."""
    f = float(v)
    if np.isnan(f):
        return None
    if float(f).is_integer() and isinstance(v, (np.integer, int)):
        return int(v)
    return f


def write_jsonl(path: str, records: Iterable[Mapping[str, Any]]) -> int:
    """Write records as JSON Lines; returns the number written."""
    n = 0
    with open(path, "w") as fh:
        for rec in records:
            fh.write(json.dumps(rec))
            fh.write("\n")
            n += 1
    return n


def write_metrics_jsonl(path: str, sink: MemorySink) -> int:
    return write_jsonl(path, metric_records(sink))


def prometheus_text(sink: MemorySink,
                    alerts: Optional[List[Alert]] = None) -> str:
    """Prometheus text exposition (v0.0.4) of the *latest* tick's
    gauges, one time series per rack, plus alert counts per rule."""
    lines: List[str] = []
    names = sink.rack_names
    for metric, row in sorted(sink.last().items()):
        prom = f"{_PROM_PREFIX}_{metric}"
        lines.append(f"# HELP {prom} per-rack fleet probe gauge")
        lines.append(f"# TYPE {prom} gauge")
        for r in range(len(row)):
            v = _scalar(row[r])
            if v is None:
                continue
            rack = names[r] if r < len(names) else f"rack{r}"
            lines.append(f'{prom}{{rack="{rack}"}} {v}')
    if alerts is not None:
        prom = f"{_PROM_PREFIX}_slo_alerts_total"
        lines.append(f"# HELP {prom} SLO alert windows per rule")
        lines.append(f"# TYPE {prom} counter")
        counts: Dict[str, int] = {}
        for alert in alerts:
            counts[alert.rule] = counts.get(alert.rule, 0) + 1
        for rule, cnt in sorted(counts.items()):
            lines.append(f'{prom}{{rule="{rule}"}} {cnt}')
    return "\n".join(lines) + "\n"


def write_prometheus(path: str, sink: MemorySink,
                     alerts: Optional[List[Alert]] = None) -> None:
    with open(path, "w") as fh:
        fh.write(prometheus_text(sink, alerts))


def write_chrome_trace(path: str, trace: Mapping[str, Any]) -> None:
    with open(path, "w") as fh:
        json.dump(trace, fh)


def write_attribution_json(path: str, ledger: EnergyLedger) -> None:
    """The full rack x tenant x cause breakdown plus replay totals."""
    doc = {
        "total_energy_j": ledger.total_energy_j(),
        "tolerance": ledger.tolerance,
        "by_cause": ledger.by_cause(),
        "by_tenant": ledger.by_tenant(),
        "rack_energy_j": ledger.rack_energy_j(),
        "records": ledger.to_records(),
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2)
