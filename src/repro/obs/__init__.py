"""``repro.obs`` — fleet observability: probes, traces, attribution, SLOs.

The paper's claims are observability claims (power tracking load,
energy per request, tail latency under diurnal/flash-crowd traffic);
this package makes them watchable *during* a run instead of only in
post-hoc roll-ups:

  * :mod:`~repro.obs.probe` — zero-cost-when-off per-tick fleet
    metric streaming (power, queues, activation, OPPs, thermals);
  * :mod:`~repro.obs.trace` — sampled request-lifecycle spans and
    per-rack counter tracks as Chrome trace-event JSON (Perfetto);
  * :mod:`~repro.obs.attribution` — an exact energy ledger whose
    per-cause components replay **bitwise** to the pools' / vector
    engine's ``energy_j`` (jax: within the engine's documented
    tolerance) — the repo's parity contract, extended to the
    observability surface;
  * :mod:`~repro.obs.slo` — burn-rate alert rules (rolling p95,
    energy budget, throttle storms, queue blow-up), streaming or
    post-hoc, surfaced on ``FleetTelemetry.alerts``;
  * :mod:`~repro.obs.export` / :mod:`~repro.obs.report` — JSONL,
    Prometheus text, chrome-trace writers and the
    ``python -m repro.obs.report`` markdown/HTML run report.

Wire-up: build a :class:`FleetObs` and pass it to ``Fleet(obs=...)``.
All three engines emit into it — scalar and vector per tick, the jax
engine by expanding its scanned telemetry rows host-side after
``lax.scan`` (the jitted hot path stays pure).

    from repro.obs import (FleetObs, ProbeRegistry, MemorySink,
                           EnergyLedger, SloPolicy, LatencyBurnRule)

    sink = MemorySink()
    obs = FleetObs(probes=ProbeRegistry([sink]),
                   ledger=EnergyLedger(),
                   slo=SloPolicy([LatencyBurnRule(target_s=120.0)]))
    fleet = Fleet(racks, backend="vector", obs=obs)
    tel = fleet.play_trace(trace)
    assert obs.ledger.total_energy_j() == tel.energy_j   # bitwise
    tel.alerts                                           # SLO windows
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.obs.attribution import CAUSES, EnergyLedger
from repro.obs.probe import (PROBE_METRICS, CallbackSink, MemorySink,
                             MetricSink, ProbeRegistry)
from repro.obs.slo import (Alert, EnergyBudgetRule, LatencyBurnRule,
                           QueueBlowupRule, ShedStormRule, SloPolicy,
                           SloRule, ThrottleStormRule)
from repro.obs.trace import (TraceConfig, TraceRecorder, build_chrome_trace,
                             validate_chrome_trace)

__all__ = [
    "FleetObs",
    # probes
    "PROBE_METRICS", "MetricSink", "MemorySink", "CallbackSink",
    "ProbeRegistry",
    # attribution
    "EnergyLedger", "CAUSES",
    # slo
    "Alert", "SloRule", "SloPolicy", "LatencyBurnRule", "EnergyBudgetRule",
    "ThrottleStormRule", "QueueBlowupRule", "ShedStormRule",
    # traces
    "TraceConfig", "TraceRecorder", "build_chrome_trace",
    "validate_chrome_trace",
]


@dataclass
class FleetObs:
    """Observability configuration handed to ``Fleet(obs=...)``.

    Every field is optional; engines pay one ``is None`` check per
    tick for whatever is absent. ``probes`` and ``ledger`` are fed by
    the engines during the run; ``slo`` is evaluated post-hoc on every
    telemetry build (alerts land on ``FleetTelemetry.alerts``);
    ``tracer`` is *not* auto-fed (a recorder accumulates events, and
    ``play_trace`` may be called repeatedly on the same fleet) — build
    traces post-hoc with ``tracer.record_fleet(tel, sink)`` or
    :func:`~repro.obs.trace.build_chrome_trace`.
    """

    probes: Optional[ProbeRegistry] = None
    ledger: Optional[EnergyLedger] = None
    slo: Optional[SloPolicy] = None
    tracer: Optional[TraceRecorder] = None
