"""Shared layers: initializers, RMSNorm, RoPE, SwiGLU MLP, embeddings.

Functional style: every module is an ``init(rng, ...) -> params`` plus an
``apply(params, x, ...)``, with a parallel ``specs(...)`` returning the
logical sharding names for each param leaf (consumed by
``distributed.sharding``).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels import ops

Params = Dict[str, Any]


def dense_init(rng, shape, dtype, scale: float = 0.02) -> jax.Array:
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_specs() -> Params:
    return {"scale": (None,)}


def rmsnorm_apply(params: Params, x: jax.Array, eps: float,
                  lowp: bool = False) -> jax.Array:
    return ops.rmsnorm(x, params["scale"], eps, lowp=lowp)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------
def rope_table(positions: jax.Array, head_dim: int, theta: float
               ) -> Tuple[jax.Array, jax.Array]:
    """positions: (s,) int -> (sin, cos) each (s, head_dim//2) fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.sin(ang), jnp.cos(ang)


def rope_apply(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (b, s, h, d); sin/cos: (s, d//2) or per-batch (b, s, d//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (dense FFN).
# ---------------------------------------------------------------------------
def mlp_init(rng, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(k1, (d, f), dtype),
        "w_up": dense_init(k2, (d, f), dtype),
        "w_down": dense_init(k3, (f, d), dtype),
    }


def mlp_specs() -> Params:
    return {
        "w_gate": ("p_embed", "p_mlp"),
        "w_up": ("p_embed", "p_mlp"),
        "w_down": ("p_mlp", "p_embed"),
    }


def mlp_apply(params: Params, x: jax.Array, lowp: bool = False) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if lowp:
        h = jax.nn.silu(g) * u
    else:
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)) * u
    h = shard(h, ("batch", "seq", "mlp_act"))
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# ---------------------------------------------------------------------------
# Token embedding / unembedding.
# ---------------------------------------------------------------------------
def embed_init(rng, vocab: int, d: int, dtype, tie: bool) -> Params:
    k1, k2 = jax.random.split(rng)
    p = {"embedding": dense_init(k1, (vocab, d), dtype)}
    if not tie:
        p["unembed"] = dense_init(k2, (d, vocab), dtype)
    return p


def embed_specs(tie: bool) -> Params:
    p = {"embedding": ("p_vocab", "p_embed")}
    if not tie:
        p["unembed"] = ("p_embed", "p_vocab")
    return p


def embed_apply(params: Params, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embedding"], tokens, axis=0)
    return shard(x, ("batch", "seq", "embed_act"))


def unembed_apply(params: Params, x: jax.Array) -> jax.Array:
    if "unembed" in params:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embedding"])
    return shard(logits, ("batch", "seq", "vocab_act"))
