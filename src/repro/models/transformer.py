"""Decoder stack builder.

Hybrid stacks (attn/mamba interleave, MoE alternation) are handled by
finding the smallest *block period* ``p`` such that the per-layer signature
``(mixer_kind, ffn_kind)`` repeats with period ``p``; parameters are stacked
over ``num_layers // p`` repeats and the stack runs as one ``lax.scan`` over
blocks of ``p`` explicitly-traced layers. This keeps compile time flat in
depth (one trace per distinct layer signature) for the 40-cell dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ATTN, ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (mlp_apply, mlp_init, mlp_specs,
                                 rmsnorm_apply, rmsnorm_init, rmsnorm_specs)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Layer signatures and the block period.
# ---------------------------------------------------------------------------
def layer_signature(cfg: ModelConfig, i: int) -> Tuple[str, str]:
    kind = cfg.layer_kinds()[i]
    if cfg.is_moe_layer(i):
        ffn = "moe"
    elif cfg.d_ff:
        ffn = "dense"
    else:
        ffn = "none"
    return (kind, ffn)


def block_period(cfg: ModelConfig) -> int:
    sigs = [layer_signature(cfg, i) for i in range(cfg.num_layers)]
    for p in range(1, cfg.num_layers + 1):
        if cfg.num_layers % p:
            continue
        if all(sigs[i] == sigs[i % p] for i in range(cfg.num_layers)):
            return p
    return cfg.num_layers


# ---------------------------------------------------------------------------
# One layer.
# ---------------------------------------------------------------------------
def layer_init(rng, cfg: ModelConfig, i: int) -> Params:
    kind, ffn = layer_signature(cfg, i)
    k1, k2 = jax.random.split(rng)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model)}
    if kind == ATTN:
        p["mixer"] = attn_mod.attn_init(k1, cfg)
    else:
        p["mixer"] = mamba_mod.mamba_init(k1, cfg)
    if ffn != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = (moe_mod.moe_init(k2, cfg) if ffn == "moe"
                    else mlp_init(k2, cfg.d_model, cfg.d_ff,
                                  jnp.dtype(cfg.dtype)))
    return p


def layer_specs(cfg: ModelConfig, i: int) -> Params:
    kind, ffn = layer_signature(cfg, i)
    p: Params = {"norm1": rmsnorm_specs()}
    p["mixer"] = (attn_mod.attn_specs(cfg) if kind == ATTN
                  else mamba_mod.mamba_specs(cfg))
    if ffn != "none":
        p["norm2"] = rmsnorm_specs()
        p["ffn"] = moe_mod.moe_specs(cfg) if ffn == "moe" else mlp_specs()
    return p


def layer_apply(params: Params, cfg: ModelConfig, i_sig: Tuple[str, str],
                x: jax.Array, *, mode: str, cache: Optional[Params],
                pos, max_len: Optional[int] = None
                ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    kind, ffn = i_sig
    h = rmsnorm_apply(params["norm1"], x, cfg.norm_eps,
                      lowp=cfg.mlp_lowp)
    if kind == ATTN:
        mix, new_cache = attn_mod.attn_apply(
            params["mixer"], cfg, h, mode=mode, cache=cache, pos=pos,
            max_len=max_len)
    else:
        mix, new_cache = mamba_mod.mamba_apply(
            params["mixer"], cfg, h, mode=mode, cache=cache)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = rmsnorm_apply(params["norm2"], x, cfg.norm_eps,
                          lowp=cfg.mlp_lowp)
        if ffn == "moe":
            f, aux = moe_mod.moe_apply(params["ffn"], cfg, h)
        else:
            f = mlp_apply(params["ffn"], h, lowp=cfg.mlp_lowp)
        x = x + f
    x = shard(x, ("batch", "seq", "embed_act"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Cache construction (per layer position; stacked over blocks).
# ---------------------------------------------------------------------------
def init_layer_cache(cfg: ModelConfig, i: int, batch: int, max_len: int,
                     dtype) -> Optional[Params]:
    kind, _ = layer_signature(cfg, i)
    if kind == ATTN:
        return attn_mod.init_cache(cfg, batch, max_len, dtype)
    return mamba_mod.init_mamba_cache(cfg, batch, dtype)


def layer_cache_specs(cfg: ModelConfig, i: int) -> Optional[Params]:
    kind, _ = layer_signature(cfg, i)
    if kind == ATTN:
        return attn_mod.cache_specs()
    return mamba_mod.mamba_cache_specs()


# ---------------------------------------------------------------------------
# Stack: init + apply.
# ---------------------------------------------------------------------------
def stack_init(rng, cfg: ModelConfig) -> List[Params]:
    """Returns a list of per-position param trees, each stacked over the
    block repeats (leading dim num_layers // period)."""
    p = block_period(cfg)
    nb = cfg.num_layers // p
    rngs = jax.random.split(rng, cfg.num_layers)
    per_layer = [layer_init(rngs[i], cfg, i) for i in range(cfg.num_layers)]
    stacked = []
    for j in range(p):
        group = [per_layer[i] for i in range(j, cfg.num_layers, p)]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *group))
    return stacked


def stack_specs(cfg: ModelConfig) -> List[Params]:
    p = block_period(cfg)
    out = []
    for j in range(p):
        spec = layer_specs(cfg, j)
        out.append(jax.tree.map(
            lambda t: (None, *t), spec,
            is_leaf=lambda t: isinstance(t, tuple)))
    return out


def stack_caches(cfg: ModelConfig, batch: int, max_len: int, dtype
                 ) -> List[Optional[Params]]:
    p = block_period(cfg)
    nb = cfg.num_layers // p
    out = []
    for j in range(p):
        c = init_layer_cache(cfg, j, batch, max_len, dtype)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (nb, *x.shape)), c))
    return out


def stack_cache_specs(cfg: ModelConfig) -> List[Optional[Params]]:
    p = block_period(cfg)
    out = []
    for j in range(p):
        spec = layer_cache_specs(cfg, j)
        out.append(jax.tree.map(
            lambda t: (None, *t), spec,
            is_leaf=lambda t: isinstance(t, tuple)))
    return out


def stack_apply(blocks: List[Params], cfg: ModelConfig, x: jax.Array, *,
                mode: str, caches: Optional[List[Params]] = None,
                pos=None, scan: bool = True, remat: str = "none",
                max_len: Optional[int] = None
                ) -> Tuple[jax.Array, Optional[List[Params]], jax.Array]:
    """Run all layers. Returns (x, new_caches, aux_loss_sum)."""
    p = block_period(cfg)
    nb = cfg.num_layers // p
    sigs = [layer_signature(cfg, j) for j in range(p)]

    def block_fn(x, block_params, block_caches, pos):
        new_caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for j in range(p):
            cache_j = None if block_caches is None else block_caches[j]
            x, nc, aux = layer_apply(
                block_params[j], cfg, sigs[j], x,
                mode=mode, cache=cache_j, pos=pos, max_len=max_len)
            new_caches.append(nc)
            aux_total = aux_total + aux
        return x, new_caches, aux_total

    fn = block_fn
    if remat == "full":
        fn = jax.checkpoint(block_fn, static_argnums=())
    elif remat == "dots":
        fn = jax.checkpoint(
            block_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    needs_cache = mode in ("prefill", "decode")
    if scan and nb > 1:
        def body(carry, xs):
            x, aux = carry
            bp, bc = xs
            x, ncs, a = fn(x, bp, bc, pos)
            return (x, aux + a), ncs

        xs = (blocks, caches if caches is not None else [None] * p)
        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs)
        out_caches = new_caches if needs_cache else None
        return x, out_caches, aux
    else:
        # Unrolled path: index the stacked leaves per repeat.
        aux = jnp.zeros((), jnp.float32)
        new_stack = [[] for _ in range(p)] if needs_cache else None
        for r in range(nb):
            bp = jax.tree.map(lambda a: a[r], blocks)
            bc = (None if caches is None
                  else jax.tree.map(lambda a: a[r], caches))
            x, ncs, a = fn(x, bp, bc, pos)
            aux = aux + a
            if needs_cache:
                for j in range(p):
                    new_stack[j].append(ncs[j])
        out_caches = None
        if needs_cache:
            out_caches = [
                jax.tree.map(lambda *xs: jnp.stack(xs), *new_stack[j])
                for j in range(p)
            ]
        return x, out_caches, aux
