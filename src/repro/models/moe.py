"""Top-k Mixture-of-Experts with capacity-bounded scatter dispatch.

TPU-native formulation (GShard-style, grouped): tokens are grouped by their
data shard, positions inside each expert's capacity buffer are computed with
a group-local cumulative sum (no cross-shard prefix), tokens are
scatter-added into an (experts x capacity) buffer (the GSPMD lowering of the
sharded scatter is the MoE all-to-all), experts run as one grouped einsum,
and results gather back weighted by the router's combine weights.

Expert weights are expert-sharded over the ``model`` axis (EP) and
fsdp-sharded over ``data`` on the hidden dim.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.distributed.sharding import active_mesh, shard
from repro.models.layers import dense_init

Params = Dict[str, Any]


def moe_init(rng, cfg: ModelConfig) -> Params:
    moe = cfg.moe
    assert moe is not None
    d, f, e = cfg.d_model, moe.d_ff_expert, moe.num_experts
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), dtype),
        "w_up": dense_init(ks[2], (e, d, f), dtype),
        "w_down": dense_init(ks[3], (e, f, d), dtype),
    }


def moe_specs(cfg: ModelConfig) -> Params:
    return {
        "router": ("p_embed", None),
        "w_gate": ("p_expert", "p_ff_fsdp", None),
        "w_up": ("p_expert", "p_ff_fsdp", None),
        "w_down": ("p_expert", None, "p_ff_fsdp"),
    }


def _num_groups() -> int:
    """Token groups = number of data-parallel shards (1 without a mesh)."""
    mesh = active_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    g = sizes.get("data", 1) * sizes.get("pod", 1)
    return g


def expert_capacity(tokens_per_group: int, moe: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * moe.top_k * moe.capacity_factor
                  / moe.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(params: Params, cfg: ModelConfig, x: jax.Array, *,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (out, aux_loss)."""
    moe = cfg.moe
    assert moe is not None
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    tokens = b * s
    groups = _num_groups()
    if tokens % groups != 0:
        groups = 1
    tpg = tokens // groups
    cap = expert_capacity(tpg, moe)

    xg = x.reshape(groups, tpg, d)
    xg = shard(xg, ("batch", None, "embed_act"))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])
    if moe.router_jitter and rng is not None:
        logits = logits + moe.router_jitter * jax.random.normal(
            rng, logits.shape, jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (g, t, e)
    top_p, top_i = jax.lax.top_k(probs, k)                      # (g, t, k)
    denom = jnp.sum(top_p, axis=-1, keepdims=True)
    combine = top_p / jnp.maximum(denom, 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=1)                                # (g, e)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=2),
        axis=1) / k                                             # (g, e)
    aux = moe.aux_loss_weight * e * jnp.mean(jnp.sum(me * ce, axis=-1))

    # ---- dispatch: k-major position assignment under capacity ----
    v2 = moe.dispatch == "v2"
    if v2:
        # drop-mode scatter straight into the expert-flat buffer: indices
        # >= e*cap fall off the end (no overflow row), so the buffer's row
        # dim is exactly e*cap and shards cleanly over the model axis.
        buf = jnp.zeros((groups, e * cap, d), x.dtype)
        buf = shard(buf, ("batch", "expert_flat", "embed_act"))
    else:
        buf = jnp.zeros((groups, e * cap + 1, d), x.dtype)
    counts = jnp.zeros((groups, e), jnp.int32)
    dests = []
    keeps = []
    g_iota = jnp.arange(groups)[:, None]
    for kk in range(k):
        idx = top_i[:, :, kk]                                   # (g, t)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)        # (g, t, e)
        within = jnp.cumsum(onehot, axis=1) - onehot            # exclusive
        pos = jnp.take_along_axis(
            within + counts[:, None, :], idx[..., None], axis=-1)[..., 0]
        keep = pos < cap
        dest = jnp.where(keep, idx * cap + pos, e * cap)        # (g, t)
        buf = buf.at[g_iota, dest].add(
            jnp.where(keep[..., None], xg, 0), mode="drop",
            indices_are_sorted=False, unique_indices=False)
        counts = counts + jnp.sum(onehot, axis=1)
        dests.append(dest)
        keeps.append(keep)

    xb = (buf if v2 else buf[:, : e * cap]).reshape(groups, e, cap, d)
    xb = shard(xb, ("batch", "expert_act", None, "embed_act"))

    # ---- grouped expert SwiGLU ----
    g_h = jnp.einsum("gecd,edf->gecf", xb, params["w_gate"])
    u_h = jnp.einsum("gecd,edf->gecf", xb, params["w_up"])
    if cfg.mlp_lowp:
        h = jax.nn.silu(g_h) * u_h
    else:
        h = jax.nn.silu(g_h.astype(jnp.float32)).astype(x.dtype) * u_h
    h = shard(h, ("batch", "expert_act", None, None))
    yb = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    yb = shard(yb, ("batch", "expert_act", None, "embed_act"))

    # ---- combine ----
    y_flat = yb.reshape(groups, e * cap, d)
    if v2:
        y_flat = shard(y_flat, ("batch", "expert_flat", "embed_act"))
    else:
        y_flat = jnp.concatenate(
            [y_flat, jnp.zeros((groups, 1, d), y_flat.dtype)], axis=1)
    out = jnp.zeros_like(xg)
    for kk in range(k):
        if v2:
            # fill-mode take: dropped slots (dest == e*cap) read as zero.
            y_k = jax.vmap(lambda rows, ix: jnp.take(
                rows, ix, axis=0, mode="fill", fill_value=0))(
                    y_flat, dests[kk])
        else:
            y_k = y_flat[g_iota, dests[kk]]                     # (g, t, d)
        w_k = (combine[:, :, kk] * keeps[kk]).astype(x.dtype)
        out = out + y_k * w_k[..., None]
    out = out.reshape(b, s, d)
    return shard(out, ("batch", "seq", "embed_act")), aux
