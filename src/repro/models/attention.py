"""GQA multi-head attention with RoPE and a decode KV cache.

Modes:
  * train   — full causal self-attention (no cache)
  * prefill — causal self-attention that also emits the KV cache laid out
              in the decode sharding (``kv_seq`` sequence-sharded)
  * decode  — one new token appended at ``pos`` against the cache
              (flash-decode partial-softmax combine under GSPMD)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.models.layers import dense_init, rope_apply, rope_table

Params = Dict[str, Any]


def attn_init(rng, cfg: ModelConfig) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, hq, hd), dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), dtype),
        "wo": dense_init(ks[3], (hq, hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    return p


def attn_specs(cfg: ModelConfig) -> Params:
    p: Params = {
        "wq": ("p_embed", "p_heads", "p_head_dim"),
        "wk": ("p_embed", "p_kv_heads", "p_head_dim"),
        "wv": ("p_embed", "p_kv_heads", "p_head_dim"),
        "wo": ("p_heads", "p_head_dim", "p_embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("p_heads", "p_head_dim")
        p["bk"] = ("p_kv_heads", "p_head_dim")
        p["bv"] = ("p_kv_heads", "p_head_dim")
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((batch, max_len, hkv, hd), dtype),
    }


def cache_specs() -> Params:
    return {
        "k": ("batch", "kv_seq", "kv_heads_act", "head_dim_act"),
        "v": ("batch", "kv_seq", "kv_heads_act", "head_dim_act"),
    }


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"][None, None]
        k = k + params["bk"][None, None]
        v = v + params["bv"][None, None]
    q = shard(q, ("batch", "seq", "heads_act", None))
    k = shard(k, ("batch", "seq", "kv_heads_act", None))
    v = shard(v, ("batch", "seq", "kv_heads_act", None))
    return q, k, v


def attn_apply(params: Params, cfg: ModelConfig, x: jax.Array, *,
               mode: str, cache: Optional[Params] = None,
               pos: Optional[jax.Array] = None,
               max_len: Optional[int] = None
               ) -> Tuple[jax.Array, Optional[Params]]:
    """x: (b, s, d). Returns (out, new_cache)."""
    b, s, d = x.shape
    if mode in ("train", "prefill"):
        positions = jnp.arange(s)
        sin, cos = rope_table(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q, k, v = _project_qkv(params, cfg, x)
        q = rope_apply(q, sin, cos)
        k = rope_apply(k, sin, cos)
        out = ops.attention(q, k, v, causal=True, impl=cfg.attn_impl,
                            chunk=cfg.attn_chunk)
        new_cache = None
        if mode == "prefill":
            kc, vc = k, v
            if max_len is not None and max_len > s:
                pad = ((0, 0), (0, max_len - s), (0, 0), (0, 0))
                kc, vc = jnp.pad(kc, pad), jnp.pad(vc, pad)
            new_cache = {
                "k": shard(kc, ("batch", "kv_seq", "kv_heads_act", None)),
                "v": shard(vc, ("batch", "kv_seq", "kv_heads_act", None)),
            }
    else:  # decode
        assert cache is not None and pos is not None
        pos_arr = jnp.asarray(pos)
        per_slot = pos_arr.ndim == 1          # (b,) slot positions
        q, k, v = _project_qkv(params, cfg, x)              # s == 1
        cdt = cache["k"].dtype   # cache may be lower-precision (fp8 lever)
        if per_slot:
            # Per-batch RoPE phases (continuous batching: every slot is at
            # its own sequence position).
            sin, cos = rope_table(pos_arr, cfg.resolved_head_dim,
                                  cfg.rope_theta)           # (b, d/2)
            sin, cos = sin[:, None], cos[:, None]           # (b, 1, d/2)
            q = rope_apply(q, sin, cos)
            k = rope_apply(k, sin, cos)
            bidx = jnp.arange(b)
            k_cache = cache["k"].at[bidx, pos_arr].set(k[:, 0].astype(cdt))
            v_cache = cache["v"].at[bidx, pos_arr].set(v[:, 0].astype(cdt))
            length = pos_arr.astype(jnp.int32) + 1
        else:
            positions = pos_arr.reshape(1)
            sin, cos = rope_table(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta)
            q = rope_apply(q, sin, cos)
            k = rope_apply(k, sin, cos)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cdt), pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cdt), pos, axis=1)
            length = jnp.full((b,), pos_arr + 1, jnp.int32)
        k_cache = shard(k_cache, ("batch", "kv_seq", "kv_heads_act", None))
        v_cache = shard(v_cache, ("batch", "kv_seq", "kv_heads_act", None))
        out1 = ops.decode_attention(q[:, 0], k_cache, v_cache, length,
                                    impl=cfg.attn_impl)
        out = out1[:, None]
        new_cache = {"k": k_cache, "v": v_cache}
    out = shard(out, ("batch", "seq", "heads_act", None))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache
