"""The LM wrapper: init / specs / forward / loss / prefill / decode.

``batch`` dict convention (produced by the data pipeline / input_specs):
  tokens : (b, s) int32
  labels : (b, s) int32       (next-token targets, already aligned)
  mask   : (b, s) float32     (1 where the loss counts)
  vision_embeds : (b, ft, d)  (optional; VLM/audio frontend stubs)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import shard
from repro.models import transformer as stack
from repro.models.layers import (embed_apply, embed_init, embed_specs,
                                 rmsnorm_apply, rmsnorm_init, rmsnorm_specs,
                                 unembed_apply)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Params.
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, rng: jax.Array) -> Params:
    k_embed, k_stack = jax.random.split(rng)
    return {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                            jnp.dtype(cfg.dtype), cfg.tie_embeddings),
        "blocks": stack.stack_init(k_stack, cfg),
        "final_norm": rmsnorm_init(cfg.d_model),
    }


def param_specs(cfg: ModelConfig) -> Params:
    return {
        "embed": embed_specs(cfg.tie_embeddings),
        "blocks": stack.stack_specs(cfg),
        "final_norm": rmsnorm_specs(),
    }


def param_shapes(cfg: ModelConfig) -> Params:
    """Abstract (ShapeDtypeStruct) params without allocation."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------
def _embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, Any]
                  ) -> jax.Array:
    x = embed_apply(params["embed"], batch["tokens"])
    if "vision_embeds" in batch and batch["vision_embeds"] is not None:
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([ve, x], axis=1)
        x = shard(x, ("batch", "seq", "embed_act"))
    return x


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Any], *,
            mode: str = "train", caches: Optional[List[Params]] = None,
            pos=None, scan: bool = True, remat: str = "none",
            max_len: Optional[int] = None
            ) -> Tuple[jax.Array, Optional[List[Params]], jax.Array]:
    """Returns (logits, new_caches, aux_loss)."""
    x = _embed_inputs(params, cfg, batch)
    x, new_caches, aux = stack.stack_apply(
        params["blocks"], cfg, x, mode=mode, caches=caches, pos=pos,
        scan=scan, remat=remat, max_len=max_len)
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps,
                      lowp=cfg.mlp_lowp)
    logits = unembed_apply(params["embed"] if cfg.tie_embeddings
                           else {**params["embed"]}, x)
    return logits, new_caches, aux


def _ce_terms(logits_f32: jax.Array, labels: jax.Array, mask: jax.Array):
    lse = jax.scipy.special.logsumexp(logits_f32, axis=-1)
    picked = jnp.take_along_axis(logits_f32, labels[..., None],
                                 axis=-1)[..., 0]
    nll = (lse - picked) * mask
    return jnp.sum(nll), jnp.sum((lse * mask) ** 2)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Any], *,
            scan: bool = True, remat: str = "none",
            z_loss: float = 1e-4, loss_chunk: int = 0
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    labels = batch["labels"]
    mask = batch["mask"].astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)

    if loss_chunk:
        # Chunked CE: run the trunk once, then compute logits + logsumexp
        # per sequence chunk under remat so the (b, s, vocab) fp32 logits
        # tensor never materializes (beyond-paper memory lever; decisive
        # for vocab-202k llama4).
        x = _embed_inputs(params, cfg, batch)
        x, _, aux = stack.stack_apply(
            params["blocks"], cfg, x, mode="train", scan=scan, remat=remat)
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps,
                      lowp=cfg.mlp_lowp)
        ft = x.shape[1] - labels.shape[1]
        if ft:
            x = x[:, ft:]
        s = labels.shape[1]
        chunk = min(loss_chunk, s)
        pad = (-s) % chunk
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        nc = x.shape[1] // chunk
        xs = x.reshape(x.shape[0], nc, chunk, -1).swapaxes(0, 1)
        ls = labels.reshape(labels.shape[0], nc, chunk).swapaxes(0, 1)
        ms = mask.reshape(mask.shape[0], nc, chunk).swapaxes(0, 1)

        @jax.checkpoint
        def chunk_ce(args):
            xc, lc, mc = args
            logits = unembed_apply(params["embed"], xc).astype(jnp.float32)
            return _ce_terms(logits, lc, mc)

        def body(carry, args):
            nll_c, z_c = chunk_ce(args)
            return (carry[0] + nll_c, carry[1] + z_c), None

        (nll_sum, z_sum), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())), (xs, ls, ms))
        ce = nll_sum / denom
        zl = z_loss * z_sum / denom
    else:
        logits, _, aux = forward(params, cfg, batch, mode="train",
                                 scan=scan, remat=remat)
        if logits.shape[1] != labels.shape[1]:
            # Frontend stub prepends embeddings; score text positions only.
            ft = logits.shape[1] - labels.shape[1]
            logits = logits[:, ft:]
        nll_sum, z_sum = _ce_terms(logits.astype(jnp.float32), labels, mask)
        ce = nll_sum / denom
        zl = z_loss * z_sum / denom
    total = ce + aux + zl
    return total, {"ce": ce, "aux": aux, "z_loss": zl,
                   "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# Serving entry points.
# ---------------------------------------------------------------------------
def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Any], *,
            scan: bool = True, max_len: Optional[int] = None
            ) -> Tuple[jax.Array, List[Params]]:
    """Returns (last-position logits, caches padded to max_len)."""
    logits, caches, _ = forward(params, cfg, batch, mode="prefill",
                                scan=scan, max_len=max_len)
    return logits[:, -1], caches


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                caches: List[Params], pos, *, scan: bool = True
                ) -> Tuple[jax.Array, List[Params]]:
    """tokens: (b, 1). Returns (logits (b, vocab), new caches)."""
    logits, new_caches, _ = forward(
        params, cfg, {"tokens": tokens}, mode="decode", caches=caches,
        pos=pos, scan=scan)
    return logits[:, 0], new_caches


# ---------------------------------------------------------------------------
# Cache helpers.
# ---------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=None) -> List[Params]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    return stack.stack_caches(cfg, batch, max_len, dtype)


def cache_specs(cfg: ModelConfig) -> List[Params]:
    return stack.stack_cache_specs(cfg)
