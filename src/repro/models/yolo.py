"""YOLOv5x-style detector backbone+head in pure JAX (paper workload, §3).

CSP bottleneck blocks + SPPF, width/depth multiples of YOLOv5x
(w=1.25, d=1.33). Detection post-processing (NMS) is out of scope — the
benchmark measures the network forward pass, as the paper's TFLite/TensorRT
measurements do.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _conv_init(rng, k, cin, cout):
    scale = (2.0 / (k * k * cin)) ** 0.5
    return {"w": jax.random.normal(rng, (k, k, cin, cout)) * scale,
            "b": jnp.zeros((cout,))}


def _conv(x, p, stride=1):
    h = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    return jax.nn.silu(h)


def _c3_init(rng, cin, cout, n):
    ks = jax.random.split(rng, 3 + 2 * n)
    cmid = cout // 2
    p = {
        "cv1": _conv_init(ks[0], 1, cin, cmid),
        "cv2": _conv_init(ks[1], 1, cin, cmid),
        "cv3": _conv_init(ks[2], 1, 2 * cmid, cout),
        "m": [{"cv1": _conv_init(ks[3 + 2 * i], 1, cmid, cmid),
               "cv2": _conv_init(ks[4 + 2 * i], 3, cmid, cmid)}
              for i in range(n)],
    }
    return p


def _c3(x, p):
    a = _conv(x, p["cv1"])
    for m in p["m"]:
        a = a + _conv(_conv(a, m["cv1"]), m["cv2"])
    b = _conv(x, p["cv2"])
    return _conv(jnp.concatenate([a, b], axis=-1), p["cv3"])


def _sppf_init(rng, c):
    k1, k2 = jax.random.split(rng)
    return {"cv1": _conv_init(k1, 1, c, c // 2),
            "cv2": _conv_init(k2, 1, c * 2, c)}


def _sppf(x, p):
    h = _conv(x, p["cv1"])
    pools = [h]
    for _ in range(3):
        pools.append(jax.lax.reduce_window(
            pools[-1], -jnp.inf, jax.lax.max, (1, 5, 5, 1), (1, 1, 1, 1),
            "SAME"))
    return _conv(jnp.concatenate(pools, axis=-1), p["cv2"])


# YOLOv5x widths/depths.
_WIDTHS = [80, 160, 320, 640, 1280]
_DEPTHS = [4, 8, 12, 4]


def yolo_init(rng, num_outputs: int = 255) -> Params:
    ks = jax.random.split(rng, 16)
    p: Params = {"stem": _conv_init(ks[0], 6, 3, _WIDTHS[0])}
    stages = []
    for i in range(4):
        stages.append({
            "down": _conv_init(ks[1 + 2 * i], 3, _WIDTHS[i], _WIDTHS[i + 1]),
            "c3": _c3_init(ks[2 + 2 * i], _WIDTHS[i + 1], _WIDTHS[i + 1],
                           _DEPTHS[i]),
        })
    p["stages"] = stages
    p["sppf"] = _sppf_init(ks[10], _WIDTHS[4])
    p["head"] = _conv_init(ks[11], 1, _WIDTHS[4], num_outputs)
    return p


def yolo_apply(params: Params, x: jax.Array) -> jax.Array:
    """x: (b, 640, 640, 3) -> (b, 20, 20, 255) coarse head."""
    h = _conv(x, params["stem"], 2)
    for st in params["stages"]:
        h = _conv(h, st["down"], 2)
        h = _c3(h, st["c3"])
    h = _sppf(h, params["sppf"])
    return jax.lax.conv_general_dilated(
        h, params["head"]["w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["head"]["b"]
