from repro.models import model
from repro.models.model import (cache_specs, decode_step, forward,
                                init_caches, init_params, loss_fn,
                                param_shapes, param_specs, prefill)

__all__ = [
    "model", "cache_specs", "decode_step", "forward", "init_caches",
    "init_params", "loss_fn", "param_shapes", "param_specs", "prefill",
]
