"""Mamba-2 (SSD) mixer layer with causal depthwise conv and gated RMSNorm.

Train/prefill run the chunked SSD (``kernels.ops.ssd``: Pallas on TPU,
sequential-scan oracle on CPU); decode runs the O(1) single-token
recurrence carrying (conv_state, ssd_state).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.kernels.ref import ssd_decode_ref
from repro.models.layers import dense_init

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    assert m is not None
    di = m.d_inner(cfg.d_model)
    nh = m.n_heads(cfg.d_model)
    return m, di, nh


def mamba_init(rng, cfg: ModelConfig) -> Params:
    m, di, nh = _dims(cfg)
    d, n = cfg.d_model, m.d_state
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    conv_dim = di + 2 * n
    return {
        # in_proj emits [z (di), x (di), B (n), C (n), dt (nh)]
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + nh), dtype),
        "conv_w": dense_init(ks[1], (m.d_conv, conv_dim), dtype, scale=0.1),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[3], (di, d), dtype),
    }


def mamba_specs(cfg: ModelConfig) -> Params:
    return {
        "w_in": ("p_embed", "p_inner"),
        "conv_w": (None, "p_inner"),
        "conv_b": ("p_inner",),
        "A_log": (None,),
        "dt_bias": (None,),
        "D": (None,),
        "norm_scale": ("p_inner",),
        "w_out": ("p_inner", "p_embed"),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    m, di, nh = _dims(cfg)
    conv_dim = di + 2 * m.d_state
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, nh, m.headdim, m.d_state), jnp.float32),
    }


def mamba_cache_specs() -> Params:
    return {
        "conv": ("batch", None, "mlp_act"),
        "ssd": ("batch", "heads_act", None, None),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    m, di, nh = _dims(cfg)
    n = m.d_state
    z = proj[..., :di]
    xbc = proj[..., di: 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 init: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. xbc: (b, s, c); w: (k, c)."""
    k = w.shape[0]
    if init is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = init.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i: i + xbc.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)[None, None, :]
    out = out + b.astype(jnp.float32)[None, None, :]
    return jax.nn.silu(out).astype(xbc.dtype)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float) -> jax.Array:
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def mamba_apply(params: Params, cfg: ModelConfig, x: jax.Array, *,
                mode: str, cache: Optional[Params] = None
                ) -> Tuple[jax.Array, Optional[Params]]:
    """x: (b, s, d) -> (out, new_cache)."""
    m, di, nh = _dims(cfg)
    n, p = m.d_state, m.headdim
    b, s, d = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    proj = shard(proj, ("batch", "seq", "mlp_act"))
    z, xbc, dt_raw = _split_proj(cfg, proj)
    A = -jnp.exp(params["A_log"])

    if mode in ("train", "prefill"):
        xbc_c = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xs = xbc_c[..., :di].reshape(b, s, nh, p)
        B = xbc_c[..., di: di + n]
        C = xbc_c[..., di + n:]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + params["dt_bias"][None, None])
        y, state = ops.ssd(xs, dt, A, B, C, params["D"],
                           chunk=m.chunk_size)
        y = y.reshape(b, s, di)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "conv": xbc[:, s - (m.d_conv - 1):].astype(x.dtype)
                if s >= m.d_conv - 1 else jnp.pad(
                    xbc, ((0, 0), (m.d_conv - 1 - s, 0), (0, 0))),
                "ssd": state,
            }
    else:  # decode: s == 1
        assert cache is not None
        conv_hist = jnp.concatenate([cache["conv"], xbc], axis=1)
        w, bias = params["conv_w"], params["conv_b"]
        acc = jnp.einsum("bkc,kc->bc", conv_hist.astype(jnp.float32),
                         w.astype(jnp.float32))
        xbc_c = jax.nn.silu(acc + bias.astype(jnp.float32))[:, None].astype(x.dtype)
        xs = xbc_c[..., :di].reshape(b, nh, p)
        B = xbc_c[:, 0, di: di + n]
        C = xbc_c[:, 0, di + n:]
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + params["dt_bias"][None])
        y1, state = ssd_decode_ref(xs, dt, A, B, C, params["D"],
                                   cache["ssd"])
        y = y1.reshape(b, 1, di)
        new_cache = {"conv": conv_hist[:, 1:], "ssd": state}

    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    y = shard(y, ("batch", "seq", "mlp_act"))
    return jnp.einsum("bse,ed->bsd", y, params["w_out"]), new_cache
