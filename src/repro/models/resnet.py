"""ResNet-50/152 in pure JAX — the paper's primary DL-serving workload
(§3, Fig 11). Used by the benchmark suite to measure real per-sample
compute on this host and to drive the energy/TCO models.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

RESNET_LAYOUT = {
    "resnet-50": (3, 4, 6, 3),
    "resnet-152": (3, 8, 36, 3),
}


def _conv_init(rng, kh, kw, cin, cout, dtype=jnp.float32):
    scale = (2.0 / (kh * kw * cin)) ** 0.5
    return jax.random.normal(rng, (kh, kw, cin, cout), dtype) * scale


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,)),
            "mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def _bn(x, p, eps=1e-5):
    inv = jax.lax.rsqrt(p["var"] + eps) * p["scale"]
    return x * inv + (p["bias"] - p["mean"] * inv)


def _bottleneck_init(rng, cin, cmid, cout, stride):
    ks = jax.random.split(rng, 4)
    p = {
        "conv1": _conv_init(ks[0], 1, 1, cin, cmid),
        "bn1": _bn_init(cmid),
        "conv2": _conv_init(ks[1], 3, 3, cmid, cmid),
        "bn2": _bn_init(cmid),
        "conv3": _conv_init(ks[2], 1, 1, cmid, cout),
        "bn3": _bn_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[3], 1, 1, cin, cout)
        p["bn_proj"] = _bn_init(cout)
    return p


def _bottleneck(x, p, stride):
    h = jax.nn.relu(_bn(_conv(x, p["conv1"]), p["bn1"]))
    h = jax.nn.relu(_bn(_conv(h, p["conv2"], stride), p["bn2"]))
    h = _bn(_conv(h, p["conv3"]), p["bn3"])
    if "proj" in p:
        x = _bn(_conv(x, p["proj"], stride), p["bn_proj"])
    return jax.nn.relu(x + h)


def resnet_init(rng, variant: str = "resnet-50",
                num_classes: int = 1000) -> Params:
    blocks = RESNET_LAYOUT[variant]
    ks = jax.random.split(rng, 3)
    params: Params = {
        "stem": _conv_init(ks[0], 7, 7, 3, 64),
        "stem_bn": _bn_init(64),
        "stages": [],
    }
    cin = 64
    rngs = jax.random.split(ks[1], sum(blocks))
    i = 0
    for stage, n in enumerate(blocks):
        cmid = 64 * (2 ** stage)
        cout = cmid * 4
        stage_p = []
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            stage_p.append(_bottleneck_init(rngs[i], cin, cmid, cout,
                                            stride))
            cin = cout
            i += 1
        params["stages"].append(stage_p)
    params["fc"] = jax.random.normal(ks[2], (cin, num_classes)) * 0.01
    return params


def resnet_apply(params: Params, x: jax.Array,
                 variant: str = "resnet-50") -> jax.Array:
    """x: (b, 224, 224, 3) -> (b, classes)."""
    blocks = RESNET_LAYOUT[variant]
    h = jax.nn.relu(_bn(_conv(x, params["stem"], 2), params["stem_bn"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for stage, n in enumerate(blocks):
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            h = _bottleneck(h, params["stages"][stage][b], stride)
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["fc"]


def resnet_flops(variant: str = "resnet-50", image: int = 224) -> float:
    """Analytic MACs x2 (published: ~4.1 GFLOPs R50, ~11.6 GFLOPs R152)."""
    return {"resnet-50": 4.1e9, "resnet-152": 11.6e9}[variant] * 2 / 2
