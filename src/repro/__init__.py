"""repro: a SoC-Cluster-inspired multi-pod JAX training/serving framework.

Reproduces and extends "More is Different: Prototyping and Analyzing a New
Form of Edge Server with Massive Mobile SoCs" — see DESIGN.md.
"""

__version__ = "0.1.0"
