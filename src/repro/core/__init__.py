"""The paper's primary contribution: SoC-Cluster orchestration in JAX.

cluster        — the cluster-of-small-units hardware model (calibrated)
collaborative  — §5.3 cross-unit tensor-parallel inference (+ pipelining)
energy         — TpE + energy-proportionality accounting (§4.1, §5.2)
scheduler      — elastic unit-activation policy + straggler hedging
tco            — §6 total-cost-of-ownership model (Tables 4/5)
"""
from repro.core import cluster, collaborative, energy, scheduler, tco

__all__ = ["cluster", "collaborative", "energy", "scheduler", "tco"]
