"""Energy accounting and proportionality metrics (paper §4.1, §5.2).

Core quantities:
  * TpE — throughput per energy (streams/W or samples/J), the paper's
    headline comparison metric (Fig 6, Fig 11b).
  * Energy-proportionality index — how closely server power tracks load
    (Barroso & Hölzle's ideal is P(u) = u * P_peak). The SoC Cluster's
    per-unit gating gives ~linear scaling; monolithic GPUs do not (Fig 7/12).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterSpec


# ---------------------------------------------------------------------------
# TpE.
# ---------------------------------------------------------------------------
def throughput_per_energy(throughput: float, power_w: float) -> float:
    """throughput in items/s, power in W -> items/J (== items/s/W)."""
    return throughput / max(power_w, 1e-9)


def energy_for_work(items: float, throughput: float, power_w: float) -> float:
    """Joules to process `items` at steady state."""
    return items / max(throughput, 1e-12) * power_w


# ---------------------------------------------------------------------------
# Load -> power curves.
# ---------------------------------------------------------------------------
def cluster_power_at_load(spec: ClusterSpec, load_frac: float,
                          unit_capacity: float = 1.0,
                          idle_units_off: bool = True) -> float:
    """Power when serving `load_frac` of peak load with the fine-grained
    policy: wake ceil(load * n) units at full utilization, gate the rest.
    (The SoC Cluster policy; a monolithic unit must instead run one unit at
    partial utilization — captured by n_units == 1 specs.)"""
    load = min(max(load_frac, 0.0), 1.0)
    if spec.n_units == 1:
        return spec.power(1, load)
    need = load * spec.n_units / unit_capacity
    full = int(np.floor(need))
    frac = need - full
    active_power = (spec.p_shared
                    + full * spec.unit.power(1.0)
                    + (spec.unit.power(frac) if frac > 0 else 0.0))
    rest = spec.n_units - full - (1 if frac > 0 else 0)
    active_power += rest * (spec.unit.p_off if idle_units_off
                            else spec.unit.p_idle)
    return active_power


def dvfs_power_at_load(spec: ClusterSpec, table, load_frac: float,
                       unit_capacity: float = 1.0,
                       idle_units_off: bool = True) -> float:
    """The frequency-resolved load→power curve: for each operating point
    in ``table`` (an :class:`repro.power.opp.OPPTable`), size the unit
    count that meets the load at that point's effective rate and take
    the cheapest feasible (OPP, count) pair — the schedutil governor's
    wide-and-slow vs narrow-and-fast search in closed form. At load 1.0
    only the top OPP with every unit is feasible, so the peak matches
    :func:`cluster_power_at_load` exactly."""
    from repro.power.opp import unit_power as opp_unit_power
    load = min(max(load_frac, 0.0), 1.0)
    unit = spec.unit
    p_rest_1 = unit.p_off if idle_units_off else unit.p_idle
    demand = load * spec.n_units / unit_capacity   # nominal-unit equivalents
    if demand <= 0.0:
        return spec.p_shared + spec.n_units * p_rest_1
    # the binary packing (nominal OPP, full units + one fractional) is
    # always a feasible configuration, so the resolved curve is pointwise
    # ≤ the binary one for every unit model (including gamma < 1, where
    # packing beats spreading utilization evenly)
    best = cluster_power_at_load(spec, load, unit_capacity=unit_capacity,
                                 idle_units_off=idle_units_off)
    for opp in table:
        n_need = max(1, int(np.ceil(demand / opp.perf_scale - 1e-12)))
        if n_need > spec.n_units:
            continue
        util = demand / (n_need * opp.perf_scale)
        p = spec.p_shared + n_need * opp_unit_power(unit, util, opp) \
            + (spec.n_units - n_need) * p_rest_1
        best = min(best, p)
    return float(best)


def proportionality_index(spec: ClusterSpec, idle_units_off: bool = True,
                          n: int = 101,
                          power_fn: Optional[
                              Callable[[ClusterSpec, float], float]] = None
                          ) -> float:
    """1 - mean |P(u)/P_peak - u|, in [0, 1]; 1.0 = perfectly proportional.

    ``power_fn(spec, load) -> W`` swaps in an alternative load→power
    curve (e.g. the frequency-resolved one via
    :func:`dvfs_proportionality_index`); the default is the binary
    per-unit-gating curve :func:`cluster_power_at_load`.
    """
    if power_fn is None:
        power_fn = lambda s, u: cluster_power_at_load(  # noqa: E731
            s, u, idle_units_off=idle_units_off)
    us = np.linspace(0.0, 1.0, n)
    peak = power_fn(spec, 1.0)
    ps = np.array([power_fn(spec, u) for u in us]) / peak
    return float(1.0 - np.mean(np.abs(ps - us)))


def dvfs_proportionality_index(spec: ClusterSpec, table,
                               idle_units_off: bool = True,
                               n: int = 101) -> float:
    """Proportionality of the frequency-resolved curve: per-unit gating
    *plus* DVFS. Never worse than the binary index — the binary
    configuration (nominal OPP, ceil(load·n) units) is one point in the
    per-load search space, so the curve is pointwise ≤ the binary one
    while the peaks coincide."""
    return proportionality_index(
        spec, idle_units_off=idle_units_off, n=n,
        power_fn=lambda s, u: dvfs_power_at_load(
            s, table, u, idle_units_off=idle_units_off))


def dynamic_range(spec: ClusterSpec, idle_units_off: bool = True) -> float:
    """P(idle)/P(peak): lower is better."""
    peak = cluster_power_at_load(spec, 1.0, idle_units_off=idle_units_off)
    idle = cluster_power_at_load(spec, 0.0, idle_units_off=idle_units_off)
    return float(idle / peak)


# ---------------------------------------------------------------------------
# Trace-driven energy accounting.
# ---------------------------------------------------------------------------
@dataclass
class EnergyReport:
    joules: float
    avg_power_w: float
    peak_power_w: float
    items: float
    tpe: float                 # items per joule
    proportionality: float


def account_trace(spec: ClusterSpec, load_trace: Sequence[float],
                  dt_s: float, items_per_s_at_peak: float,
                  idle_units_off: bool = True) -> EnergyReport:
    """Integrate energy over a load trace (fractions of peak load)."""
    powers = np.array([cluster_power_at_load(spec, u,
                                             idle_units_off=idle_units_off)
                       for u in load_trace])
    joules = float(np.sum(powers) * dt_s)
    items = float(np.sum(np.asarray(load_trace) * items_per_s_at_peak * dt_s))
    return EnergyReport(
        joules=joules,
        avg_power_w=float(np.mean(powers)),
        peak_power_w=float(np.max(powers)),
        items=items,
        tpe=items / max(joules, 1e-9),
        proportionality=proportionality_index(spec, idle_units_off),
    )
