"""Energy accounting and proportionality metrics (paper §4.1, §5.2).

Core quantities:
  * TpE — throughput per energy (streams/W or samples/J), the paper's
    headline comparison metric (Fig 6, Fig 11b).
  * Energy-proportionality index — how closely server power tracks load
    (Barroso & Hölzle's ideal is P(u) = u * P_peak). The SoC Cluster's
    per-unit gating gives ~linear scaling; monolithic GPUs do not (Fig 7/12).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec


# ---------------------------------------------------------------------------
# TpE.
# ---------------------------------------------------------------------------
def throughput_per_energy(throughput: float, power_w: float) -> float:
    """throughput in items/s, power in W -> items/J (== items/s/W)."""
    return throughput / max(power_w, 1e-9)


def energy_for_work(items: float, throughput: float, power_w: float) -> float:
    """Joules to process `items` at steady state."""
    return items / max(throughput, 1e-12) * power_w


# ---------------------------------------------------------------------------
# Load -> power curves.
# ---------------------------------------------------------------------------
def cluster_power_at_load(spec: ClusterSpec, load_frac: float,
                          unit_capacity: float = 1.0,
                          idle_units_off: bool = True) -> float:
    """Power when serving `load_frac` of peak load with the fine-grained
    policy: wake ceil(load * n) units at full utilization, gate the rest.
    (The SoC Cluster policy; a monolithic unit must instead run one unit at
    partial utilization — captured by n_units == 1 specs.)"""
    load = min(max(load_frac, 0.0), 1.0)
    if spec.n_units == 1:
        return spec.power(1, load)
    need = load * spec.n_units / unit_capacity
    full = int(np.floor(need))
    frac = need - full
    active_power = (spec.p_shared
                    + full * spec.unit.power(1.0)
                    + (spec.unit.power(frac) if frac > 0 else 0.0))
    rest = spec.n_units - full - (1 if frac > 0 else 0)
    active_power += rest * (spec.unit.p_off if idle_units_off
                            else spec.unit.p_idle)
    return active_power


def proportionality_index(spec: ClusterSpec, idle_units_off: bool = True,
                          n: int = 101) -> float:
    """1 - mean |P(u)/P_peak - u|, in [0, 1]; 1.0 = perfectly proportional.
    """
    us = np.linspace(0.0, 1.0, n)
    peak = cluster_power_at_load(spec, 1.0, idle_units_off=idle_units_off)
    ps = np.array([cluster_power_at_load(spec, u,
                                         idle_units_off=idle_units_off)
                   for u in us]) / peak
    return float(1.0 - np.mean(np.abs(ps - us)))


def dynamic_range(spec: ClusterSpec, idle_units_off: bool = True) -> float:
    """P(idle)/P(peak): lower is better."""
    peak = cluster_power_at_load(spec, 1.0, idle_units_off=idle_units_off)
    idle = cluster_power_at_load(spec, 0.0, idle_units_off=idle_units_off)
    return float(idle / peak)


# ---------------------------------------------------------------------------
# Trace-driven energy accounting.
# ---------------------------------------------------------------------------
@dataclass
class EnergyReport:
    joules: float
    avg_power_w: float
    peak_power_w: float
    items: float
    tpe: float                 # items per joule
    proportionality: float


def account_trace(spec: ClusterSpec, load_trace: Sequence[float],
                  dt_s: float, items_per_s_at_peak: float,
                  idle_units_off: bool = True) -> EnergyReport:
    """Integrate energy over a load trace (fractions of peak load)."""
    powers = np.array([cluster_power_at_load(spec, u,
                                             idle_units_off=idle_units_off)
                       for u in load_trace])
    joules = float(np.sum(powers) * dt_s)
    items = float(np.sum(np.asarray(load_trace) * items_per_s_at_peak * dt_s))
    return EnergyReport(
        joules=joules,
        avg_power_w=float(np.mean(powers)),
        peak_power_w=float(np.max(powers)),
        items=items,
        tpe=items / max(joules, 1e-9),
        proportionality=proportionality_index(spec, idle_units_off),
    )
