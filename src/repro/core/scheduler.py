"""Energy-proportional elastic scheduler with straggler hedging.

The paper's observation (§2.3, Fig 5): edge load is user-driven and swings
25x within a day while deployed clusters sit below 20% utilization. Its
thesis (§5.2): a cluster of small units saves energy by *activating only the
units the offered load needs*. This module implements that policy as a
discrete-event simulation plus the reusable policy object the serving
autoscaler consumes:

  * scale-up: immediate, with headroom;
  * scale-down: hysteresis (cooldown) to avoid thrashing on bursty load;
  * straggler hedging: requests stuck past a latency deadline are
    re-dispatched to a second unit (first completion wins) — the
    cross-unit analogue of backup tasks.

This is the *model-level* simulator. The canonical executable loop —
where the activation target actually gates workload concurrency — is
:class:`repro.runtime.ClusterRuntime`; both report the unified
:class:`repro.runtime.Telemetry` (``SimResult`` is a deprecated alias).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
# Deprecation shims: ScalePolicy now lives in repro.runtime.policy and the
# result struct is the unified repro.runtime.Telemetry; both are
# re-exported here so existing imports keep working.
from repro.runtime.policy import ScalePolicy
from repro.runtime.result import Telemetry

SimResult = Telemetry


class ElasticScheduler:
    """Discrete-time simulation (dt-stepped) of the unit-activation policy.

    Each unit serves ``unit_rate`` req/s at full utilization. Queued
    requests are FIFO; per-step latency is estimated from queue depth
    (M/D/c-style). This is intentionally a *model* — the serving engine
    drives real decode steps through the same policy object.
    """

    def __init__(self, spec: ClusterSpec, unit_rate: float,
                 policy: Optional[ScalePolicy] = None):
        self.spec = spec
        self.unit_rate = unit_rate
        self.policy = policy or ScalePolicy()

    def target_units(self, offered: float) -> int:
        need = offered * self.policy.headroom / self.unit_rate
        return int(min(self.spec.n_units,
                       max(self.policy.min_units, np.ceil(need))))

    def simulate(self, load_trace: Sequence[float], dt_s: float = 1.0
                 ) -> SimResult:
        p = self.policy
        n_steps = len(load_trace)
        active = p.min_units
        pending_wake: List[Tuple[float, int]] = []  # (ready_time, count)
        last_downscale = -1e9
        queue = 0.0
        served = dropped = 0.0
        hedged = 0
        latencies: List[float] = []
        t_arr = np.arange(n_steps) * dt_s
        act_arr = np.zeros(n_steps)
        pow_arr = np.zeros(n_steps)
        util_arr = np.zeros(n_steps)

        for i, offered in enumerate(load_trace):
            t = i * dt_s
            # Units finishing wake-up become active.
            pending_wake = [(rt, c) for rt, c in pending_wake if rt > t] or []
            waking = sum(c for rt, c in pending_wake)
            tgt = self.target_units(offered + queue / dt_s)
            if tgt > active + waking:
                pending_wake.append((t + p.wake_latency_s,
                                     tgt - active - waking))
            elif tgt < active and t - last_downscale > p.cooldown_s:
                active = max(p.min_units, tgt)
                last_downscale = t
            # Activate woken units.
            ready = sum(c for rt, c in pending_wake if rt <= t + dt_s)
            pending_wake = [(rt, c) for rt, c in pending_wake
                            if rt > t + dt_s]
            active = min(self.spec.n_units, active + ready)

            capacity = active * self.unit_rate * dt_s
            arriving = offered * dt_s
            work = queue + arriving
            done = min(work, capacity)
            queue = work - done
            served += done
            # Latency estimate: queueing delay + service time.
            util = min(1.0, work / max(capacity, 1e-9))
            wait = queue / max(active * self.unit_rate, 1e-9)
            lat = wait + 1.0 / self.unit_rate
            if p.hedge_after_s is not None and lat > p.hedge_after_s:
                # Hedge: borrow one extra unit this step (energy charged).
                hedged += 1
                extra = self.unit_rate * dt_s
                redo = min(queue, extra)
                queue -= redo
                served += redo
                lat = min(lat, p.hedge_after_s + 1.0 / self.unit_rate)
                act_for_power = active + 1
            else:
                act_for_power = active
            latencies.append(lat)
            util_for_power = min(1.0, work / max(
                act_for_power * self.unit_rate * dt_s, 1e-9))
            pow_arr[i] = self.spec.power(act_for_power, util_for_power,
                                         idle_units_off=True)
            act_arr[i] = active
            util_arr[i] = util_for_power

        lat_a = np.array(latencies)
        return Telemetry(
            time_s=t_arr,
            offered_load=np.asarray(load_trace, float),
            active_units=act_arr,
            power_w=pow_arr,
            utilization=util_arr,
            served=served,
            dropped=dropped,
            hedged=hedged,
            p50_latency_s=float(np.percentile(lat_a, 50)),
            p99_latency_s=float(np.percentile(lat_a, 99)),
            energy_j=float(np.sum(pow_arr) * dt_s),
        )


def diurnal_trace(peak_rps: float, hours: float = 24.0, dt_s: float = 60.0,
                  trough_frac: float = 0.04, noise: float = 0.05,
                  seed: int = 0) -> np.ndarray:
    """Synthetic diurnal load like the paper's Fig 5 (25x peak/trough)."""
    rng = np.random.default_rng(seed)
    n = int(hours * 3600 / dt_s)
    t = np.linspace(0, hours, n)
    base = 0.5 * (1 + np.sin((t - 9.0) / 24.0 * 2 * np.pi))
    load = trough_frac + (1 - trough_frac) * base ** 2
    load = load * (1 + noise * rng.standard_normal(n))
    return np.clip(load, 0.0, 1.0) * peak_rps
