"""Energy-proportional elastic scheduler with straggler hedging.

The paper's observation (§2.3, Fig 5): edge load is user-driven and swings
25x within a day while deployed clusters sit below 20% utilization. Its
thesis (§5.2): a cluster of small units saves energy by *activating only
the units the offered load needs*, and requests stuck past a latency
deadline are hedged onto an extra unit (the cross-unit analogue of backup
tasks).

Since the unit-allocation refactor, :class:`ElasticScheduler` is a **thin
wrapper**: ``simulate()`` builds a one-tenant
:class:`~repro.runtime.MultiTenantRuntime` over a fluid
:class:`~repro.runtime.QueueWorkload` and plays the trace through the
canonical runtime loop — the wake/cooldown/hedge policy lives once, in
:class:`~repro.runtime.UnitGovernor` and the runtime's hedging pass, not
in a duplicated simulation loop here. Both report the unified
:class:`repro.runtime.Telemetry` (``SimResult`` is a deprecated alias).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterSpec
# Deprecation shims: ScalePolicy now lives in repro.runtime.policy and the
# result struct is the unified repro.runtime.Telemetry; both are
# re-exported here so existing imports keep working.
from repro.runtime.multi_tenant import MultiTenantRuntime, Tenant
from repro.runtime.policy import ScalePolicy
from repro.runtime.result import Telemetry
from repro.runtime.workload import QueueWorkload

SimResult = Telemetry


class ElasticScheduler:
    """Fluid model of the unit-activation policy (thin runtime wrapper).

    Each unit serves ``unit_rate`` req/s at full utilization; queued
    requests are FIFO. The heavy lifting happens in the runtime stack —
    this class only packages a trace into a one-tenant run and trims the
    result to the legacy report shape.
    """

    def __init__(self, spec: ClusterSpec, unit_rate: float,
                 policy: Optional[ScalePolicy] = None):
        self.spec = spec
        self.unit_rate = unit_rate
        self.policy = policy or ScalePolicy()

    def target_units(self, offered: float) -> int:
        need = offered * self.policy.headroom / self.unit_rate
        return int(min(self.spec.n_units,
                       max(self.policy.min_units, np.ceil(need))))

    def simulate(self, load_trace: Sequence[float], dt_s: float = 1.0
                 ) -> SimResult:
        """Play ``load_trace`` through a one-tenant runtime.

        The runtime keeps ticking past the trace to drain the backlog
        (so latencies are real completion times, not estimates); the
        per-tick series and the energy integral are then trimmed back to
        the trace window, which is what the legacy simulator reported.
        """
        trace = np.asarray(load_trace, float)
        workload = QueueWorkload(self.unit_rate, name="elastic-sim")
        runtime = MultiTenantRuntime(
            self.spec,
            [Tenant("sim", workload, policy=self.policy,
                    unit_rate=self.unit_rate)],
            dt_s=dt_s, model_wake_latency=True)
        tel = runtime.play_traces({"sim": trace}, dt_s=dt_s)
        n = len(trace)
        energy = float(np.sum(tel.power_w[:n]) * dt_s)
        served = float(np.sum(runtime.pool.served_hist[:n]))
        return Telemetry(
            time_s=tel.time_s[:n],
            offered_load=trace,
            active_units=tel.active_units[:n],
            power_w=tel.power_w[:n],
            utilization=tel.utilization[:n],
            served=served,
            hedged=tel.hedged,
            scale_events=tel.scale_events,
            p50_latency_s=tel.p50_latency_s,
            p99_latency_s=tel.p99_latency_s,
            energy_j=energy,
            responses=tel.responses,
            workload=tel.workload,
        )


def diurnal_trace(peak_rps: float, hours: float = 24.0, dt_s: float = 60.0,
                  trough_frac: float = 0.04, noise: float = 0.05,
                  seed: int = 0) -> np.ndarray:
    """Synthetic diurnal load like the paper's Fig 5 (25x peak/trough)."""
    rng = np.random.default_rng(seed)
    n = int(hours * 3600 / dt_s)
    t = np.linspace(0, hours, n)
    base = 0.5 * (1 + np.sin((t - 9.0) / 24.0 * 2 * np.pi))
    load = trough_frac + (1 - trough_frac) * base ** 2
    load = load * (1 + noise * rng.standard_normal(n))
    return np.clip(load, 0.0, 1.0) * peak_rps
