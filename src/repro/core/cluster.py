"""The SoC-Cluster abstraction: a server/pod as a set of small units.

Calibrated to the paper's prototype (60x Snapdragon 865 in 2U, §2.2,
Table 1/4) and mapped onto the TPU deployment target (chip ≙ SoC,
ICI neighborhood ≙ PCB group, pod ≙ server). All downstream layers
(energy model, elastic scheduler, TCO) consume this description.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List


@dataclass(frozen=True)
class UnitSpec:
    """One compute unit (a mobile SoC, a GPU, or a TPU chip)."""

    name: str
    # power (watts)
    p_off: float
    p_idle: float
    p_peak: float
    # proportionality exponent: P(u) = idle + (peak - idle) * u**gamma.
    # gamma ~ 1 is proportional (mobile SoCs); gamma < 1 is the GPU-style
    # "jumps to high power at first request" behavior the paper measures.
    gamma: float = 1.0
    # nominal compute (used by the scheduler's capacity model)
    peak_tflops: float = 0.0
    mem_gb: float = 0.0

    def power(self, util: float) -> float:
        u = min(max(util, 0.0), 1.0)
        return self.p_idle + (self.p_peak - self.p_idle) * (u ** self.gamma)


@dataclass(frozen=True)
class ClusterSpec:
    """A server/pod: n units + shared infrastructure."""

    name: str
    unit: UnitSpec
    n_units: int
    p_shared: float              # fans, switch boards, BMC / host, links
    group_size: int = 1          # units per PCB / ICI neighborhood
    net_unit_gbps: float = 0.0   # per-unit network bandwidth
    net_shared_gbps: float = 0.0  # server/pod uplink

    def groups(self) -> List[List[int]]:
        return [list(range(i, min(i + self.group_size, self.n_units)))
                for i in range(0, self.n_units, self.group_size)]

    def power(self, active_units: int, util: float = 1.0,
              idle_units_off: bool = False) -> float:
        """Server power with `active_units` at `util`; the rest idle (or
        powered off — the SoC Cluster's per-SoC power gating, §5.2)."""
        active = min(active_units, self.n_units)
        rest = self.n_units - active
        p_rest = rest * (self.unit.p_off if idle_units_off
                         else self.unit.p_idle)
        return self.p_shared + active * self.unit.power(util) + p_rest

    @property
    def peak_power(self) -> float:
        return self.power(self.n_units, 1.0)


# ---------------------------------------------------------------------------
# Calibrated platforms.
# ---------------------------------------------------------------------------
def soc_cluster() -> ClusterSpec:
    """The paper's prototype: 60x SD865, 2U. Calibration: measured avg peak
    589 W (Table 4) = 60 x ~8 W (SoC full load) + ~109 W shared (8 fans,
    ESB, 12 PCBs, BMC); per-SoC idle ~0.6 W (Android suspended)."""
    return ClusterSpec(
        name="soc-cluster",
        unit=UnitSpec("sd865", p_off=0.0, p_idle=0.6, p_peak=8.0,
                      gamma=1.0, peak_tflops=1.2, mem_gb=12.0),
        n_units=60,
        p_shared=109.0,
        group_size=5,                 # 5 SoCs per PCB
        net_unit_gbps=1.0,            # PCB ethernet
        net_shared_gbps=20.0,         # dual SFP+ uplink
    )


def edge_server_cpu() -> ClusterSpec:
    """Traditional edge server, CPU only (Intel Xeon Gold, Table 1).
    Avg peak 633 W (Table 4); 8-core container ≙ one schedulable unit
    (the paper's Docker partitioning, §3 Setups)."""
    return ClusterSpec(
        name="edge-cpu",
        unit=UnitSpec("xeon-8core", p_off=0.0, p_idle=15.0, p_peak=48.0,
                      gamma=1.0, peak_tflops=0.6, mem_gb=76.0),
        n_units=10,
        p_shared=153.0,
        group_size=10,
        net_shared_gbps=20.0,
    )


def edge_server_gpu() -> ClusterSpec:
    """Traditional edge server GPU pool: 8x NVIDIA A40. Measured avg peak
    1231 W total (Table 4) => ~(1231-633)/8 ≈ 75 W avg per GPU during
    transcoding; DL serving drives them to ~220 W. High idle floor + sub-
    linear gamma reproduce the paper's poor proportionality (Fig 7/12)."""
    return ClusterSpec(
        name="edge-a40",
        unit=UnitSpec("a40", p_off=0.0, p_idle=55.0, p_peak=220.0,
                      gamma=0.45, peak_tflops=37.4, mem_gb=48.0),
        n_units=8,
        p_shared=633.0,   # host CPU/DRAM/fans (the CPU server underneath)
        group_size=1,
        net_shared_gbps=20.0,
    )


def a100_server() -> ClusterSpec:
    """High-end comparison GPU (GCP A100, §3 Hardware)."""
    return ClusterSpec(
        name="a100",
        unit=UnitSpec("a100", p_off=0.0, p_idle=60.0, p_peak=330.0,
                      gamma=0.45, peak_tflops=156.0, mem_gb=40.0),
        n_units=1,
        p_shared=250.0,
        group_size=1,
        net_shared_gbps=100.0,
    )


def tpu_v5e_pod(n_chips: int = 256) -> ClusterSpec:
    """The deployment target: one v5e pod as a 'SoC Cluster' of chips."""
    return ClusterSpec(
        name=f"tpu-v5e-{n_chips}",
        unit=UnitSpec("v5e", p_off=0.0, p_idle=60.0, p_peak=170.0,
                      gamma=0.9, peak_tflops=197.0, mem_gb=16.0),
        n_units=n_chips,
        p_shared=0.06 * n_chips * 170.0,   # hosts/fans amortized
        group_size=4,                       # one host board
        net_unit_gbps=400.0,                # ~50 GB/s/link ICI
        net_shared_gbps=800.0,              # DCN per pod
    )


PLATFORMS = {
    "soc-cluster": soc_cluster,
    "edge-cpu": edge_server_cpu,
    "edge-a40": edge_server_gpu,
    "a100": a100_server,
    "tpu-v5e": tpu_v5e_pod,
}
