"""Total-cost-of-ownership model (paper §6, Tables 4 & 5).

CapEx amortized over 36 months + electricity OpEx (unit cost x kWh x PUE).
Numbers are the paper's published Table 4 values; ``monthly_tco`` reproduces
its bottom line and ``throughput_per_cost`` produces Table 5.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

ELECTRICITY_USD_PER_KWH = 0.0786   # EIA industrial avg, Aug 2021–Jul 2022
PUE_EDGE = 2.0
AMORTIZE_MONTHS = 36
UTILIZATION = 0.5                  # "average peak power 50% of the time"


@dataclass(frozen=True)
class CapEx:
    items: Dict[str, float]

    @property
    def total(self) -> float:
        return float(sum(self.items.values()))

    @property
    def monthly(self) -> float:
        return self.total / AMORTIZE_MONTHS


@dataclass(frozen=True)
class TCOModel:
    name: str
    capex: CapEx
    avg_peak_power_w: float

    def monthly_kwh(self, utilization: float = UTILIZATION) -> float:
        return self.avg_peak_power_w * utilization * 24 * 30 / 1000.0

    def monthly_electricity(self, utilization: float = UTILIZATION,
                            pue: float = PUE_EDGE) -> float:
        base = self.monthly_kwh(utilization) * ELECTRICITY_USD_PER_KWH
        return base * pue  # server cost + (pue-1) overhead

    def monthly_tco(self, utilization: float = UTILIZATION,
                    pue: float = PUE_EDGE) -> float:
        return self.capex.monthly + self.monthly_electricity(utilization, pue)

    def throughput_per_cost(self, throughput: float,
                            utilization: float = UTILIZATION) -> float:
        """Table 5 TpC: items/s per monthly dollar."""
        return throughput / max(self.monthly_tco(utilization), 1e-9)


# ---------------------------------------------------------------------------
# The paper's three servers (Table 4).
# ---------------------------------------------------------------------------
def edge_server_tco() -> TCOModel:
    return TCOModel(
        name="edge-server-8xA40",
        capex=CapEx({
            "intel-cpu": 2740.0, "dram": 3540.0, "disk": 1220.0,
            "8x-a40": 35192.0, "others": 5544.0,
        }),
        avg_peak_power_w=1231.0,
    )


def edge_server_nogpu_tco() -> TCOModel:
    return TCOModel(
        name="edge-server-no-gpu",
        capex=CapEx({
            "intel-cpu": 2740.0, "dram": 3540.0, "disk": 1220.0,
            "others": 5544.0,
        }),
        avg_peak_power_w=633.0,
    )


def soc_cluster_tco() -> TCOModel:
    return TCOModel(
        name="soc-cluster",
        capex=CapEx({
            "60x-soc": 24489.0, "12x-pcb": 7075.0, "esb": 689.0,
            "bmc": 1923.0, "others": 2104.0,
        }),
        avg_peak_power_w=589.0,
    )


def tpu_v5e_pod_tco(n_chips: int = 256) -> TCOModel:
    """Deployment-target extension: a v5e pod through the same TCO lens
    (list-price-style estimates; used for the framework's own what-if
    analyses, clearly not a paper number)."""
    per_chip_capex = 4500.0
    host_capex = n_chips / 4 * 9000.0 / 4
    return TCOModel(
        name=f"tpu-v5e-{n_chips}",
        capex=CapEx({
            "chips": per_chip_capex * n_chips,
            "hosts+fabric": host_capex,
        }),
        avg_peak_power_w=n_chips * 170.0 * 0.75,
    )


PAPER_TABLE4 = {
    # published reference values for validation (tests/benchmarks assert
    # the model reproduces these within rounding)
    "edge-server-8xA40": {"total_capex": 48236.0, "capex_monthly": 1340.0,
                          "electricity_monthly": 70.0, "tco_monthly": 1410.0},
    "edge-server-no-gpu": {"total_capex": 13044.0, "capex_monthly": 363.0,
                           "electricity_monthly": 36.0, "tco_monthly": 399.0},
    "soc-cluster": {"total_capex": 36280.0, "capex_monthly": 1008.0,
                    "electricity_monthly": 34.0, "tco_monthly": 1042.0},
}
