"""Cross-unit collaborative DL inference (paper §5.3, Fig 13).

The paper width-partitions each tensor across N SoCs (Zeng et al. tensor
parallelism under MNN), observes that communication dominates (41.5% of
latency at N=5 over ~0.9 Gbps TCP), then pipelines computation with
communication ("transfer computation-required data first"), cutting the
communication share to 22.9%.

This module provides:
  1. a calibrated analytic latency model reproducing Fig 13 (the
     paper-faithful baseline AND its pipelined variant);
  2. the TPU mapping of the same workload under ICI bandwidth with the
     ring collective-matmul from ``distributed.collectives`` (the
     beyond-paper variant whose exposed communication is ~1/N of the
     transfer);
  3. an executable TP block (shard_map) used by benchmarks to measure real
     compute scaling on N devices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.collectives import naive_ag_matmul, ring_ag_matmul
from repro.distributed.compat import shard_map


# ---------------------------------------------------------------------------
# Network + workload models.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class NetworkModel:
    bandwidth_gbps: float       # effective per-link
    rtt_ms: float = 0.0
    per_hop_overhead_ms: float = 0.0

    def transfer_ms(self, megabytes: float) -> float:
        return megabytes * 8.0 / self.bandwidth_gbps + self.rtt_ms


# Measured by the paper (§2.3): TCP ~903 Mbps, RTT 0.44 ms between SoCs.
SOC_TCP = NetworkModel(bandwidth_gbps=0.903, rtt_ms=0.44)
# Deployment target: one ICI link ~50 GB/s = 400 Gbps; negligible RTT.
TPU_ICI = NetworkModel(bandwidth_gbps=400.0, rtt_ms=0.0)


@dataclass(frozen=True)
class CollabProfile:
    """Workload profile for width-partitioned inference of one model."""

    name: str
    compute_ms_1: float          # single-unit compute latency
    amdahl_alpha: float          # parallelizable fraction of compute
    comm_volume_mb: float        # total activation bytes exchanged (N->inf)
    overlap_frac: float          # fraction of compute usable to hide comm
                                 # in the paper's pipelined scheme

    def compute_ms(self, n: int) -> float:
        return self.compute_ms_1 * (self.amdahl_alpha / n
                                    + (1 - self.amdahl_alpha))

    def comm_ms(self, n: int, net: NetworkModel) -> float:
        if n <= 1:
            return 0.0
        vol = self.comm_volume_mb * (n - 1) / n
        return net.transfer_ms(vol)


# Calibrated to Fig 13 (ResNet-50, MNN): compute 80 ms -> 34 ms at N=5
# (alpha = 0.719); comm = 41.5% of total at N=5 => 24.1 ms over 0.903 Gbps
# => 3.40 MB effective exchanged volume; pipelining leaves 22.9% exposed
# => overlap_frac = 0.412 of compute hides communication.
RESNET50_PROFILE = CollabProfile(
    name="resnet-50", compute_ms_1=80.0, amdahl_alpha=0.719,
    comm_volume_mb=3.40, overlap_frac=0.412)

PAPER_FIG13 = {
    # (n_socs) -> reference points from the paper's text
    "compute_ms": {1: 80.0, 5: 34.0},
    "total_speedup_at_5": 1.38,
    "comm_share_at_5": 0.415,
    "comm_share_at_5_pipelined": 0.229,
}


def latency_breakdown(profile: CollabProfile, n: int, net: NetworkModel,
                      pipelined: bool = False,
                      ring_overlap: bool = False) -> Dict[str, float]:
    """Latency decomposition for N collaborating units.

    pipelined   — the paper's §5.3 scheme: overlap_frac of compute hides
                  communication.
    ring_overlap — the TPU ring collective-matmul: only the first of N
                  chunks is exposed (plus per-hop overheads).
    """
    comp = profile.compute_ms(n)
    comm = profile.comm_ms(n, net)
    if n <= 1:
        exposed = 0.0
    elif ring_overlap:
        exposed = comm / n + (n - 1) * net.per_hop_overhead_ms
    elif pipelined:
        exposed = max(comm - profile.overlap_frac * comp, 0.15 * comm)
    else:
        exposed = comm
    total = comp + exposed
    return {
        "n": n,
        "compute_ms": comp,
        "comm_ms_raw": comm,
        "comm_ms_exposed": exposed,
        "total_ms": total,
        "comm_share": exposed / total if total else 0.0,
        "speedup": profile.compute_ms(1) / total,
    }


def fig13_table(profile: CollabProfile = RESNET50_PROFILE,
                net: NetworkModel = SOC_TCP, max_n: int = 5):
    rows = []
    for n in range(1, max_n + 1):
        rows.append({
            "baseline": latency_breakdown(profile, n, net),
            "pipelined": latency_breakdown(profile, n, net, pipelined=True),
            "tpu_ring": latency_breakdown(profile, n, TPU_ICI,
                                          ring_overlap=True),
        })
    return rows


# ---------------------------------------------------------------------------
# Executable TP block (for real compute-scaling measurements).
# ---------------------------------------------------------------------------
def make_tp_block(mesh: Mesh, d_model: int, d_hidden: int,
                  overlap: bool = True, axis: str = "model"):
    """Two-matmul block  y = relu(x @ W1) @ W2  with W1 column- and W2
    row-sharded; the gather of x runs as a ring collective-matmul when
    ``overlap`` (beyond-paper) or a blocking all-gather + matmul otherwise
    (paper-faithful §5.3 baseline)."""
    mm = ring_ag_matmul if overlap else naive_ag_matmul

    def block(x_local, w1_local, w2_local):
        h = mm(x_local, w1_local, axis_name=axis)       # (m, d_hidden/A)
        h = jax.nn.relu(h)
        y = jnp.dot(h, w2_local, preferred_element_type=jnp.float32)
        y = jax.lax.psum(y, axis)                       # row-parallel reduce
        a = jax.lax.psum(1, axis)
        i = jax.lax.axis_index(axis)
        nl = y.shape[1] // a
        return jax.lax.dynamic_slice_in_dim(y, i * nl, nl, 1
                                            ).astype(x_local.dtype)

    return jax.jit(shard_map(
        block, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(axis, None)),
        out_specs=P(None, axis)))
