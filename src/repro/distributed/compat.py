"""Version compatibility helpers for the distributed layer.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax.shard_map`` around jax 0.6; import it from here so the repo runs on
both spellings.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # pre-0.6 jax
    from jax.experimental.shard_map import shard_map  # type: ignore


def pvary(x: jax.Array, axis_names) -> jax.Array:
    """Mark a replicated value as device-varying over ``axis_names``.

    Required for carries that mix with ppermute'd values under the vma
    (varying-manual-axes) type system of newer shard_map; older jax has
    no vma typing, so the identity is correct there.
    """
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, tuple(axis_names))
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, tuple(axis_names), to="varying")
    return x


__all__ = ["shard_map", "pvary"]
