"""Fault tolerance: failure detection, retries, and elastic re-meshing.

The paper (§8, "Killer applications") calls fault tolerance "crucial for
the success of SoC Cluster" — single-SoC failures must not take down the
job. At pod scale the equivalents are: (a) checkpoint/restart (see
``training.checkpoint``), (b) detecting dead/straggling units, (c) elastic
re-meshing — continuing on a smaller (or larger) healthy mesh by restoring
the last checkpoint with new shardings.
"""
from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Sequence, Tuple

import numpy as np

log = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# Heartbeats / straggler detection.
# ---------------------------------------------------------------------------
@dataclass
class UnitHealth:
    unit_id: int
    last_heartbeat: float
    # bounded O(1) ring of recent step times (was list.pop(0) — O(n))
    step_times: Deque[float] = field(
        default_factory=lambda: deque(maxlen=64))
    failed: bool = False

    def record(self, t_now: float, step_time: float) -> None:
        self.last_heartbeat = t_now
        self.step_times.append(step_time)


class HealthTracker:
    """Tracks per-unit liveness and step-time distribution.

    A unit is *failed* if it missed ``timeout_s`` of heartbeats, and a
    *straggler* if its recent step time exceeds ``straggler_factor`` x the
    cluster median (the mitigation hooks — hedged dispatch, backup fetch —
    live in the scheduler and data pipeline).
    """

    def __init__(self, unit_ids: Sequence[int], timeout_s: float = 60.0,
                 straggler_factor: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        now = clock()
        self.units: Dict[int, UnitHealth] = {
            u: UnitHealth(u, now) for u in unit_ids}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor

    def heartbeat(self, unit_id: int, step_time: float) -> None:
        self.units[unit_id].record(self._clock(), step_time)

    def mark_failed(self, unit_id: int) -> None:
        self.units[unit_id].failed = True

    def failed_units(self) -> List[int]:
        now = self._clock()
        out = []
        for u in self.units.values():
            if u.failed or now - u.last_heartbeat > self.timeout_s:
                out.append(u.unit_id)
        return sorted(out)

    def healthy_units(self) -> List[int]:
        bad = set(self.failed_units())
        return sorted(u for u in self.units if u not in bad)

    def stragglers(self) -> List[int]:
        times = {u.unit_id: np.mean(list(u.step_times)[-8:])
                 for u in self.units.values() if u.step_times}
        if len(times) < 2:
            return []
        med = float(np.median(list(times.values())))
        return sorted(u for u, t in times.items()
                      if t > self.straggler_factor * med)


# ---------------------------------------------------------------------------
# Retries.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RetryPolicy:
    """The single copy of the exponential-backoff math.

    ``with_retries`` (wall clock) and the fleet's deterministic retry
    mechanism (``repro.fleet.degrade``, sim clock) both delay attempt
    ``a`` by :meth:`delay_s` — there is deliberately no second
    implementation of ``backoff * 2**attempt`` anywhere in the repo.

    The jitter path is *seeded and clock-free*: :meth:`jitter_u` is a
    pure function of ``(seed, key)`` (the fleet uses the global tick
    index as ``key``), so two engines replaying the same schedule draw
    bit-identical jitter — wall-clock ``random.random()`` jitter would
    make retry timing, and therefore telemetry, irreproducible.
    """

    max_attempts: int = 3
    backoff_s: float = 0.1
    jitter: float = 0.0  # fraction of the base delay added at u=1
    seed: int = 0

    def __post_init__(self) -> None:
        assert self.max_attempts >= 1, "need at least one attempt"
        assert self.backoff_s >= 0.0, "backoff must be non-negative"
        assert 0.0 <= self.jitter, "jitter fraction must be >= 0"

    def delay_s(self, attempt: int, u: float = 0.0) -> float:
        """Backoff before retrying after failed attempt ``attempt``
        (0-based): ``backoff_s * 2**attempt * (1 + jitter * u)`` with
        ``u`` in [0, 1) from :meth:`jitter_u` (or 0 for no jitter)."""
        return self.backoff_s * (2 ** attempt) * (1.0 + self.jitter * u)

    def jitter_u(self, key: int) -> float:
        """Deterministic jitter draw in [0, 1) for ``key`` — seeded,
        independent of call order, identical across engines."""
        return float(np.random.default_rng([self.seed, int(key)]).random())

    @property
    def max_delay_s(self) -> float:
        """Upper bound on any single backoff delay (jitter maxed)."""
        return self.delay_s(self.max_attempts - 1, 1.0)


def with_retries(fn: Callable, max_attempts: int = 3,
                 backoff_s: float = 0.1,
                 retriable: Tuple[type, ...] = (RuntimeError,)):
    """Wrap a step function with bounded retries (transient XLA/runtime
    failures; non-retriable exceptions propagate). Delays come from
    :class:`RetryPolicy` — jitter-free here for backward compatibility."""
    policy = RetryPolicy(max_attempts=max_attempts, backoff_s=backoff_s)

    def wrapped(*a, **kw):
        last = None
        for attempt in range(policy.max_attempts):
            try:
                return fn(*a, **kw)
            except retriable as e:  # pragma: no cover - timing dependent
                last = e
                log.warning("step failed (attempt %d/%d): %s",
                            attempt + 1, policy.max_attempts, e)
                time.sleep(policy.delay_s(attempt))
        raise last
    return wrapped


# ---------------------------------------------------------------------------
# Elastic re-meshing.
# ---------------------------------------------------------------------------
def shrink_mesh_shape(shape: Tuple[int, ...], axes: Tuple[str, ...],
                      n_failed: int, shrink_axis: str = "data"
                      ) -> Tuple[int, ...]:
    """Compute the largest healthy mesh after losing ``n_failed`` units:
    the elastic policy drops whole slices along ``shrink_axis`` (each slice
    = prod(other axes) units), mirroring the SoC Cluster's PCB-granular
    fail-out."""
    sizes = dict(zip(axes, shape))
    other = 1
    for a, s in sizes.items():
        if a != shrink_axis:
            other *= s
    lost_slices = -(-n_failed // other)  # ceil
    new = max(1, sizes[shrink_axis] - lost_slices)
    return tuple(new if a == shrink_axis else sizes[a] for a in axes)


def remesh_arrays(tree, new_shardings):
    """Re-shard a pytree of arrays onto a new mesh (device_put handles the
    all-to-all movement; from a checkpoint this is a plain sharded load)."""
    # deferred: failure detection (HealthTracker) must stay importable
    # without jax — the scalar/vector chaos path composes with it
    import jax

    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings)


def elastic_step_scale(global_batch: int, old_data: int, new_data: int
                       ) -> Tuple[int, float]:
    """Keep the *global* batch when the data axis shrinks by raising the
    per-replica microbatch count; returns (microbatches, lr_scale)."""
    assert global_batch % old_data == 0
    per_replica = global_batch // old_data
    micro = -(-global_batch // (new_data * per_replica))
    return micro, 1.0  # same global batch => same LR
