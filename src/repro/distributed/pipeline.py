"""GPipe-style pipeline parallelism under ``shard_map``.

Each device along the ``stage`` axis owns a contiguous chunk of layers
(params pre-stacked with a leading stage dim). Microbatches stream through
the ring: at tick t stage s runs microbatch (t - s), activations hop
stage s -> s+1 via ``lax.ppermute``. Bubble fraction is the usual
(S-1)/(M+S-1); pick M >= 4*S.

This substrate is exercised at smoke scale (multi-device subprocess tests)
and is available via ``TrainConfig``-level wiring for models whose layers
are homogeneous; the 40-cell dry-run table uses DP x TP meshes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.compat import pvary, shard_map

Params = Any


def _shift_right(x: jax.Array, axis_name: str) -> jax.Array:
    a = jax.lax.psum(1, axis_name)
    perm = [(j, (j + 1) % a) for j in range(a)]
    return jax.lax.ppermute(x, axis_name, perm)


def pipeline_forward(stage_fn: Callable[[Params, jax.Array], jax.Array],
                     stage_params: Params, x_mb: jax.Array,
                     axis_name: str = "stage") -> jax.Array:
    """Run inside shard_map. x_mb: (M, mb, ...) microbatched inputs
    (replicated); stage_params: this stage's params. Returns (M, mb, ...)
    outputs (valid on the last stage; replicated back via ppermute ring).
    """
    s_idx = jax.lax.axis_index(axis_name)
    n_stage = jax.lax.psum(1, axis_name)
    m = x_mb.shape[0]
    ticks = m + n_stage - 1

    def _pvary(v):
        return pvary(v, (axis_name,))

    state = _pvary(jnp.zeros_like(x_mb[0]))
    outputs = _pvary(jnp.zeros_like(x_mb))
    x_mb = _pvary(x_mb)

    def body(t, carry):
        state, outputs = carry
        # Stage 0 ingests microbatch t (if any); others take the incoming
        # activation from the previous stage.
        mb_in = jax.lax.dynamic_index_in_dim(
            x_mb, jnp.clip(t, 0, m - 1), axis=0, keepdims=False)
        inp = jnp.where(s_idx == 0, mb_in, state)
        active = (t - s_idx >= 0) & (t - s_idx < m)
        out = stage_fn(stage_params, inp)
        out = jnp.where(active, out, jnp.zeros_like(out))
        # Last stage records its finished microbatch.
        mb_done = t - (n_stage - 1)
        record = (s_idx == n_stage - 1) & (mb_done >= 0) & (mb_done < m)
        outputs = jax.lax.cond(
            record,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, out, jnp.clip(mb_done, 0, m - 1), axis=0),
            lambda o: o,
            outputs)
        # Everyone forwards to the next stage.
        state = _shift_right(out, axis_name)
        return state, outputs

    _, outputs = jax.lax.fori_loop(0, ticks, body, (state, outputs))
    # Broadcast results from the last stage to all stages (masked psum is
    # provably replicated under the vma type system).
    outputs = jax.lax.psum(
        jnp.where(s_idx == n_stage - 1, outputs, jnp.zeros_like(outputs)),
        axis_name)
    return outputs


def make_pipelined_fn(stage_fn: Callable, mesh: Mesh, n_stages: int,
                      axis_name: str = "stage"):
    """Wrap stage_fn into a jit-able pipelined callable.

    stage_params must be stacked with a leading (n_stages,) dim; inputs are
    (M, mb, ...) microbatches.
    """
    def run(stacked_params, x_mb):
        fn = shard_map(
            functools.partial(pipeline_forward, stage_fn,
                              axis_name=axis_name),
            mesh=mesh,
            in_specs=(P(axis_name), P()),
            out_specs=P(),
        )
        # Each stage receives its own params slice: leading dim sharded.
        squeezed = jax.tree.map(lambda p: p, stacked_params)
        return fn(squeezed, x_mb)

    def wrapper(stacked_params, x_mb):
        def stage_body(params_slice, x):
            p = jax.tree.map(lambda a: a[0], params_slice)
            return stage_fn(p, x)
        fn = shard_map(
            functools.partial(pipeline_forward, stage_body,
                              axis_name=axis_name),
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(axis_name), stacked_params),
                      P()),
            out_specs=P(),
        )
        return fn(stacked_params, x_mb)

    return wrapper
