"""Overlapped collectives: ring collective-matmul under ``shard_map``.

This is the TPU-native implementation of the paper's §5.3 insight
("transfer computation-required data first" to pipeline communication with
computation): instead of `all_gather(x) @ w` (a blocking transfer followed
by compute), the gathered operand circulates around the ring one shard-chunk
per step via ``lax.ppermute`` while the MXU consumes the chunk already in
hand. Peak comm/compute overlap is ~(A-1)/A of the transfer.

All functions run *inside* ``shard_map`` (they use named axes).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.compat import pvary, shard_map


def _axis_size(axis_name: str) -> int:
    return jax.lax.psum(1, axis_name)


def _pvary(x: jax.Array, axis_name: str) -> jax.Array:
    """Mark a replicated value as device-varying over `axis_name` (required
    for carries that mix with ppermute'd values under shard_map's vma type
    system; identity on pre-vma jax)."""
    return pvary(x, (axis_name,))


def _ring_perm(a: int) -> Sequence[tuple]:
    # send j -> j-1: after i hops we hold the chunk originally at (idx+i)%A
    return [(j, (j - 1) % a) for j in range(a)]


# ---------------------------------------------------------------------------
# All-gather matmul:  y = all_gather(x, axis) @ w_local
#   x_local : (m, k_l)      -- sharded on k (the contracting dim)
#   w_local : (A*k_l, n_l)  -- full contracting dim, n sharded
# Returns y_local: (m, n_l).
# ---------------------------------------------------------------------------
def ring_ag_matmul(x_local: jax.Array, w_local: jax.Array,
                   axis_name: str) -> jax.Array:
    a = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m, kl = x_local.shape
    n_l = w_local.shape[1]
    perm = _ring_perm(a)

    def body(i, carry):
        acc, chunk = carry
        src = (idx + i) % a
        w_rows = jax.lax.dynamic_slice_in_dim(w_local, src * kl, kl, axis=0)
        acc = acc + jnp.dot(chunk, w_rows,
                            preferred_element_type=jnp.float32)
        # Send the chunk onward while (conceptually) the next matmul runs.
        chunk = jax.lax.ppermute(chunk, axis_name, perm)
        return acc, chunk

    acc0 = _pvary(jnp.zeros((m, n_l), jnp.float32), axis_name)
    acc, _ = jax.lax.fori_loop(0, a, body, (acc0, x_local))
    return acc.astype(x_local.dtype)


# ---------------------------------------------------------------------------
# Matmul reduce-scatter:  y = reduce_scatter(x @ w, axis, scatter dim=1)
#   x_local : (m, k_l)      -- k sharded (partial contributions)
#   w_local : (k_l, n)      -- full n
# Returns y_local: (m, n / A): the n-shard owned by this device, fully
# reduced. Partial products for the chunk that is `i` hops away are computed
# while the accumulator ring-hops toward its owner.
# ---------------------------------------------------------------------------
def ring_matmul_rs(x_local: jax.Array, w_local: jax.Array,
                   axis_name: str) -> jax.Array:
    a = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m, kl = x_local.shape
    n = w_local.shape[1]
    assert n % a == 0
    nl = n // a
    perm = _ring_perm(a)

    def partial(i):
        # partial(j) contributes to the accumulator that is j ring-hops away
        # from its final owner; with a j->j-1 ring that owner is idx - j.
        tgt = (idx - i) % a
        w_cols = jax.lax.dynamic_slice_in_dim(w_local, tgt * nl, nl, axis=1)
        return jnp.dot(x_local, w_cols, preferred_element_type=jnp.float32)

    def body(i, acc):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        step = a - 1 - i  # chunks farthest from their owner go first
        return acc + partial(step)

    acc = partial(a - 1)
    acc = jax.lax.fori_loop(1, a, lambda i, c: body(i, c), acc)
    return acc.astype(x_local.dtype)


# ---------------------------------------------------------------------------
# Baseline (unoverlapped) variants — the paper-faithful §5.3 "tensor
# parallelism without pipelining" reference points.
# ---------------------------------------------------------------------------
def naive_ag_matmul(x_local: jax.Array, w_local: jax.Array,
                    axis_name: str) -> jax.Array:
    x_full = jax.lax.all_gather(x_local, axis_name, axis=0)  # (A, m, k_l)
    a, m, kl = x_full.shape
    x_full = jnp.moveaxis(x_full, 0, 1).reshape(m, a * kl)
    return jnp.dot(x_full, w_local,
                   preferred_element_type=jnp.float32).astype(x_local.dtype)


def naive_matmul_rs(x_local: jax.Array, w_local: jax.Array,
                    axis_name: str) -> jax.Array:
    y = jnp.dot(x_local, w_local, preferred_element_type=jnp.float32)
    y = jax.lax.psum(y, axis_name)
    a = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    nl = y.shape[1] // a
    return jax.lax.dynamic_slice_in_dim(y, idx * nl, nl, axis=1
                                        ).astype(x_local.dtype)


# ---------------------------------------------------------------------------
# Jit-level helpers that wrap the ring ops in shard_map for a 1-D mesh axis.
# ---------------------------------------------------------------------------
def tp_matmul_overlapped(x: jax.Array, w: jax.Array, mesh: Mesh,
                         axis: str = "model") -> jax.Array:
    """y = x @ w with x k-sharded and w n-sharded on `axis`, overlapped."""
    fn = shard_map(
        functools.partial(ring_ag_matmul, axis_name=axis),
        mesh=mesh,
        in_specs=(P(None, axis), P(None, axis)),
        out_specs=P(None, axis),
    )
    return fn(x, w)
