"""Logical-axis sharding rules.

Every tensor dimension in the model carries a *logical* name ("batch",
"heads", "mlp", ...). A :class:`RuleSet` maps logical names to an ordered
tuple of physical mesh axes. The resolver assigns mesh axes to dims with two
safety properties that make the 40-cell dry-run robust:

* **divisibility fallback** — a mesh axis whose size does not divide the dim
  is dropped (e.g. ``kv_heads=10`` over ``model=16`` resolves to replicated),
  never an error;
* **no double-use** — a mesh axis is used by at most one dim of a tensor.

Models call :func:`shard` on activations; parameter shardings are resolved
from per-leaf logical specs. When no mesh context is active (unit tests on
one device) everything is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class RuleSet:
    """Mapping logical axis name -> ordered physical mesh axes to try."""

    rules: Dict[str, Tuple[str, ...]]

    def get(self, name: Optional[str]) -> Tuple[str, ...]:
        if name is None:
            return ()
        return self.rules.get(name, ())

    def override(self, **kw: Tuple[str, ...]) -> "RuleSet":
        d = dict(self.rules)
        d.update(kw)
        return RuleSet(d)


# ---------------------------------------------------------------------------
# Default rule tables. ``pod`` only exists on the multi-pod mesh; the
# resolver silently skips axes missing from the mesh.
# ---------------------------------------------------------------------------
def train_rules() -> RuleSet:
    return RuleSet({
        # activations
        "batch": ("pod", "data"),
        "seq": (),
        "embed_act": (),
        "heads_act": ("model",),
        "mlp_act": ("model",),
        "vocab_act": ("model",),
        "expert_act": ("model",),
        "expert_flat": ("model",),
        "kv_seq": ("model",),
        # params: fsdp over (pod,data), tensor-parallel over model
        "p_vocab": ("model",),
        "p_embed": ("pod", "data"),
        "p_heads": ("model",),
        "p_kv_heads": ("model",),
        "p_mlp": ("model",),
        "p_expert": ("model",),
        "p_inner": ("model",),        # mamba d_inner
        "p_state": (),
        "p_head_dim": (),
        "p_ff_fsdp": ("pod", "data"),  # second fsdp-able dim for expert w
    })


def serve_rules(serve_fsdp: bool = False, batch1: bool = False) -> RuleSet:
    fsdp: Tuple[str, ...] = ("pod", "data") if serve_fsdp else ()
    return RuleSet({
        "batch": ("pod", "data"),
        "seq": (),
        "embed_act": (),
        "heads_act": ("model",),
        "mlp_act": ("model",),
        "vocab_act": ("model",),
        "expert_act": ("model",),
        "expert_flat": ("model",),
        # decode caches: sequence-sharded (flash-decode combine); when
        # batch==1 the data axis is idle, so shard kv_seq over both.
        "kv_seq": ("pod", "data", "model") if batch1 else ("model",),
        "p_vocab": ("model",),
        "p_embed": fsdp,
        "p_heads": ("model",),
        "p_kv_heads": ("model",),
        "p_mlp": ("model",),
        "p_expert": ("model",),
        "p_inner": ("model",),
        "p_state": (),
        "p_head_dim": (),
        "p_ff_fsdp": fsdp,
    })


# ---------------------------------------------------------------------------
# Context.
# ---------------------------------------------------------------------------
class _Ctx(threading.local):
    def __init__(self) -> None:
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[RuleSet] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[RuleSet]):
    """Activate (mesh, rules) for `shard()` calls during tracing."""
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


# ---------------------------------------------------------------------------
# Resolution.
# ---------------------------------------------------------------------------
def resolve_spec(shape: Sequence[int], logical: Logical, rules: RuleSet,
                 mesh: Mesh) -> P:
    """Resolve logical names to a PartitionSpec honoring divisibility and
    single-use of mesh axes."""
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    out = []
    sizes = getattr(mesh, "axis_sizes", None)
    if sizes is None:  # concrete Mesh without axis_sizes property
        sizes = mesh.devices.shape
    axis_sizes = dict(zip(mesh.axis_names, sizes))
    for dim, name in zip(shape, logical):
        cand = [a for a in rules.get(name)
                if a in axis_sizes and a not in used]
        # Greedily keep a prefix of candidate axes whose product divides dim.
        chosen: list = []
        prod = 1
        for a in cand:
            if dim % (prod * axis_sizes[a]) == 0:
                chosen.append(a)
                prod *= axis_sizes[a]
        for a in chosen:
            used.add(a)
        if not chosen:
            out.append(None)
        elif len(chosen) == 1:
            out.append(chosen[0])
        else:
            out.append(tuple(chosen))
    # Trim trailing Nones (canonical form).
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(shape: Sequence[int], logical: Logical,
                   mesh: Optional[Mesh] = None,
                   rules: Optional[RuleSet] = None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None or rules is None:
        return None
    return NamedSharding(mesh, resolve_spec(shape, logical, rules, mesh))


def shard(x: jax.Array, logical: Logical) -> jax.Array:
    """Apply a logical sharding constraint (no-op without mesh context)."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or rules is None:
        return x
    spec = resolve_spec(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(tree_of_shapes, tree_of_logical, mesh: Mesh,
                   rules: RuleSet):
    """Map (shape-tree, logical-tree) -> NamedSharding tree."""
    return jax.tree.map(
        lambda shp, lg: NamedSharding(mesh, resolve_spec(shp, lg, rules, mesh)),
        tree_of_shapes, tree_of_logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (int, str, type(None))) for e in x),
    )
