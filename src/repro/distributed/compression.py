"""Gradient compression for data-parallel sync over weak links.

Directly motivated by the paper's setting: the SoC Cluster's inter-unit
fabric is ~1 Gbps — two orders of magnitude below datacenter interconnects —
so cross-unit synchronization must ship fewer bytes. We provide blockwise
int8 quantization with error feedback and a compressed all-reduce
(all-to-all reduce-scatter in int8 wire format + int8 all-gather: 2x N/4
bytes on the wire instead of 2x N fp32 bytes).

``compressed_psum_mean`` runs inside ``shard_map``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Blockwise int8 quantization.
# ---------------------------------------------------------------------------
def quantize_blockwise(x: jax.Array, block: int = 256
                       ) -> Tuple[jax.Array, jax.Array, int]:
    """x: any shape -> (q int8 (nb, block), scales (nb,), pad)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], pad


def dequantize_blockwise(q: jax.Array, scales: jax.Array, pad: int,
                         shape, dtype=jnp.float32) -> jax.Array:
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def quantize_blockwise_log(x: jax.Array, block: int = 256, tiny: float = 1e-30
                           ) -> Tuple[jax.Array, jax.Array, jax.Array, int]:
    """Log-space blockwise int8 for *non-negative* tensors (e.g. Adam's
    second moment): per-block (min, max) of log(x+tiny) mapped to [0, 255],
    giving bounded *relative* error — linear int8 would collapse small
    entries to zero and blow up 1/sqrt(v).

    Returns (q uint8 (nb, block), log_min (nb,), log_scale (nb,), pad)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = jnp.log(flat.reshape(-1, block) + tiny)
    lo = jnp.min(blocks, axis=1, keepdims=True)
    hi = jnp.max(blocks, axis=1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    q = jnp.clip(jnp.round((blocks - lo) / scale), 0, 255).astype(jnp.uint8)
    return q, lo[:, 0], scale[:, 0], pad


def dequantize_blockwise_log(q: jax.Array, log_min: jax.Array,
                             log_scale: jax.Array, pad: int, shape,
                             tiny: float = 1e-30) -> jax.Array:
    logs = (q.astype(jnp.float32) * log_scale[:, None] + log_min[:, None])
    flat = jnp.exp(logs).reshape(-1) - tiny
    if pad:
        flat = flat[:-pad]
    return jnp.maximum(flat, 0.0).reshape(shape)


def quantize_with_feedback(x: jax.Array, err: jax.Array, block: int = 256):
    """Error-feedback quantization: q = Q(x + err); err' = (x+err) - deQ(q).

    Returns ((q, scales, pad), new_err). The residual is re-injected on the
    next step so the quantization error does not bias the optimizer
    trajectory (1-bit-Adam-style memory compensation).
    """
    target = x.astype(jnp.float32) + err
    q, scales, pad = quantize_blockwise(target, block)
    approx = dequantize_blockwise(q, scales, pad, x.shape)
    return (q, scales, pad), target - approx


# ---------------------------------------------------------------------------
# Compressed all-reduce (mean) over a named axis. Call inside shard_map.
# Wire format: int8 payloads + fp32 per-block scales.
# ---------------------------------------------------------------------------
def compressed_psum_mean(x: jax.Array, axis_name: str,
                         block: int = 256) -> jax.Array:
    a = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad_to = (-n) % (a * block)
    if pad_to:
        flat = jnp.pad(flat, (0, pad_to))
    per = flat.shape[0] // a
    chunks = flat.reshape(a, per)

    # 1) reduce-scatter in int8: quantize each destination chunk, all_to_all,
    #    dequantize, and sum the a received contributions.
    qs, scales, pad = quantize_blockwise(chunks, block)     # (a*nb, block)
    nb = qs.shape[0] // a
    qs = qs.reshape(a, nb, block)
    scales = scales.reshape(a, nb)
    qs_r = jax.lax.all_to_all(qs, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    sc_r = jax.lax.all_to_all(scales, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    own = jnp.sum(qs_r.astype(jnp.float32) * sc_r[..., None], axis=0) / a

    # 2) all-gather the reduced chunk, again in int8.
    q2, s2, pad2 = quantize_blockwise(own, block)
    q2_g = jax.lax.all_gather(q2, axis_name, axis=0)        # (a, nb, block)
    s2_g = jax.lax.all_gather(s2, axis_name, axis=0)
    full = (q2_g.reshape(a, nb, block).astype(jnp.float32)
            * s2_g.reshape(a, nb)[..., None]).reshape(-1)
    if pad_to:
        full = full[:-pad_to]
    return full.reshape(x.shape).astype(x.dtype)


def wire_bytes_fp32(num_elements: int, axis_size: int) -> int:
    """Bytes on the wire for a ring fp32 all-reduce (2(A-1)/A * N * 4)."""
    return int(2 * (axis_size - 1) / axis_size * num_elements * 4)


def wire_bytes_compressed(num_elements: int, axis_size: int,
                          block: int = 256) -> int:
    payload = num_elements  # int8
    scales = (num_elements // block) * 4
    return int(2 * (axis_size - 1) / axis_size * (payload + scales))
