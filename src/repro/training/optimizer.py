"""AdamW with optional blockwise-int8 moment compression.

The int8 state path stores both Adam moments as (int8 payload, fp32
per-block scales) — 4x smaller optimizer state. At 256-chip scale this is
what lets the 398B/778B assigned configs fit HBM during training (see
EXPERIMENTS.md §Dry-run); it is also in the spirit of the paper's thesis
that fleets of small-memory units need software that respects their limits.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig

Params = Any


@jax.tree_util.register_pytree_node_class
class QTensor:
    """Row-wise (last-axis) int8 tensor: shape-preserving, so the payload
    inherits the parameter's sharding unchanged (no flatten/reshape that
    would force GSPMD resharding at 256-chip scale)."""

    def __init__(self, q: jax.Array, scale: jax.Array):
        self.q = q              # int8, same shape as the source
        self.scale = scale      # fp32, shape[:-1] + (1,)

    def dequant(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __repr__(self):  # pragma: no cover
        return f"QTensor(shape={self.q.shape})"


@jax.tree_util.register_pytree_node_class
class QTensorLog:
    """Row-wise log-space uint8 tensor for non-negative data (Adam v):
    per-row (min, range) of log(v+tiny) mapped to [0, 255] — bounded
    *relative* error, so 1/sqrt(v) stays sane where linear int8 would
    collapse small entries to zero."""

    TINY = 1e-30

    def __init__(self, q, log_min, log_scale):
        self.q = q                     # uint8, source shape
        self.log_min = log_min         # fp32, shape[:-1] + (1,)
        self.log_scale = log_scale     # fp32, shape[:-1] + (1,)

    def dequant(self) -> jax.Array:
        logs = self.q.astype(jnp.float32) * self.log_scale + self.log_min
        return jnp.maximum(jnp.exp(logs) - self.TINY, 0.0)

    def tree_flatten(self):
        return (self.q, self.log_min, self.log_scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __repr__(self):  # pragma: no cover
        return f"QTensorLog(shape={self.q.shape})"


def _quant_rowwise(x: jax.Array) -> QTensor:
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def _quant_rowwise_log(x: jax.Array) -> QTensorLog:
    logs = jnp.log(x + QTensorLog.TINY)
    lo = jnp.min(logs, axis=-1, keepdims=True)
    hi = jnp.max(logs, axis=-1, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / 255.0
    q = jnp.clip(jnp.round((logs - lo) / scale), 0, 255).astype(jnp.uint8)
    return QTensorLog(q, lo, scale)


def _maybe_quant(x: jax.Array, dtype: str, log_space: bool = False):
    if dtype == "int8":
        return _quant_rowwise_log(x) if log_space else _quant_rowwise(x)
    return x.astype(jnp.float32)


def _maybe_dequant(x) -> jax.Array:
    if isinstance(x, (QTensor, QTensorLog)):
        return x.dequant()
    return x


class OptState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def init_opt_state(params: Params, cfg: TrainConfig) -> OptState:
    zeros = jax.tree.map(
        lambda p: _maybe_quant(jnp.zeros(p.shape, jnp.float32),
                               cfg.opt_state_dtype), params)
    zeros_v = jax.tree.map(
        lambda p: _maybe_quant(jnp.zeros(p.shape, jnp.float32),
                               cfg.opt_state_dtype, log_space=True), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros_v)


def opt_state_specs(param_specs: Params, cfg: TrainConfig) -> OptState:
    """Logical sharding specs matching init_opt_state's structure. Row-wise
    payloads inherit the param's logical spec; scales drop the last axis."""
    def leaf_m(spec):
        t = tuple(spec)
        if cfg.opt_state_dtype == "int8":
            return QTensor(q=t, scale=(*t[:-1], None))
        return t

    def leaf_v(spec):
        t = tuple(spec)
        if cfg.opt_state_dtype == "int8":
            return QTensorLog(q=t, log_min=(*t[:-1], None),
                              log_scale=(*t[:-1], None))
        return t

    is_t = lambda t: isinstance(t, tuple)
    return OptState(
        step=(),
        m=jax.tree.map(leaf_m, param_specs, is_leaf=is_t),
        v=jax.tree.map(leaf_v, param_specs, is_leaf=is_t),
    )


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads: Params, state: OptState, params: Params,
                 cfg: TrainConfig) -> Tuple[Params, OptState, Dict[str, Any]]:
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_f = _maybe_dequant(m)
        v_f = _maybe_dequant(v)
        m_n = b1 * m_f + (1 - b1) * g
        v_n = b2 * v_f + (1 - b2) * g * g
        update = (m_n / bc1) / (jnp.sqrt(v_n / bc2) + eps)
        if p.dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return p_new, _maybe_quant(m_n, cfg.opt_state_dtype), \
            _maybe_quant(v_n, cfg.opt_state_dtype, log_space=True)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_q = lambda x: isinstance(x, (QTensor, QTensorLog))
    flat_m = jax.tree.leaves(state.m, is_leaf=is_q)
    flat_v = jax.tree.leaves(state.v, is_leaf=is_q)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        pn, mn, vn = upd(p, g, m, v)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    params = jax.tree.unflatten(tdef, new_p)
    m_tree = jax.tree.unflatten(tdef, new_m)
    v_tree = jax.tree.unflatten(tdef, new_v)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return params, OptState(step, m_tree, v_tree), metrics


def opt_state_bytes(params: Params, cfg: TrainConfig) -> int:
    n = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    if cfg.opt_state_dtype == "int8":
        # payloads (m int8 + v uint8) + row scales (1 + 2 fp32 per row)
        rows = sum(int(jnp.size(l)) // max(l.shape[-1], 1)
                   for l in jax.tree.leaves(params))
        return 2 * n + 3 * rows * 4
    return 2 * n * 4
