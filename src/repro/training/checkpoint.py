"""Sharded, atomic, retention-managed checkpointing (no external deps).

Layout:
    <dir>/step_<n>/manifest.json       # keypath -> {file, shape, dtype}
    <dir>/step_<n>/<leaf files>.npy
    <dir>/LATEST                       # contains "step_<n>"

Guarantees:
  * atomic — written into ``.tmp-step_<n>`` then os.rename'd, so a crash
    mid-save never corrupts LATEST;
  * resumable onto a different mesh — leaves are stored unsharded and
    restored via device_put with the *target* shardings (elastic restart);
  * retention — keep the most recent ``keep`` checkpoints;
  * async — ``save_async`` snapshots to host then writes on a worker
    thread so the train loop is not blocked.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

Params = Any


def _keypath_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree: Params):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_keypath_str(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree: Params, keep: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, f".tmp-{name}")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: Dict[str, Dict] = {}
    for i, (key, leaf) in enumerate(_flatten(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":
            # numpy can't round-trip ml_dtypes; store the raw bits.
            np.save(os.path.join(tmp, fname), arr.view(np.uint16),
                    allow_pickle=False)
        else:
            np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # LATEST pointer (atomic via rename).
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _apply_retention(ckpt_dir, keep)
    return final


class AsyncSave:
    def __init__(self, thread: threading.Thread):
        self._thread = thread

    def wait(self) -> None:
        self._thread.join()


def save_async(ckpt_dir: str, step: int, tree: Params,
               keep: int = 3) -> AsyncSave:
    """Snapshot to host memory now; write on a worker thread."""
    host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, keep),
                         daemon=True)
    t.start()
    return AsyncSave(t)


def _apply_retention(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template: Params, step: Optional[int] = None,
            shardings: Optional[Params] = None) -> Params:
    """Restore into the structure of ``template``; optionally place each
    leaf with the given shardings (tree matching template) — this is the
    elastic-remesh path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]

    flat_template, tdef = jax.tree_util.tree_flatten_with_path(template)
    flat_shardings: List[Any]
    if shardings is not None:
        flat_shardings = [s for _, s in
                          jax.tree_util.tree_flatten_with_path(shardings)[0]]
    else:
        flat_shardings = [None] * len(flat_template)

    leaves = []
    for (keypath, tmpl_leaf), shard in zip(flat_template, flat_shardings):
        key = _keypath_str(keypath)
        if key not in manifest:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        entry = manifest[key]
        arr = np.load(os.path.join(path, entry["file"]), allow_pickle=False)
        if entry["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        want_dtype = (tmpl_leaf.dtype if hasattr(tmpl_leaf, "dtype")
                      else arr.dtype)
        if str(want_dtype) != str(arr.dtype):
            arr = arr.astype(want_dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(tdef, leaves)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_"))
