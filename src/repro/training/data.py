"""Deterministic synthetic LM data pipeline with prefetch + straggler
backup.

Sequences mix a Zipf unigram stream with copy/repeat motifs so a small LM
has real structure to learn (the end-to-end example shows the loss curve).
Batches are keyed by (seed, step) — bitwise deterministic, which is what
makes the checkpoint-resume test exact.

Straggler mitigation (paper §8: single-unit failures must not stall the
job): the prefetcher runs fetches on worker threads with a deadline; a
fetch that misses its deadline is *hedged* — the batch for that step is
regenerated inline (generation is deterministic, so the hedge is
bit-identical) and the slow worker's late result is discarded.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.config import ModelConfig, ShapeSpec


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    copy_prob: float = 0.35
    frontend_tokens: int = 0
    frontend_dim: int = 0


def _gen_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xC0FFEE]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    base = rng.zipf(cfg.zipf_a, size=(b, s + 1)) % v
    # Copy motif: with prob copy_prob, token t repeats token t-3.
    copy_mask = rng.random((b, s + 1)) < cfg.copy_prob
    shifted = np.roll(base, 3, axis=1)
    seq = np.where(copy_mask, shifted, base).astype(np.int32)
    out: Dict[str, np.ndarray] = {
        "tokens": seq[:, :-1],
        "labels": seq[:, 1:].astype(np.int32),
        "mask": np.ones((b, s), np.float32),
    }
    if cfg.frontend_tokens:
        out["vision_embeds"] = rng.standard_normal(
            (b, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    return out


def data_config_for(model: ModelConfig, shape: ShapeSpec,
                    seed: int = 0) -> DataConfig:
    ft = model.frontend_tokens
    return DataConfig(
        vocab_size=model.vocab_size,
        seq_len=shape.seq_len - ft,
        global_batch=shape.global_batch,
        seed=seed,
        frontend_tokens=ft,
        frontend_dim=model.frontend_dim or model.d_model,
    )


class PrefetchingLoader:
    """Background prefetch with per-fetch deadline + deterministic hedging.
    """

    def __init__(self, cfg: DataConfig, prefetch: int = 2,
                 fetch_deadline_s: float = 30.0,
                 place_fn: Optional[Callable[[Dict[str, np.ndarray]], Any]]
                 = None,
                 delay_injector: Optional[Callable[[int], float]] = None):
        self.cfg = cfg
        self.prefetch = prefetch
        self.deadline = fetch_deadline_s
        self.place_fn = place_fn or (lambda b: b)
        self.delay_injector = delay_injector  # tests inject stragglers
        self.hedge_count = 0
        self._results: Dict[int, Any] = {}
        self._lock = threading.Lock()
        self._next_to_start = 0

    def _fetch(self, step: int) -> None:
        if self.delay_injector is not None:
            time.sleep(self.delay_injector(step))
        batch = _gen_batch(self.cfg, step)
        with self._lock:
            self._results.setdefault(step, batch)

    def _ensure_started(self, upto: int) -> None:
        while self._next_to_start <= upto:
            s = self._next_to_start
            threading.Thread(target=self._fetch, args=(s,),
                             daemon=True).start()
            self._next_to_start += 1

    def get(self, step: int) -> Any:
        self._ensure_started(step + self.prefetch)
        deadline = time.monotonic() + self.deadline
        while True:
            with self._lock:
                if step in self._results:
                    batch = self._results.pop(step)
                    break
            if time.monotonic() > deadline:
                # Hedge: regenerate deterministically inline.
                self.hedge_count += 1
                batch = _gen_batch(self.cfg, step)
                with self._lock:
                    self._results.pop(step, None)
                break
            time.sleep(0.001)
        return self.place_fn(batch)

    def __iter__(self) -> Iterator[Any]:
        step = 0
        while True:
            yield self.get(step)
            step += 1


def place_on_mesh(mesh, rules):
    """Returns a place_fn putting each array with its logical sharding."""
    from repro.distributed.sharding import named_sharding

    logical = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "mask": ("batch", "seq"),
        "vision_embeds": ("batch", "seq", "embed_act"),
    }

    def place(batch: Dict[str, np.ndarray]):
        out = {}
        for k, arr in batch.items():
            ns = named_sharding(arr.shape, logical[k], mesh, rules)
            out[k] = (jax.device_put(arr, ns) if ns is not None
                      else jax.numpy.asarray(arr))
        return out

    return place
