"""Train-step builder + fault-tolerant training loop.

``make_train_step`` closes over (model cfg, train cfg) and returns a pure
(params, opt_state, batch) -> (params, opt_state, metrics) function. All
sharding is injected by tracing under ``use_sharding(mesh, train_rules)``
— the same function lowers for 1 CPU device (smoke tests) and for the
256/512-chip production meshes (dry-run) unchanged.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.distributed.sharding import (RuleSet, train_rules,
                                        use_sharding)
from repro.models import model as lm
from repro.training import checkpoint as ckpt
from repro.training.optimizer import (OptState, adamw_update,
                                      init_opt_state)

log = logging.getLogger(__name__)
Params = Any


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig
                    ) -> Callable[[Params, OptState, Dict[str, Any]],
                                  Tuple[Params, OptState, Dict[str, Any]]]:
    def loss_fn(params, batch):
        return lm.loss_fn(params, cfg, batch, scan=tcfg.scan_layers,
                          remat=tcfg.remat, loss_chunk=tcfg.loss_chunk)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def split(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def accum(carry, mb_batch):
                g_acc, l_acc, m_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb_batch)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss, m_acc + metrics["ce"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, ce), _ = jax.lax.scan(
                accum, (zeros, 0.0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss, ce = loss / mb, ce / mb
            metrics = {"ce": ce}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg)
        out = {"loss": loss, **metrics, **opt_metrics}
        return params, opt_state, out

    return train_step


def jit_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh, rules=None,
                   in_shardings=None, out_shardings=None, donate: bool = True):
    """Trace the train step under the sharding context and jit it."""
    rules = rules or train_rules()
    step = make_train_step(cfg, tcfg)

    def traced(params, opt_state, batch):
        with use_sharding(mesh, rules):
            return step(params, opt_state, batch)

    kwargs = {}
    if in_shardings is not None:
        kwargs["in_shardings"] = in_shardings
    if out_shardings is not None:
        kwargs["out_shardings"] = out_shardings
    if donate:
        kwargs["donate_argnums"] = (0, 1)
    return jax.jit(traced, **kwargs)


# ---------------------------------------------------------------------------
# The loop.
# ---------------------------------------------------------------------------
class Trainer:
    """Checkpointed, resumable training loop with async saves."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, *,
                 mesh=None, rules: Optional[RuleSet] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 keep: int = 3):
        self.cfg, self.tcfg = cfg, tcfg
        self.mesh, self.rules = mesh, rules or train_rules()
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.step_fn = jit_train_step(cfg, tcfg, mesh, self.rules,
                                      donate=False)
        self._pending_save = None

    def init_state(self, seed: int = 0) -> Tuple[Params, OptState, int]:
        params = lm.init_params(self.cfg, jax.random.key(seed))
        opt_state = init_opt_state(params, self.tcfg)
        start = 0
        if self.ckpt_dir and ckpt.latest_step(self.ckpt_dir) is not None:
            start = ckpt.latest_step(self.ckpt_dir)
            tree = ckpt.restore(self.ckpt_dir,
                                {"params": params, "opt": opt_state})
            params, opt_state = tree["params"], tree["opt"]
            log.info("resumed from step %d", start)
        return params, opt_state, start

    def maybe_checkpoint(self, step: int, params: Params,
                         opt_state: OptState, force: bool = False) -> None:
        if not self.ckpt_dir:
            return
        if force or (step > 0 and step % self.ckpt_every == 0):
            if self._pending_save is not None:
                self._pending_save.wait()
            self._pending_save = ckpt.save_async(
                self.ckpt_dir, step, {"params": params, "opt": opt_state},
                keep=self.keep)

    def run(self, data_iter, steps: int, seed: int = 0,
            log_every: int = 10) -> Dict[str, list]:
        params, opt_state, start = self.init_state(seed)
        history: Dict[str, list] = {"step": [], "loss": [], "ce": [],
                                    "step_time_s": []}
        for step in range(start, steps):
            batch = data_iter.get(step) if hasattr(data_iter, "get") \
                else next(data_iter)
            t0 = time.monotonic()
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            history["step"].append(step)
            history["loss"].append(loss)
            history["ce"].append(float(metrics["ce"]))
            history["step_time_s"].append(dt)
            if step % log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
            self.maybe_checkpoint(step + 1, params, opt_state)
        self.maybe_checkpoint(steps, params, opt_state, force=True)
        if self._pending_save is not None:
            self._pending_save.wait()
        history["params"] = params          # type: ignore[assignment]
        history["opt_state"] = opt_state    # type: ignore[assignment]
        return history
