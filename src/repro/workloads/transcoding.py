"""Video-transcoding workload model (paper §4, Tables 3, Fig 6-10).

Transcoding itself is an x264/MediaCodec/NVENC workload with no TPU/JAX
analogue (DESIGN.md §2), so this module is *data-driven*: the vbench video
metadata and per-platform measured stream counts come from the paper's
Table 3 and figures, and the energy/TCO layers consume them to reproduce
the paper's comparisons (and to extrapolate to new platforms).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.cluster import soc_cluster


@dataclass(frozen=True)
class Video:
    vid: str
    name: str
    width: int
    height: int
    fps: int
    entropy: float            # bits/pixel/s proxy for scene complexity
    source_kbps: float
    target_kbps: float
    # Table 3: max simultaneous live streams per SoC
    soc_cpu_streams: int
    soc_hw_streams: int


VIDEOS: List[Video] = [
    Video("V1", "holi", 854, 480, 30, 7.0, 2800, 819.8, 13, 16),
    Video("V2", "desktop", 1280, 720, 30, 0.2, 181, 90.5, 15, 16),
    Video("V3", "game3", 1280, 720, 59, 6.1, 5600, 2700, 4, 12),
    Video("V4", "presentation", 1920, 1080, 25, 0.2, 430, 215, 9, 16),
    Video("V5", "hall", 1920, 1080, 29, 7.7, 16000, 4100, 3, 7),
    Video("V6", "chicken", 3840, 2160, 30, 5.9, 49000, 16600, 1, 2),
]

VIDEO_BY_ID = {v.vid: v for v in VIDEOS}

# Whole-server live-stream counts for the comparison platforms are
# back-derived from the paper's *published* Table 5 TpC (streams/$) and
# Table 4 monthly TCO — i.e. the paper's own measurements, not guesses.
# Monthly TCO: edge w/ GPU $1410, edge w/o GPU $399 (Table 4).
_PAPER_TPC_INTEL_NOGPU = {"V1": 0.627, "V2": 0.777, "V3": 0.200,
                          "V4": 0.351, "V5": 0.146, "V6": 0.047}
_PAPER_TPC_A40 = {"V1": 0.420, "V2": 0.210, "V3": 0.102, "V4": 0.181,
                  "V5": 0.114, "V6": 0.034}
_TCO_NOGPU_MONTHLY = 399.0
_TCO_GPU_MONTHLY = 1410.0
# Measured average power during live transcoding (Table 4 note): the whole
# 8xA40 server draws 1231 W; the CPU-only server 633 W.
_A40_SERVER_TRANSCODE_W = 1231.0
_INTEL_SERVER_TRANSCODE_W = 633.0


@dataclass(frozen=True)
class PlatformThroughput:
    platform: str
    streams: float            # whole-server live streams
    power_w: float            # measured power at that load

    @property
    def streams_per_watt(self) -> float:
        return self.streams / self.power_w


def soc_cluster_live(video: Video, hw_codec: bool = False
                     ) -> PlatformThroughput:
    spec = soc_cluster()
    per_soc = video.soc_hw_streams if hw_codec else video.soc_cpu_streams
    streams = per_soc * spec.n_units
    power = spec.power(spec.n_units, 1.0)
    if hw_codec:
        # Fig 8b: hardware codec gives 2.5x (low-entropy) to ~5x TpE;
        # power drops while streams rise.
        power = power * 0.55
    return PlatformThroughput(
        "soc-cluster-hw" if hw_codec else "soc-cluster-cpu", streams, power)


def intel_live(video: Video) -> PlatformThroughput:
    streams = _PAPER_TPC_INTEL_NOGPU[video.vid] * _TCO_NOGPU_MONTHLY
    return PlatformThroughput("intel-cpu", streams,
                              _INTEL_SERVER_TRANSCODE_W)


def a40_live(video: Video) -> PlatformThroughput:
    streams = _PAPER_TPC_A40[video.vid] * _TCO_GPU_MONTHLY
    return PlatformThroughput("a40-gpu", streams, _A40_SERVER_TRANSCODE_W)


# ---------------------------------------------------------------------------
# Network-bound analysis (Table 3 right half).
# ---------------------------------------------------------------------------
def network_usage(video: Video, hw_codec: bool = True) -> Dict[str, float]:
    """In+out traffic for one SoC running its max streams; PCB and server
    utilization, reproducing Table 3's bound analysis."""
    spec = soc_cluster()
    per_soc = video.soc_hw_streams if hw_codec else video.soc_cpu_streams
    per_stream_mbps = (video.source_kbps + video.target_kbps) / 1000.0
    soc_mbps = per_soc * per_stream_mbps
    pcb_mbps = soc_mbps * spec.group_size
    server_mbps = soc_mbps * spec.n_units
    return {
        "per_soc_mbps": soc_mbps,
        "per_pcb_mbps": pcb_mbps,
        "pcb_util": pcb_mbps / (spec.net_unit_gbps * 1000.0),
        "server_mbps": server_mbps,
        "server_util": server_mbps / (spec.net_shared_gbps * 1000.0),
    }


# Archive transcoding (Fig 6b): frames/J per platform per video,
# anchored to the paper's qualitative results (SoC > Intel always; A40
# wins on high-entropy, loses on V2/V4 low-entropy).
ARCHIVE_FPJ = {
    #          soc-cpu intel  a40
    "V1": (2.3, 0.9, 3.1),
    "V2": (9.5, 3.8, 5.6),
    "V3": (1.3, 0.5, 2.6),
    "V4": (4.1, 1.7, 2.9),
    "V5": (0.5, 0.2, 1.4),
    "V6": (0.13, 0.05, 0.6),
}
