"""DL-serving workload profiles (paper §5, Fig 11/12, Tables 5/7).

Latency/power reference points are the paper's measurements (Table 7
physical-SoC numbers where published); the executable side (benchmarks)
runs the actual JAX models on this host and scales through the
compute-ratio model to cross-check the shape of the comparisons.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ServingPoint:
    model: str
    precision: str            # fp32 | int8
    platform: str
    latency_ms: float         # batch-1 unless noted
    batch: int
    unit_power_w: float       # per serving unit at load
    units: int                # units per server

    @property
    def throughput(self) -> float:
        return 1000.0 / self.latency_ms * self.batch * self.units

    @property
    def samples_per_joule(self) -> float:
        return self.throughput / (self.unit_power_w * self.units)


# Paper Table 7 (physical SoC) + §5.1 text + A40/A100 figures (Fig 11).
PAPER_POINTS = [
    # SoC GPU / DSP (per-SoC; x60 for the cluster)
    ServingPoint("resnet-50", "fp32", "soc-gpu", 32.5, 1, 6.0, 60),
    ServingPoint("resnet-50", "int8", "soc-dsp", 8.8, 1, 4.0, 60),
    ServingPoint("resnet-152", "fp32", "soc-gpu", 100.9, 1, 6.0, 60),
    ServingPoint("resnet-152", "int8", "soc-dsp", 20.4, 1, 4.0, 60),
    ServingPoint("yolov5x", "fp32", "soc-gpu", 620.6, 1, 6.5, 60),
    ServingPoint("bert-base", "fp32", "soc-gpu", 93.0, 1, 6.0, 60),
    # Intel CPU (8-core container; x10 per server)
    ServingPoint("resnet-50", "fp32", "intel-cpu", 81.2, 1, 48.0, 10),
    ServingPoint("resnet-152", "fp32", "intel-cpu", 258.3, 1, 48.0, 10),
    ServingPoint("yolov5x", "fp32", "intel-cpu", 1121.3, 1, 48.0, 10),
    ServingPoint("bert-base", "fp32", "intel-cpu", 130.0, 1, 48.0, 10),
    # NVIDIA A40 (batch 64) / A100 (batch 64)
    ServingPoint("resnet-50", "fp32", "a40", 157.0, 64, 220.0, 8),
    ServingPoint("resnet-152", "fp32", "a40", 360.0, 64, 220.0, 8),
    ServingPoint("resnet-50", "fp32", "a100", 115.0, 64, 330.0, 1),
    ServingPoint("resnet-152", "fp32", "a100", 230.0, 64, 330.0, 1),
]


def point(model: str, precision: str, platform: str
          ) -> Optional[ServingPoint]:
    for p in PAPER_POINTS:
        if (p.model, p.precision, p.platform) == (model, precision,
                                                  platform):
            return p
    return None


# Key published ratios for validation (Fig 11b / §5.2 text).
PAPER_CLAIMS = {
    # SoC GPU resnet-50 fp32 vs Intel CPU: 7.09x; vs A40: 1.78x;
    # vs A100: 1.15x. DSP resnet-152 int8 vs Intel: 42x, vs A100: 1.5x.
    "r50_gpu_vs_intel": 7.09,
    "r50_gpu_vs_a40": 1.78,
    "r50_gpu_vs_a100": 1.15,
    "r152_dsp_vs_intel": 42.0,
    "max_tpe_vs_a40": 6.5,
    "light_load_vs_a100": 5.71,
}


# Host-measurable model set (executed by benchmarks/fig11): name ->
# (constructor module, flops estimate per sample).
EXECUTABLE_MODELS = {
    "resnet-50": 8.2e9,
    "resnet-152": 23.2e9,
    "yolov5x": 205e9 * 2 / 2,   # ~205 GMACs at 640x640 -> 410 GFLOPs? use half-res in bench
    "bert-base": 2 * 110e6 * 128,  # fwd, seq 128
}
