"""Config system for the repro framework.

Plain dataclasses (no external deps), a registry keyed by arch id, and
helpers to derive reduced "smoke" configs. Every assigned architecture in
``repro.configs`` registers a :class:`ModelConfig` here.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds for hybrid stacks.
# ---------------------------------------------------------------------------
ATTN = "attn"
MAMBA = "mamba"


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for a (subset of) layers."""

    num_experts: int
    top_k: int
    d_ff_expert: int                    # per-expert hidden width
    # Every `period`-th layer (offset `offset`) is MoE; others use dense FFN.
    period: int = 1
    offset: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # dispatch variant: "v1" (padded buffer + extra overflow row) or
    # "v2" (drop-mode scatter into an expert-flat buffer that shards
    # cleanly over the model axis — the EP-collective hillclimb lever)
    dispatch: str = "v1"

    def is_moe_layer(self, layer_idx: int) -> bool:
        return layer_idx % self.period == self.offset


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-2 (SSD) mixer settings."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-style LM backbone configuration.

    Covers dense / MoE / SSM / hybrid / modality-stub families with one
    schema. ``layer_pattern`` expands to a per-layer kind list for hybrids.
    """

    name: str
    family: str                         # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                      # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int                           # dense FFN hidden (0 if no dense FFN)
    vocab_size: int
    head_dim: int = 0                   # 0 => d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    max_seq_len: int = 1 << 20
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # 'attn'/'mamba' pattern; None => all-attn (or all-mamba for family=ssm).
    layer_pattern: Optional[Tuple[str, ...]] = None
    # attention implementation on the XLA (non-Pallas) path:
    # "ref" (materialized scores) | "chunked" (online-softmax q-chunks,
    # native-dtype dots — flash-attention access pattern in pure jnp)
    attn_impl: str = "ref"
    attn_chunk: int = 512
    # compute activation nonlinearities in the storage dtype (bf16) instead
    # of upcasting to fp32 (halves elementwise HBM traffic in the FFN)
    mlp_lowp: bool = False
    # Modality frontend stub: number of prepended embedding positions the
    # frontend contributes (patch/frame embeddings come precomputed via
    # input_specs()).
    frontend_tokens: int = 0
    frontend_dim: int = 0               # dim of precomputed frontend embeds
    dtype: str = "bfloat16"
    # Notes carried into DESIGN/EXPERIMENTS.
    source: str = ""

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            assert len(self.layer_pattern) == self.num_layers
            return self.layer_pattern
        if self.family == "ssm":
            return tuple([MAMBA] * self.num_layers)
        return tuple([ATTN] * self.num_layers)

    def is_moe_layer(self, layer_idx: int) -> bool:
        return self.moe is not None and self.moe.is_moe_layer(layer_idx)

    @property
    def uses_attention(self) -> bool:
        return any(k == ATTN for k in self.layer_kinds())

    @property
    def uses_mamba(self) -> bool:
        return any(k == MAMBA for k in self.layer_kinds())

    @property
    def subquadratic(self) -> bool:
        """True if the arch can run 500k-context decode per the spec
        (SSM/hybrid/linear-attention)."""
        return self.family in ("ssm", "hybrid")

    # ----- parameter counting (analytic; used for roofline MODEL_FLOPS) ----
    def param_counts(self) -> Dict[str, float]:
        d, hd = self.d_model, self.resolved_head_dim
        counts: Dict[str, float] = {}
        counts["embed"] = self.vocab_size * d
        counts["unembed"] = 0 if self.tie_embeddings else self.vocab_size * d
        attn_p = d * (self.num_heads * hd) * 2  # Wq + Wo
        attn_p += d * (self.num_kv_heads * hd) * 2  # Wk + Wv
        if self.qkv_bias:
            attn_p += (self.num_heads + 2 * self.num_kv_heads) * hd
        dense_ffn_p = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        mamba_p = 0.0
        if self.mamba is not None:
            di = self.mamba.d_inner(d)
            nh = self.mamba.n_heads(d)
            # in_proj -> (z, x, B, C, dt): 2*di + 2*d_state*? (heads share B,C
            # in SSD: B,C are (n_groups=1, d_state)); out_proj di->d.
            mamba_p = d * (2 * di + 2 * self.mamba.d_state + nh) + di * d
            mamba_p += di * self.mamba.d_conv + di  # conv + skip D
        total = counts["embed"] + counts["unembed"]
        active = total
        per_layer_total, per_layer_active = 0.0, 0.0
        for i, kind in enumerate(self.layer_kinds()):
            lt, la = 0.0, 0.0
            if kind == ATTN:
                lt += attn_p
                la += attn_p
            else:
                lt += mamba_p
                la += mamba_p
            if self.is_moe_layer(i):
                assert self.moe is not None
                e_p = 3 * d * self.moe.d_ff_expert
                lt += self.moe.num_experts * e_p + d * self.moe.num_experts
                la += self.moe.top_k * e_p + d * self.moe.num_experts
            elif self.d_ff:
                lt += dense_ffn_p
                la += dense_ffn_p
            lt += 2 * d  # norms
            la += 2 * d
            per_layer_total += lt
            per_layer_active += la
        counts["layers_total"] = per_layer_total
        counts["layers_active"] = per_layer_active
        counts["total"] = total + per_layer_total
        counts["active"] = active + per_layer_active
        return counts

    @property
    def num_params(self) -> float:
        return self.param_counts()["total"]

    @property
    def num_active_params(self) -> float:
        return self.param_counts()["active"]

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES: Dict[str, ShapeSpec] = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Per-spec applicability: (sanctioned, note).

    long_500k is sanctioned only for sub-quadratic archs; for pure
    full-attention archs we may still compile it as a *bonus* cell.
    """
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("spec-sanctioned skip: pure full-attention arch; "
                       "compiled as bonus cell (decode attention is O(S))")
    return True, ""


# ---------------------------------------------------------------------------
# Mesh / run configs.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        return int(math.prod(self.shape))

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axes


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    # remat: "none" | "full" | "dots" (checkpoint_dots policy)
    remat: str = "full"
    scan_layers: bool = True
    # optimizer state compression: "fp32" | "int8"
    opt_state_dtype: str = "fp32"
    # gradient compression on the DP all-reduce: "none" | "int8"
    grad_compression: str = "none"
    microbatches: int = 1               # grad accumulation
    # chunked cross-entropy: sequence-chunk size (0 = full logits)
    loss_chunk: int = 0
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 128
    quantize_weights: bool = False       # int8 weight-only serving path
    kv_cache_dtype: str = "bfloat16"
    serve_fsdp: bool = False             # shard serve weights over data too
    max_seq_len: int = 32768


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    mesh: MeshConfig = SINGLE_POD
    train: TrainConfig = TrainConfig()
    serve: ServeConfig = ServeConfig()


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str) -> Callable[[Callable[[], ModelConfig]], Callable[[], ModelConfig]]:
    def deco(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers registration)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced ("smoke") configs: same family, tiny dims, CPU-runnable.
# ---------------------------------------------------------------------------
def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to a CPU-runnable variant of the same family."""
    n_layers = min(cfg.num_layers, 4)
    d_model = 64
    n_heads = 4
    n_kv = max(1, min(cfg.num_kv_heads, 2)) if cfg.num_heads else 0
    if cfg.num_heads and cfg.num_kv_heads == cfg.num_heads:
        n_kv = n_heads  # preserve MHA-ness (musicgen)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            num_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32,
            period=cfg.moe.period, offset=cfg.moe.offset,
            capacity_factor=cfg.moe.capacity_factor,
        )
    mamba = None
    if cfg.mamba is not None:
        mamba = MambaConfig(d_state=16, d_conv=4, expand=2, headdim=16,
                            chunk_size=32)
    pattern = None
    if cfg.layer_pattern is not None:
        # Preserve the interleave flavor within the reduced depth.
        kinds = cfg.layer_kinds()
        # Keep at least one of each kind present in the original.
        pattern = tuple(kinds[i % len(kinds)] for i in range(n_layers))
        if MAMBA in kinds and MAMBA not in pattern:
            pattern = (MAMBA, *pattern[1:])
        if ATTN in kinds and ATTN not in pattern:
            pattern = (*pattern[:-1], ATTN)
    return cfg.replace(
        num_layers=n_layers, d_model=d_model, num_heads=n_heads if cfg.num_heads else 0,
        num_kv_heads=n_kv, d_ff=128 if cfg.d_ff else 0, vocab_size=512,
        head_dim=16 if cfg.num_heads else 0, moe=moe, mamba=mamba,
        layer_pattern=pattern, frontend_tokens=min(cfg.frontend_tokens, 8),
        frontend_dim=d_model if cfg.frontend_dim else 0,
        max_seq_len=4096,
    )
