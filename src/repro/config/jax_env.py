"""JAX environment knobs for the fleet engine and batched sweeps.

Two process-level switches the ``backend="jax"`` fleet engine depends
on (the idiom mirrors the elisa/numpyro helpers catalogued in
SNIPPETS.md 1-2):

* :func:`jax_enable_x64` — flip the global float64 flag. The jax fleet
  engine is tolerance-parity against the float64 numpy vector engine,
  so running it in jax's default float32 silently quadruples the error;
  the engine also wraps its own entry points in the scoped
  ``jax.experimental.enable_x64`` context, so this global helper is for
  scripts/CI that want the whole process in x64 (equivalently set
  ``JAX_ENABLE_X64=1`` before the first jax import).
* :func:`set_host_device_count` — make XLA expose ``n`` virtual CPU
  devices (``--xla_force_host_platform_device_count``) so a batched
  ``sweep()`` can shard its config axis with ``pmap``. Must run before
  jax initializes its backends; calling it later changes nothing for
  the current process (equivalently export
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
"""
from __future__ import annotations

import os
import re

__all__ = ["jax_enable_x64", "set_host_device_count"]


def jax_enable_x64(enable: bool = True) -> None:
    """Globally enable (or disable) 64-bit jax arithmetic."""
    import jax

    jax.config.update("jax_enable_x64", enable)


def set_host_device_count(n: int) -> None:
    """Force XLA to expose ``n`` host (CPU) devices.

    Rewrites ``XLA_FLAGS``, replacing any existing
    ``--xla_force_host_platform_device_count`` flag. Only effective
    before the process's first jax backend initialization.
    """
    xla_flags = os.getenv("XLA_FLAGS", "")
    rest = re.sub(
        r"--xla_force_host_platform_device_count=\S+", "", xla_flags
    ).split()
    os.environ["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={int(n)}", *rest]
    )
