from repro.config.base import (
    ATTN, MAMBA,
    ALL_SHAPES, SHAPES, SINGLE_POD, MULTI_POD,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    MambaConfig, MeshConfig, ModelConfig, MoEConfig, RunConfig,
    ServeConfig, ShapeSpec, TrainConfig,
    get_config, list_configs, register, shape_applicable, smoke_config,
)
from repro.config.jax_env import jax_enable_x64, set_host_device_count

__all__ = [
    "jax_enable_x64", "set_host_device_count",
    "ATTN", "MAMBA", "ALL_SHAPES", "SHAPES", "SINGLE_POD", "MULTI_POD",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "MambaConfig", "MeshConfig", "ModelConfig", "MoEConfig", "RunConfig",
    "ServeConfig", "ShapeSpec", "TrainConfig",
    "get_config", "list_configs", "register", "shape_applicable",
    "smoke_config",
]
