"""Serving engine: jit'd prefill / decode with full-length caches.

Decode caches live at ``max_seq_len`` from the start (the dry-run decode
cells take them as inputs); prefill writes the first ``s`` positions and the
engine pads. Weight-only int8 serving (the paper's DSP path) is applied at
load time via ``ServeConfig.quantize_weights``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ServeConfig
from repro.distributed.sharding import RuleSet, serve_rules, use_sharding
from repro.kernels.ref import quantize_int8
from repro.models import model as lm

Params = Any


def quantize_params_int8(params: Params) -> Params:
    """Weight-only int8: store int8 payload + per-output-channel scales,
    dequantized on use. (Serving-only; halves/quarters weight HBM.)"""
    def q(leaf):
        if leaf.ndim >= 2 and leaf.dtype in (jnp.bfloat16, jnp.float32):
            qv, s = quantize_int8(leaf, axis=-2)  # per-column of last dim
            return {"__int8__": qv, "scale": s}
        return leaf
    return jax.tree.map(q, params)


def dequantize_params(params: Params) -> Params:
    def dq(leaf):
        if isinstance(leaf, dict) and "__int8__" in leaf:
            return (leaf["__int8__"].astype(jnp.float32)
                    * leaf["scale"][..., None, :]).astype(jnp.bfloat16)
        return leaf
    return jax.tree.map(dq, params,
                        is_leaf=lambda l: isinstance(l, dict)
                        and "__int8__" in l)




class ServingEngine:
    def __init__(self, cfg: ModelConfig, scfg: Optional[ServeConfig] = None,
                 mesh=None, rules: Optional[RuleSet] = None,
                 scan: bool = True):
        self.cfg = cfg
        self.scfg = scfg or ServeConfig()
        self.mesh = mesh
        self.rules = rules or serve_rules(self.scfg.serve_fsdp)
        self.scan = scan
        self.params: Optional[Params] = None

        def _prefill(params, batch):
            with use_sharding(self.mesh, self.rules):
                if self.scfg.quantize_weights:
                    params = dequantize_params(params)
                return lm.prefill(params, cfg, batch, scan=self.scan,
                                  max_len=self.scfg.max_seq_len)

        def _decode(params, tokens, caches, pos):
            with use_sharding(self.mesh, self.rules):
                if self.scfg.quantize_weights:
                    params = dequantize_params(params)
                return lm.decode_step(params, cfg, tokens, caches, pos,
                                      scan=self.scan)

        self.prefill_fn = jax.jit(_prefill)
        self.decode_fn = jax.jit(_decode, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def load(self, params: Params) -> None:
        if self.scfg.quantize_weights:
            params = quantize_params_int8(params)
        self.params = params

    def init_random(self, seed: int = 0) -> None:
        self.load(lm.init_params(self.cfg, jax.random.key(seed)))

    # ------------------------------------------------------------------
    def generate(self, tokens: jax.Array, max_new_tokens: int,
                 vision_embeds: Optional[jax.Array] = None,
                 greedy: bool = True, rng: Optional[jax.Array] = None
                 ) -> jax.Array:
        """tokens: (b, s) -> (b, max_new_tokens) generated ids."""
        assert self.params is not None, "call load()/init_random() first"
        b, s = tokens.shape
        batch: Dict[str, Any] = {"tokens": tokens}
        if vision_embeds is not None:
            batch["vision_embeds"] = vision_embeds
            s = s + vision_embeds.shape[1]
        logits, caches = self.prefill_fn(self.params, batch)
        out = []
        pos = s
        for _ in range(max_new_tokens):
            if greedy:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                rng, k = jax.random.split(rng)
                nxt = jax.random.categorical(k, logits).astype(jnp.int32)
            out.append(nxt)
            logits, caches = self.decode_fn(
                self.params, nxt[:, None], caches, pos)
            pos += 1
        return jnp.stack(out, axis=1)
