"""Slot-based continuous batching.

Fixed B decode slots; finished slots are refilled from the queue without
draining the batch (per-slot sequence positions — the attention layer takes
a (b,) position vector). Prefill runs per-request at batch 1 and the fresh
cache is inserted into the batched cache at the slot index.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as lm
from repro.serving.engine import ServingEngine


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (s,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, engine: ServingEngine, slots: int):
        self.engine = engine
        self.cfg = engine.cfg
        self.slots = slots
        self.queue: Deque[Request] = deque()   # O(1) FIFO admission
        self.active: List[Optional[Request]] = [None] * slots
        self.finished: List[Request] = []
        self.positions = np.zeros(slots, np.int64)
        self.tokens = np.zeros(slots, np.int64)
        self.caches = None
        self._rid = itertools.count()
        self._insert_fns: Dict[int, Any] = {}

        def _insert(caches, cache1, slot):
            def ins(big, small):
                return jax.lax.dynamic_update_index_in_dim(
                    big, small[0], slot, axis=0)
            # caches leaves: (nb, b, ...); cache1 leaves: (nb, 1, ...)
            return jax.tree.map(
                lambda big, small: jax.vmap(
                    lambda bg, sm: jax.lax.dynamic_update_index_in_dim(
                        bg, sm[0], slot, axis=0))(big, small),
                caches, cache1)

        self._insert_jit = jax.jit(_insert, static_argnums=(2,),
                                   donate_argnums=(0,))

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens))
        return rid

    def _ensure_caches(self) -> None:
        if self.caches is None:
            self.caches = lm.init_caches(
                self.cfg, self.slots, self.engine.scfg.max_seq_len)

    def _admit(self, max_slots: Optional[int] = None) -> None:
        limit = self.slots if max_slots is None else min(max_slots,
                                                         self.slots)
        busy = sum(a is not None for a in self.active)
        for slot in range(self.slots):
            if busy >= limit or not self.queue:
                break
            if self.active[slot] is not None:
                continue
            busy += 1
            req = self.queue.popleft()
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            logits, cache1 = self.engine.prefill_fn(self.engine.params,
                                                    batch)
            self._ensure_caches()
            self.caches = self._insert_jit(self.caches, cache1, slot)
            nxt = int(jnp.argmax(logits[0]))
            req.generated.append(nxt)
            self.active[slot] = req
            self.positions[slot] = len(req.prompt)
            self.tokens[slot] = nxt

    def step(self, max_slots: Optional[int] = None) -> int:
        """One engine tick: admit (up to ``max_slots`` concurrent — the
        runtime's activation gate) + one batched decode. Returns number
        of active slots. Requests already in flight keep decoding even if
        ``max_slots`` drops below the current occupancy; the cap throttles
        admission only."""
        self._admit(max_slots)
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return 0
        self._ensure_caches()
        toks = jnp.asarray(self.tokens[:, None], jnp.int32)
        pos = jnp.asarray(self.positions, jnp.int32)
        logits, self.caches = self.engine.decode_fn(
            self.engine.params, toks, self.caches, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in live:
            req = self.active[s]
            req.generated.append(int(nxt[s]))
            self.positions[s] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.active[s] = None
                self.finished.append(req)
            else:
                self.tokens[s] = int(nxt[s])
        return len(live)

    def run_to_completion(self, max_ticks: int = 10000) -> List[Request]:
        start = len(self.finished)
        for _ in range(max_ticks):
            if not self.queue and all(a is None for a in self.active):
                break
            self.step()
        return self.finished[start:]
