"""Energy-proportional serving autoscaler (paper §5.2 / Fig 12 as a policy).

Wraps ``core.scheduler.ElasticScheduler``'s policy for the serving engine:
arrivals are recorded, the offered rate is estimated over a sliding window,
and the pod's data-parallel replicas (mesh slices ≙ SoCs) are activated or
gated to track the load. Energy is accounted through the cluster spec so
benchmarks can report TpE under dynamic load.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.scheduler import ScalePolicy


@dataclass
class AutoscalerReport:
    ticks: int
    mean_active: float
    energy_j: float
    served: int
    tpe: float
    scale_events: int


class ServingAutoscaler:
    def __init__(self, spec: ClusterSpec, unit_rate_rps: float,
                 policy: Optional[ScalePolicy] = None,
                 window_s: float = 10.0):
        self.spec = spec
        self.unit_rate = unit_rate_rps
        self.policy = policy or ScalePolicy()
        self.window_s = window_s
        self.arrivals: List[float] = []
        self.active_units = self.policy.min_units
        self._last_downscale = -1e9
        self._energy = 0.0
        self._served = 0
        self._ticks = 0
        self._active_hist: List[int] = []
        self._scale_events = 0

    def record_arrival(self, t: float, n: int = 1) -> None:
        self.arrivals.extend([t] * n)

    def offered_rate(self, t: float) -> float:
        cutoff = t - self.window_s
        self.arrivals = [a for a in self.arrivals if a >= cutoff]
        return len(self.arrivals) / self.window_s

    def tick(self, t: float, served_this_tick: int, dt_s: float = 1.0
             ) -> int:
        """Update the activation target; charge energy. Returns the number
        of active replicas to use for the next tick."""
        rate = self.offered_rate(t)
        need = rate * self.policy.headroom / self.unit_rate
        tgt = int(min(self.spec.n_units,
                      max(self.policy.min_units, np.ceil(need))))
        if tgt > self.active_units:
            self.active_units = tgt
            self._scale_events += 1
        elif tgt < self.active_units and \
                t - self._last_downscale > self.policy.cooldown_s:
            self.active_units = tgt
            self._last_downscale = t
            self._scale_events += 1
        util = min(1.0, rate / max(self.active_units * self.unit_rate,
                                   1e-9))
        self._energy += self.spec.power(self.active_units, util,
                                        idle_units_off=True) * dt_s
        self._served += served_this_tick
        self._ticks += 1
        self._active_hist.append(self.active_units)
        return self.active_units

    def report(self) -> AutoscalerReport:
        return AutoscalerReport(
            ticks=self._ticks,
            mean_active=float(np.mean(self._active_hist))
            if self._active_hist else 0.0,
            energy_j=self._energy,
            served=self._served,
            tpe=self._served / max(self._energy, 1e-9),
            scale_events=self._scale_events,
        )
