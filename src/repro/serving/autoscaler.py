"""DEPRECATED shim — energy-proportional serving autoscaler.

The autoscaler's policy/accounting now lives in
:class:`repro.runtime.UnitGovernor`, and the canonical serving loop —
where the activation target actually gates batcher slots — is
:class:`repro.runtime.ClusterRuntime` (paper §5.2 / Fig 12). This module
keeps the old ``ServingAutoscaler`` surface working on top of the
governor; ``AutoscalerReport`` is an alias of the unified
:class:`repro.runtime.Telemetry`.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.core.cluster import ClusterSpec
from repro.runtime.cluster_runtime import UnitGovernor
from repro.runtime.policy import ScalePolicy
from repro.runtime.result import Telemetry

AutoscalerReport = Telemetry


class ServingAutoscaler:
    """Deprecated: use ``ClusterRuntime`` (or ``UnitGovernor`` directly).

    Thin adapter that preserves the seed API: ``record_arrival(t, n)``,
    ``tick(t, served_this_tick, dt_s) -> active_units``, and
    ``report() -> AutoscalerReport`` (now a ``Telemetry``).
    """

    def __init__(self, spec: ClusterSpec, unit_rate_rps: float,
                 policy: Optional[ScalePolicy] = None,
                 window_s: float = 10.0):
        warnings.warn(
            "ServingAutoscaler is deprecated; use "
            "repro.runtime.ClusterRuntime (gates concurrency for real) "
            "or repro.runtime.UnitGovernor (policy + accounting only)",
            DeprecationWarning, stacklevel=2)
        self.spec = spec
        self.unit_rate = unit_rate_rps
        self.governor = UnitGovernor(spec, unit_rate_rps, policy,
                                     window_s=window_s)
        self.policy = self.governor.policy

    # -- seed API ----------------------------------------------------------
    @property
    def active_units(self) -> int:
        return self.governor.active_units

    def record_arrival(self, t: float, n: int = 1) -> None:
        self.governor.record_arrival(t, n)

    def offered_rate(self, t: float) -> float:
        return self.governor.offered_rate(t)

    def tick(self, t: float, served_this_tick: int, dt_s: float = 1.0
             ) -> int:
        """Update the activation target; charge energy. Returns the number
        of active replicas to use for the next tick."""
        active = self.governor.update(t, dt_s)
        rate = self.governor.offered_rate(t)
        util = min(1.0, rate / max(active * self.unit_rate, 1e-9))
        self.governor.charge(t, util, dt_s, served=served_this_tick)
        return active

    def report(self) -> Telemetry:
        return self.governor.telemetry()
