"""Jit'd dispatch wrappers for the Pallas kernels.

The model stack calls these; a process-global mode selects the backend:

* ``reference`` (default) — pure-jnp oracles from :mod:`repro.kernels.ref`.
  Used on CPU (this container) and for the dry-run/roofline lowering.
* ``interpret`` — Pallas kernels executed with ``interpret=True`` (kernel
  body runs in Python on CPU). Used by the kernel test suite.
* ``tpu`` — Pallas kernels compiled for real TPUs (the deploy target).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention as _pl_decode
from repro.kernels.flash_attention import flash_attention as _pl_flash
from repro.kernels.int8_matmul import int8_matmul as _pl_int8
from repro.kernels.rmsnorm import rmsnorm as _pl_rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _pl_ssd

_MODE = "reference"
_VALID = ("reference", "interpret", "tpu")


def set_mode(mode: str) -> None:
    global _MODE
    assert mode in _VALID, mode
    _MODE = mode


def get_mode() -> str:
    return _MODE


@contextlib.contextmanager
def kernel_mode(mode: str):
    old = _MODE
    set_mode(mode)
    try:
        yield
    finally:
        set_mode(old)


def _interp() -> bool:
    return _MODE == "interpret"


# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps: float = 1e-5, lowp: bool = False):
    if _MODE == "reference":
        if lowp:
            return _ref.rmsnorm_lowp(x, w, eps)
        return _ref.rmsnorm_ref(x, w, eps)
    return _pl_rmsnorm(x, w, eps=eps, interpret=_interp())


def attention(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
              q_offset: int = 0, kv_len=None, impl: str = "ref",
              chunk: int = 512):
    if _MODE == "reference" or kv_len is not None or q_offset:
        # Pallas prefill kernel covers the self-attention (no-cache) case;
        # masked/offset variants stay on the reference path.
        if impl.startswith("chunked") and kv_len is None and not q_offset:
            if impl == "chunked_kvrep":
                # GQA sharding fix for the XLA path: the (hkv, g) reshape
                # can't shard either factor over a 16-way model axis, so
                # scores replicate. Expanding KV to hq heads keeps the
                # flat head dim sharded (cheap: KV is tiny next to the
                # O(s^2) scores it de-replicates). The repeat output MUST
                # be re-constrained or it replicates too.
                from repro.distributed.sharding import shard as _shard
                g = q.shape[2] // k.shape[2]
                if g > 1:
                    k = _shard(jnp.repeat(k, g, axis=2),
                               ("batch", "seq", "heads_act", None))
                    v = _shard(jnp.repeat(v, g, axis=2),
                               ("batch", "seq", "heads_act", None))
            return _ref.attention_chunked(q, k, v, causal=causal,
                                          scale=scale, chunk=chunk)
        return _ref.attention_ref(q, k, v, causal=causal, scale=scale,
                                  q_offset=q_offset, kv_len=kv_len)
    return _pl_flash(q, k, v, causal=causal, scale=scale,
                     interpret=_interp())


def decode_attention(q, k, v, length, *, scale: Optional[float] = None,
                     impl: str = "ref"):
    if _MODE == "reference":
        if impl == "chunked":   # "chunked" config selects low-cast decode
            return _ref.decode_attention_lowcast(q, k, v, length,
                                                 scale=scale)
        return _ref.decode_attention_ref(q, k, v, length, scale=scale)
    return _pl_decode(q, k, v, length, scale=scale, interpret=_interp())


def int8_matmul(x_q, sx, w_q, sw, out_dtype=jnp.float32):
    if _MODE == "reference":
        return _ref.int8_matmul_ref(x_q, sx, w_q, sw).astype(out_dtype)
    return _pl_int8(x_q, sx, w_q, sw, out_dtype=out_dtype,
                    interpret=_interp())


def ssd(x, dt, A, B, C, D, *, chunk: int = 128):
    """Returns (y, final_state (b,h,p,n) fp32)."""
    if _MODE == "reference":
        return _ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    return _pl_ssd(x, dt, A, B, C, D, chunk=chunk, interpret=_interp())


quantize_int8 = _ref.quantize_int8
