"""Causal GQA flash attention (prefill/train) as a Pallas TPU kernel.

TPU-native adaptation: q/k/v tiles are staged HBM->VMEM via BlockSpec, the
MXU consumes (block_q x d) @ (d x block_k) tiles, and the online-softmax
running statistics live in VMEM scratch that persists across the innermost
(sequential) kv grid dimension. GQA is handled by index-mapping kv blocks
with ``head // group`` so KV is never materialized per-q-head.

Oracle: ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # With causal masking, kv blocks strictly above the diagonal contribute
    # nothing; skip their compute (they are still iterated by the grid).
    run = (not causal) or (ki * block_k <= qi * block_q + (block_q - 1))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                        # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (b, sq, hq, d); k, v: (b, skv, hkv, d) -> (b, sq, hq, d)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)

    # (b*hq, sq, d) rows; kv folded to (b*hkv, skv, d).
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)

    def kv_index(h, qi, ki):
        # head-major layout: q row h = bi*hq + qh; kv row = bi*hkv + qh//g
        bi = h // hq
        qh = h % hq
        return (bi * hkv + qh // g, ki, 0)

    grid = (b * hq, sq // block_q, skv // block_k)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
