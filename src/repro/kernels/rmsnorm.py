"""Fused RMSNorm as a Pallas TPU kernel (row-tiled, fp32 statistics).

Oracle: ``ref.rmsnorm_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # Pad rows to a multiple of the block (kernel output is sliced back).
    pad = (-rows) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    nrows = xr.shape[0]
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(nrows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, d), x.dtype),
        interpret=interpret,
    )(xr, w.reshape(1, d))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
