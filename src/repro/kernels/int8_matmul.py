"""W8A8 int8 matmul with per-row/per-column scales, as a Pallas TPU kernel.

This is the TPU-native analogue of the paper's INT8-on-Hexagon-DSP serving
path (its most energy-efficient configuration): int8 x int8 -> int32 MXU
accumulation, dequantized once in the epilogue with per-channel scales.

Oracle: ``ref.int8_matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_scr):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(ki == nk - 1)
    def _finalize():
        sx = sx_ref[...].astype(jnp.float32)       # (bm, 1)
        sw = sw_ref[...].astype(jnp.float32)       # (1, bn)
        o_ref[...] = (acc_scr[...].astype(jnp.float32) * sx * sw
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype",
                     "interpret"))
def int8_matmul(x_q: jax.Array, sx: jax.Array, w_q: jax.Array,
                sw: jax.Array, *, block_m: int = 128, block_n: int = 128,
                block_k: int = 512, out_dtype=jnp.float32,
                interpret: bool = False) -> jax.Array:
    """x_q: (m, k) int8; sx: (m,); w_q: (k, n) int8; sw: (n,) -> (m, n)."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_m, 1), lambda mi, ni, ki: (mi, 0)),
            pl.BlockSpec((1, block_n), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, sx.reshape(m, 1), sw.reshape(1, n))
