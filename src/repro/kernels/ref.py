"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: each Pallas kernel in this package is
asserted allclose against the function here across shape/dtype sweeps
(tests/test_kernels_*.py), and the model stack uses these implementations
on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def rmsnorm_lowp(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 *statistics* but storage-dtype wide ops: the
    (b, s, d) multiply chain (and its backward) stays bf16; only the
    per-row variance reduction upcasts. Halves norm HBM traffic."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)      # (b, s, 1)
    return x * inv * w.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (prefill / train): GQA, causal or full.
# q: (b, sq, hq, d)   k, v: (b, skv, hkv, d)
# ---------------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, scale: float | None = None,
                  q_offset: int = 0, kv_len: jax.Array | None = None
                  ) -> jax.Array:
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qf = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    mask = None
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(skv)[None, :]
        mask = qi >= ki
    if kv_len is not None:
        lmask = jnp.arange(skv)[None, :] < jnp.asarray(kv_len).reshape(-1, 1)
        lmask = lmask.reshape(b, 1, 1, 1, skv)
        scores = jnp.where(lmask, scores, NEG_INF)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention: single query token against a (possibly longer) cache.
# q: (b, hq, d)   k, v: (b, skv, hkv, d)   length: (b,) valid cache length
# ---------------------------------------------------------------------------
def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         length: jax.Array, *, scale: float | None = None
                         ) -> jax.Array:
    out = attention_ref(q[:, None], k, v, causal=False, scale=scale,
                        kv_len=length)
    return out[:, 0]


# ---------------------------------------------------------------------------
# Int8 W8A8 matmul with per-channel scales (the paper's INT8-on-DSP analog).
# x_q: (m, k) int8, sx: (m,) f32;  w_q: (k, n) int8, sw: (n,) f32
# ---------------------------------------------------------------------------
def int8_matmul_ref(x_q: jax.Array, sx: jax.Array, w_q: jax.Array,
                    sw: jax.Array) -> jax.Array:
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx[:, None] * sw[None, :]


def quantize_int8(x: jax.Array, axis: int = -1) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row (along `axis` reduced) int8 quantization."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.where(amax == 0, 1.0, amax / 127.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), jnp.squeeze(scale, axis=axis)


# ---------------------------------------------------------------------------
# Mamba-2 SSD: sequential-recurrence oracle.
# x:  (b, s, h, p)    per-head inputs (p = headdim)
# dt: (b, s, h)       positive step sizes (already softplus'ed + bias)
# A:  (h,)            negative per-head decay rates
# B:  (b, s, n)       shared across heads (ngroups=1), n = d_state
# C:  (b, s, n)
# D:  (h,)            skip
# Returns y: (b, s, h, p) and final state (b, h, p, n).
# ---------------------------------------------------------------------------
def ssd_ref(x, dt, A, B, C, D, init_state=None):
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    decay = jnp.exp(dtf * Af[None, None, :])            # (b, s, h)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, t):
        a_t = decay[:, t]                                # (b, h)
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dtf[:, t], xf[:, t], Bf[:, t])
        state = state * a_t[..., None, None] + dbx
        y_t = jnp.einsum("bhpn,bn->bhp", state, Cf[:, t])
        return state, y_t

    state, ys = jax.lax.scan(step, init_state.astype(jnp.float32),
                             jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)                           # (b, s, h, p)
    y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_decode_ref(x, dt, A, B, C, D, state):
    """One-token SSD recurrence. x: (b,h,p), dt: (b,h), B/C: (b,n),
    state: (b,h,p,n) -> (y, new_state)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32)[None, :])
    dbx = jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, B.astype(jnp.float32))
    state = state * a[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", state, C.astype(jnp.float32))
    y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), state


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, scale: float | None = None,
                      chunk: int = 512) -> jax.Array:
    """Query-chunked attention with native-dtype MXU dots (fp32 accumulation
    via preferred_element_type, no operand upcasts) and an online softmax.

    The (s x s) score tensor never materializes: peak extra memory is
    O(chunk x s) per layer instead of O(s^2) — the flash-attention access
    pattern expressed in pure jnp (the Pallas kernel is the TPU-native
    version; this path is what the XLA reference build lowers).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    chunk = min(chunk, sq)
    assert sq % chunk == 0
    nc = sq // chunk
    qr = q.reshape(b, nc, chunk, hkv, g, d)
    qs = jnp.moveaxis(qr, 1, 0)                      # (nc, b, c, hkv, g, d)

    @jax.checkpoint
    def one_chunk(args):
        qc, ci = args
        s = jax.lax.dot_general(
            qc, k,
            (((4,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=jnp.float32)       # (b, hkv, c, g, skv)
        s = s * scale
        if causal:
            rows = ci * chunk + jnp.arange(chunk)
            mask = rows[:, None] >= jnp.arange(skv)[None, :]
            s = jnp.where(mask[None, None, :, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((4,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32)       # (b, hkv, c, g, d)
        return o.astype(q.dtype)

    outs = jax.lax.map(one_chunk, (qs, jnp.arange(nc)))
    # (nc, b, hkv, c, g, d) -> (b, s, hq, d)
    outs = jnp.moveaxis(outs, 0, 1)                   # (b, nc, hkv, c, g, d)
    outs = jnp.moveaxis(outs, 2, 3)                   # (b, nc, c, hkv, g, d)
    return outs.reshape(b, sq, hq, d)


def decode_attention_lowcast(q: jax.Array, k: jax.Array, v: jax.Array,
                             length: jax.Array, *,
                             scale: float | None = None) -> jax.Array:
    """Decode attention without upcasting the KV cache: bf16/fp8 operands
    feed the dot directly with fp32 accumulation; only the (b, h, skv)
    scores run in fp32."""
    b, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qr = q.reshape(b, hkv, g, d).astype(k.dtype)
    s = jax.lax.dot_general(
        qr, k, (((3,), (3,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32) * scale   # (b, hkv, g, skv)
    lmask = jnp.arange(skv)[None, None, None, :] < \
        jnp.asarray(length).reshape(b, 1, 1, 1)
    s = jnp.where(lmask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jax.lax.dot_general(
        p.astype(v.dtype), v, (((3,), (1,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32)           # (b, hkv, g, d)
    return o.reshape(b, hq, d).astype(q.dtype)


def ssd_chunked(x, dt, A, B, C, D, chunk: int = 256, init_state=None):
    """Vectorized chunked SSD (same math as the Pallas kernel) in pure jnp.

    This is the production non-Pallas path: the scan runs over s/chunk
    boundaries only, so the backward pass stashes O(s/chunk) states instead
    of O(s) (the sequential oracle ``ssd_ref`` keeps one per timestep).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32
    xr = x.astype(f32).reshape(b, nc, chunk, h, p)
    dtr = dt.astype(f32).reshape(b, nc, chunk, h)
    Br = B.astype(f32).reshape(b, nc, chunk, n)
    Cr = C.astype(f32).reshape(b, nc, chunk, n)
    Af = A.astype(f32)

    l = dtr * Af[None, None, None, :]                    # (b,nc,Q,h)
    L = jnp.cumsum(l, axis=2)                            # inclusive
    # intra-chunk: M[t,j] = (C_t.B_j) exp(L_t - L_j) [j<=t]
    cb = jnp.einsum("bctn,bcjn->bctj", Cr, Br)           # (b,nc,Q,Q)
    logdec = L[:, :, :, None, :] - L[:, :, None, :, :]   # (b,nc,Q,Q,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = cb[..., None] * jnp.exp(
        jnp.where(tri[None, None, :, :, None], logdec, NEG_INF))
    y_intra = jnp.einsum("bctjh,bcjh,bcjhp->bcthp", M, dtr, xr)

    # chunk summaries: G_c = sum_j exp(L_last - L_j) dt_j B_j (x) x_j
    w = jnp.exp(L[:, :, -1:, :] - L) * dtr               # (b,nc,Q,h)
    G = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Br, w, xr)  # (b,nc,h,n,p)
    a_chunk = jnp.exp(L[:, :, -1])                       # (b,nc,h)

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), f32)
    st0 = jnp.swapaxes(init_state, -1, -2)               # (b,h,n,p)

    def step(carry, inp):
        g_c, a_c = inp                                   # (b,h,n,p),(b,h)
        h_in = carry
        h_out = h_in * a_c[..., None, None] + g_c
        return h_out, h_in                               # emit state BEFORE

    (h_last, h_ins) = jax.lax.scan(
        step, st0, (jnp.moveaxis(G, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                    # (b,nc,h,n,p)
    y_inter = jnp.einsum("bctn,bcth,bchnp->bcthp",
                         Cr, jnp.exp(L), h_ins)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), jnp.swapaxes(h_last, -1, -2)


# ---------------------------------------------------------------------------
# SwiGLU
# ---------------------------------------------------------------------------
def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)
