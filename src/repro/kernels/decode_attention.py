"""Flash-decode attention: one query token vs a long KV cache, as a Pallas
TPU kernel with per-batch valid-length masking.

The kv axis is the innermost (sequential) grid dimension; online-softmax
stats persist in VMEM scratch. Valid lengths arrive via scalar prefetch
(SMEM) so block masking is computed before the VMEM tiles are touched.

Oracle: ``ref.decode_attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(length_ref, q_ref, k_ref, v_ref, o_ref,
                m_scr, l_scr, acc_scr, *,
                scale: float, block_k: int, hq: int, g: int):
    h = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    bi = h // hq

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = length_ref[bi]
    # Skip fully-invalid blocks.
    @pl.when(ki * block_k < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (1, d)
        k = k_ref[0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # (1, bk)
        pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     length: jax.Array, *, scale: Optional[float] = None,
                     block_k: int = 256, interpret: bool = False
                     ) -> jax.Array:
    """q: (b, hq, d); k, v: (b, skv, hkv, d); length: (b,) -> (b, hq, d)."""
    b, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    block_k = min(block_k, skv)
    assert skv % block_k == 0

    qr = q.reshape(b * hq, 1, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)

    def kv_index(h, ki, length):  # scalar-prefetch ref comes last
        bi = h // hq
        qh = h % hq
        return (bi * hkv + qh // g, ki, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hq, skv // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda h, ki, length: (h, 0, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, ki, length: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, block_k=block_k,
                          hq=hq, g=g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        interpret=interpret,
    )(length.astype(jnp.int32), qr, kr, vr)
    return out.reshape(b, hq, d)
