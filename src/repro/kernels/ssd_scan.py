"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Within a chunk of Q timesteps the SSD duality turns the recurrence into two
MXU matmuls (the (Q,Q) masked-decay "attention" and the inter-chunk state
read); the (p, n) running state lives in VMEM scratch and is carried across
the sequential chunk grid dimension — the TPU-native replacement for a
sequential scan over 500k steps.

    y_t = C_t . ( exp(L_t) h_in + sum_{j<=t} exp(L_t - L_j) dt_j B_j x_j )
    h_out = exp(L_last) h_in + sum_j exp(L_last - L_j) dt_j B_j x_j

with l_t = dt_t * A_h (A_h < 0), L = inclusive cumsum(l).

Oracle: ``ref.ssd_ref`` (sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(a_coef_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref,
                state_scr, *, chunk: int, nheads: int):
    h = pl.program_id(0)
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    a = a_coef_ref[h]                                   # A_h (negative)
    x = x_ref[0].astype(jnp.float32)                    # (Q, p)
    dt = dt_ref[0].astype(jnp.float32)                  # (Q, 1) -> (Q,)
    dt = dt.reshape(chunk)
    B = b_ref[0].astype(jnp.float32)                    # (Q, n)
    C = c_ref[0].astype(jnp.float32)                    # (Q, n)

    l = dt * a                                          # (Q,)
    L = jnp.cumsum(l)                                   # inclusive
    # intra-chunk: M[t, j] = (C_t . B_j) exp(L_t - L_j) [j <= t]
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    logdecay = L[:, None] - L[None, :]
    M = cb * jnp.exp(jnp.where(rows >= cols, logdecay, NEG_INF))
    y = jax.lax.dot_general(M, x * dt[:, None], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, p)
    # inter-chunk: y += exp(L_t) * (C_t . h_in);  state is (n, p)
    y += jnp.exp(L)[:, None] * jax.lax.dot_general(
        C, state_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update
    w = jnp.exp(L[-1] - L) * dt                         # (Q,)
    state_scr[...] = jnp.exp(L[-1]) * state_scr[...] + jax.lax.dot_general(
        B * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (n, p)

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_ref[0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, D: jax.Array, *, chunk: int = 128,
             interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h); A,D: (h,); B,C: (b, s, n).

    Returns (y: (b, s, h, p), final_state: (b, h, n, p))  [fp32 state].
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    xr = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(b * h, s, 1)
    a_coef = jnp.tile(A.astype(jnp.float32), b)         # (b*h,)

    def bc_index(bh, ci, a_ref):
        return (bh // h, ci, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci, a: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci, a: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, n), bc_index),
            pl.BlockSpec((1, chunk, n), bc_index),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci, a: (bh, ci, 0)),
            pl.BlockSpec((1, n, p), lambda bh, ci, a: (bh, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
    )
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, nheads=h),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(a_coef, xr, dtr, B, C)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    y = y + x.astype(jnp.float32).astype(x.dtype) * D.astype(x.dtype)[None, None, :, None]
    state = state.reshape(b, h, n, p).transpose(0, 1, 3, 2)  # (b, h, p, n)
    return y, state
