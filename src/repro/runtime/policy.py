"""Unit-activation policy (paper §5.2): how a cluster of small units
tracks offered load. Canonical home of :class:`ScalePolicy` and of
:class:`UnitGovernor`, the policy engine that turns offered load into a
per-tenant activation target and applies it to a
:class:`~repro.runtime.pool.UnitPool` (``core.scheduler`` re-exports
``ScalePolicy`` for backward compatibility).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import TYPE_CHECKING, List, Optional, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.runtime.pool import UnitPool, make_unit_pool
from repro.runtime.result import (Response, Telemetry, latency_percentiles)

if TYPE_CHECKING:   # deferred: repro.power.governor imports repro.core
    from repro.power.governor import FreqGovernor


@dataclass
class ScalePolicy:
    headroom: float = 1.25            # target capacity / offered load
    cooldown_s: float = 30.0          # scale-down hysteresis
    min_units: int = 1
    wake_latency_s: float = 0.5       # unit power-on latency
    # Straggler hedging deadline: a tenant whose oldest queued request is
    # older than this borrows one extra unit for the tick (and is charged
    # for it). Honored by the runtime proper (MultiTenantRuntime /
    # ClusterRuntime) and, through its thin wrapper, by
    # ``core.scheduler.ElasticScheduler.simulate``.
    hedge_after_s: Optional[float] = None
    # Frequency policy (repro.power.governor): picks the tenant's
    # operating point each tick; the activation target is then sized
    # against that point's effective service rate, so unit count and
    # frequency are co-optimized. Only consulted when the pool carries
    # an OPP table; None pins the nominal point (strictly additive).
    freq_governor: Optional[FreqGovernor] = None


class UnitGovernor:
    """Activation policy + per-tenant bookkeeping for one pool tenant.

    Pure demand-side logic (no workload knowledge): records arrivals,
    estimates the offered rate over a sliding window, computes the
    group-quantized activation target, and applies a (possibly
    arbitrated) target to the :class:`UnitPool` — immediate scale-up
    with optional wake latency, cooldown-hysteresis scale-down. The
    wake/cooldown loop lives *only* here (:meth:`apply_target`); the
    single-tenant :class:`~repro.runtime.ClusterRuntime`, the
    multi-tenant runtime, and the retired ``ElasticScheduler`` wrapper
    all share it.

    Standalone use (no pool given) creates a private single-tenant pool —
    this is the ``serving.autoscaler.ServingAutoscaler`` compatibility
    path, where :meth:`charge` records full-cluster power. When driven by
    ``MultiTenantRuntime`` the pool is shared and the runtime records
    tenant-attributed power via :meth:`note`.
    """

    def __init__(self, spec: ClusterSpec, unit_rate: float,
                 policy: Optional[ScalePolicy] = None,
                 window_s: float = 10.0, idle_units_off: bool = True,
                 model_wake_latency: bool = False, group_units: int = 1,
                 pool: Optional[UnitPool] = None, tenant: str = "default",
                 backend: str = "scalar") -> None:
        assert unit_rate > 0, "unit_rate must be positive"
        self.spec = spec
        self.unit_rate = unit_rate
        self.policy = policy or ScalePolicy()
        self.window_s = window_s
        self.idle_units_off = idle_units_off
        self.model_wake_latency = model_wake_latency
        # units activate in groups of this size (e.g. an n-SoC tensor-
        # parallel collaboration group, §5.3): targets are rounded up to
        # a whole number of groups so no unit is stranded in a partial one
        self.group_units = max(1, int(group_units))
        assert self.group_units <= spec.n_units, \
            f"group_units={group_units} exceeds cluster size {spec.n_units}"
        self.pool = pool if pool is not None \
            else make_unit_pool(spec, backend=backend,
                                idle_units_off=idle_units_off)
        self.tenant = tenant
        self.pool.force_active(tenant, self._quantize(self.policy.min_units))
        # frequency side: consulted only when the pool carries an OPP
        # table; the chosen point feeds both the activation target (via
        # the effective service rate) and pool.set_opp in apply_target
        self.freq_governor = self.policy.freq_governor
        self._opp_target: Optional[int] = None \
            if self.pool.opp_table is None else self.pool.opp_table.nominal
        self.backlog = False          # runtime sets from last tick's queue
        # chaos hooks (repro.fleet.chaos), set per tick by the fleet
        # driver. unit_cap models killed units: the governor may not
        # hold more than cap units (excess is force-released, bypassing
        # the cooldown — a fault is not a scale decision). A capped-out
        # rack also may not borrow hedge units (MultiTenantRuntime
        # gates on it). force_floor_opp models a rack power cap: the
        # frequency governor still runs (its persistent target is
        # untouched, so it resumes cleanly on release) but the pool is
        # driven at the floor OPP and activation is sized against it.
        self.unit_cap: Optional[int] = None
        self.force_floor_opp = False
        self._arrivals: List[Tuple[float, float]] = []   # (t, count)
        self._last_downscale = -1e9
        self._tick_rate = 0.0
        self.served = 0.0
        self.scale_events = 0
        self.hedged = 0
        # per-tick history (cluster view when standalone, tenant-
        # attributed view when driven by MultiTenantRuntime)
        self.t_hist: List[float] = []
        self.offered_hist: List[float] = []
        self.active_hist: List[int] = []
        self.power_hist: List[float] = []
        self.util_hist: List[float] = []

    # ------------------------------------------------------------------
    @property
    def active_units(self) -> int:
        return self.pool.active(self.tenant)

    @active_units.setter
    def active_units(self, n: int) -> None:
        # compatibility/testing hook: force the allocation, no wake latency
        self.pool.force_active(self.tenant, int(n))

    @property
    def energy_j(self) -> float:
        return self.pool.energy_j

    # ------------------------------------------------------------------
    def record_arrival(self, t: float, n: float = 1) -> None:
        if n > 0:
            self._arrivals.append((float(t), float(n)))

    def offered_rate(self, t: float) -> float:
        # strict cutoff: an arrival exactly window_s old has left the
        # window (otherwise tick-bucketed traces double-count the edge)
        cutoff = t - self.window_s
        self._arrivals = [(a, n) for a, n in self._arrivals if a > cutoff]
        return sum(n for _, n in self._arrivals) / self.window_s

    def _quantize(self, units: int) -> int:
        g = self.group_units
        whole = -(-int(units) // g) * g          # ceil to whole groups
        if whole > self.spec.n_units:            # keep only full groups
            whole = self.spec.n_units // g * g
        return max(g, whole)

    def target_units(self, offered: float, perf_scale: float = 1.0) -> int:
        need = offered * self.policy.headroom \
            / (self.unit_rate * max(perf_scale, 1e-9))
        # math.ceil == np.ceil for any finite float but skips the numpy
        # scalar round-trip on this per-tick path
        raw = int(min(self.spec.n_units,
                      max(self.policy.min_units, math.ceil(need))))
        return self._quantize(raw)

    # ------------------------------------------------------------------
    def _select_opp(self, rate: float) -> float:
        """Run the frequency governor for this tick; returns the chosen
        point's perf scale (1.0 when the frequency axis is off)."""
        table = self.pool.opp_table
        if table is None:
            return 1.0
        from repro.power.governor import FreqContext
        if self.freq_governor is not None:
            # the governor may only plan with units this tenant can
            # actually obtain (its current holding plus the free pool),
            # not the whole cluster — otherwise a contended schedutil
            # picks a wide-and-slow point arbitration can never grant
            obtainable = min(self.spec.n_units,
                             max(self.policy.min_units,
                                 self.pool.active(self.tenant)
                                 + self.pool.waking(self.tenant)
                                 + self.pool.free_units()))
            self._opp_target = table.clamp(self.freq_governor.select(
                FreqContext(
                    demand_rate=rate, unit_rate=self.unit_rate,
                    headroom=self.policy.headroom,
                    n_units=obtainable, table=table,
                    unit=self.spec.unit, min_units=self.policy.min_units,
                    max_sustainable=self.pool.max_sustainable_opp(),
                    backlog=self.backlog,
                    p_gated_w=self.spec.unit.p_off if self.idle_units_off
                    else self.spec.unit.p_idle)))
        if self.force_floor_opp:
            return table[table.lowest].perf_scale
        return table[self._opp_target].perf_scale

    def desired_units(self, t: float, offered: Optional[float] = None
                      ) -> int:
        """The tenant's demand this tick: group-quantized activation
        target from the (windowed) offered rate, sized against the
        frequency governor's chosen operating point."""
        rate = self.offered_rate(t) if offered is None else offered
        self._tick_rate = rate
        return self.target_units(rate, self._select_opp(rate))

    def apply_target(self, tgt: int, t: float, dt_s: float = 1.0) -> int:
        """Move the pool allocation toward ``tgt`` (which arbitration may
        have capped below :meth:`desired_units`); returns the active-unit
        count the workload may use this tick.

        Wake handling is fluid: a unit waking within the tick serves the
        whole tick, so ``model_wake_latency`` only delays activation when
        ``wake_latency_s > dt_s`` — with the 0.5 s default and >= 1 s
        ticks it changes nothing."""
        p = self.policy
        wake_s = p.wake_latency_s if self.model_wake_latency else 0.0
        cap = self.unit_cap
        if cap is not None:
            # chaos kill: units beyond the cap are force-released now —
            # no cooldown gate, no scale event, no downscale stamp (a
            # fault is not a scaling decision)
            over = (self.pool.active(self.tenant)
                    + self.pool.waking(self.tenant) - cap)
            if over > 0:
                self.pool.release(self.tenant, over)
            if tgt > cap:
                tgt = cap
        active = self.pool.active(self.tenant)
        waking = self.pool.waking(self.tenant)
        if tgt > active + waking:
            # a starved wake (pool exhausted) is not a scale event
            if self.pool.wake(self.tenant, tgt - active - waking,
                              t + wake_s):
                self.scale_events += 1
        elif tgt < active + waking \
                and t - self._last_downscale > p.cooldown_s:
            # the pool cancels still-waking units first (they are not
            # serving, so a demand drop costs them nothing), then powers
            # off active ones
            keep = max(self._quantize(p.min_units), tgt)
            if self.pool.release(self.tenant, active + waking - keep):
                self._last_downscale = t
                self.scale_events += 1
        if self._opp_target is not None:
            opp_run = self._opp_target
            table = self.pool.opp_table
            if self.force_floor_opp and table is not None:
                opp_run = table.lowest
            self.pool.set_opp(self.tenant, opp_run)
        self.pool.advance(t, dt_s, self.tenant)
        return self.pool.active(self.tenant)

    def update(self, t: float, dt_s: float = 1.0,
               offered: Optional[float] = None) -> int:
        """Single-tenant shorthand: demand is granted unarbitrated."""
        return self.apply_target(self.desired_units(t, offered), t, dt_s)

    # ------------------------------------------------------------------
    def note(self, t: float, active: int, power: float, util: float,
             served: float = 0.0) -> None:
        """Append one tick to the per-tenant history."""
        self.served += served
        self.t_hist.append(t)
        self.offered_hist.append(self._tick_rate)
        self.active_hist.append(active)
        self.power_hist.append(power)
        self.util_hist.append(util)

    def charge(self, t: float, utilization: float, dt_s: float = 1.0,
               served: float = 0.0, extra_units: int = 0) -> float:
        """Standalone/single-tenant accounting: one tick of full-cluster
        power at the current activation; returns the tick's power draw."""
        total, _, powered = self.pool.charge(
            t, dt_s, {self.tenant: utilization},
            {self.tenant: extra_units},
            offered=self._tick_rate, served=served)
        self.note(t, powered[self.tenant], total, utilization, served)
        return total

    # ------------------------------------------------------------------
    def telemetry(self, responses: Optional[List[Response]] = None,
                  workload: Optional[dict] = None) -> Telemetry:
        p50, p99 = latency_percentiles(responses or [])
        return Telemetry(
            time_s=np.asarray(self.t_hist, float),
            offered_load=np.asarray(self.offered_hist, float),
            active_units=np.asarray(self.active_hist, float),
            power_w=np.asarray(self.power_hist, float),
            utilization=np.asarray(self.util_hist, float),
            served=self.served,
            hedged=self.hedged,
            scale_events=self.scale_events,
            p50_latency_s=p50,
            p99_latency_s=p99,
            energy_j=self.energy_j,
            responses=list(responses or []),
            workload=dict(workload or {}),
        )
