"""Unit-activation policy (paper §5.2): how a cluster of small units
tracks offered load. Canonical home of :class:`ScalePolicy`, which is
bound into :class:`~repro.runtime.ClusterRuntime` alongside a
``ClusterSpec`` and a ``Workload`` (``core.scheduler`` re-exports it for
backward compatibility).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass
class ScalePolicy:
    headroom: float = 1.25            # target capacity / offered load
    cooldown_s: float = 30.0          # scale-down hysteresis
    min_units: int = 1
    wake_latency_s: float = 0.5       # unit power-on latency
    # Straggler hedging deadline. Honored only by the model-level
    # ``core.scheduler.ElasticScheduler`` simulation; the live
    # ``ClusterRuntime`` path warns and ignores it (not implemented yet).
    hedge_after_s: Optional[float] = None
