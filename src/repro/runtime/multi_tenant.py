"""``MultiTenantRuntime`` — N workloads, one :class:`UnitPool`.

The paper's deployed SoC Clusters are inherently multi-tenant: 60 SoCs
shared across cloud gaming, video transcoding, and DL inference (§2,
§4-5), and energy proportionality pays off when the *pool* is
partitioned per offered load. This runtime hosts any number of
:class:`~repro.runtime.workload.Workload`\\ s on a single
:class:`~repro.core.cluster.ClusterSpec`:

  * each tenant has its own :class:`UnitGovernor`-derived activation
    target (windowed offered rate, headroom, cooldown hysteresis,
    group quantization);
  * when total demand exceeds ``n_units``, grants are arbitrated by
    **weighted fair share** with per-tenant ``min_units`` floors
    (progressive filling, one unit at a time to the tenant with the
    least granted-beyond-floor capacity per unit of weight);
  * **straggler hedging** (§5.2) happens here, in the runtime proper: a
    tenant whose oldest queued request is older than its policy's
    ``hedge_after_s`` borrows one *free* pool unit for the tick — the
    borrowed unit serves backlog and its energy is charged to the
    tenant;
  * energy is one pool-level power integral: shared power
    (``ClusterSpec.p_shared``) is charged once per tick, never per
    tenant, and each tenant accrues only its own units' energy.

Typical use::

    from repro.core.cluster import soc_cluster
    from repro.runtime import (MultiTenantRuntime, Tenant, ScalePolicy,
                               DLServingWorkload, TranscodingWorkload)

    rt = MultiTenantRuntime(soc_cluster(), [
        Tenant("dl", DLServingWorkload.from_point("resnet-50", "fp32",
                                                  "soc-gpu")),
        Tenant("video", TranscodingWorkload(video, hw_codec=True),
               weight=2.0),
    ])
    tel = rt.play_traces({"dl": dl_trace, "video": video_trace}, dt_s=60.0)
    print(tel.per_tenant["dl"].summary())     # per-tenant roll-up
    print(tel.summary())                      # cluster roll-up
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.power.opp import OPPTable
from repro.power.thermal import ThermalModel, ThermalParams
from repro.runtime.policy import ScalePolicy, UnitGovernor
from repro.runtime.pool import make_unit_pool
from repro.runtime.result import (Request, Response, StepStats, Telemetry,
                                  latency_percentiles)
from repro.runtime.workload import Workload


@dataclass
class Tenant:
    """One workload's binding onto the shared pool."""

    name: str
    workload: Workload
    policy: Optional[ScalePolicy] = None
    unit_rate: Optional[float] = None    # req/s one unit sustains;
    #                                      from workload.describe() if None
    weight: float = 1.0                  # fair-share weight under contention
    group_units: int = 1                 # activation granularity (§5.3)


def weighted_fair_share(demands: Dict[str, int], floors: Dict[str, int],
                        weights: Dict[str, float], capacity: int,
                        groups: Optional[Dict[str, int]] = None
                        ) -> Dict[str, int]:
    """Arbitrate integer unit demands against a capacity.

    Every tenant first receives its floor (capped by its demand); the
    remaining capacity is granted in per-tenant ``groups`` chunks to the
    tenant with the smallest granted-beyond-floor per unit of weight
    (progressive filling — the discrete analogue of weighted max-min
    fairness). Beyond its floor a tenant only ever advances by whole
    groups: a tensor-parallel tenant is never handed a partial
    collaboration group, so demand left over below one group (from an
    unquantized demand) goes ungranted. When total demand fits and is
    group-aligned, everyone simply gets their demand.
    """
    groups = groups or {}
    grants = {m: min(demands[m], floors.get(m, 0)) for m in demands}
    # Uncontended fast path: when total demand fits the capacity and every
    # tenant's beyond-floor demand is a whole number of its groups, the
    # progressive fill below provably lands on the demands themselves —
    # skip the unit-at-a-time loop (it is O(capacity) and dominates the
    # single-tenant tick otherwise).
    if sum(demands.values()) <= capacity and all(
            (demands[m] - grants[m]) % groups.get(m, 1) == 0
            for m in demands):
        return dict(demands)
    order = {name: i for i, name in enumerate(demands)}
    remaining = capacity - sum(grants.values())
    while remaining > 0:
        cand = [m for m in demands
                if groups.get(m, 1) <= min(remaining,
                                           demands[m] - grants[m])]
        if not cand:
            break
        nxt = min(cand, key=lambda m: (
            (grants[m] - floors.get(m, 0)) / max(weights.get(m, 1.0), 1e-9),
            order[m]))
        grants[nxt] += groups.get(nxt, 1)
        remaining -= groups.get(nxt, 1)
    return grants


def _oldest_waiting_s(workload: Workload, t: float) -> Optional[float]:
    fn = getattr(workload, "oldest_waiting_s", None)
    return fn(t) if fn is not None else None


@dataclass
class _TenantState:
    tenant: Tenant
    governor: UnitGovernor
    responses: List[Response] = field(default_factory=list)
    accepts_perf: bool = False    # workload.step takes perf_scale=


class MultiTenantRuntime:
    """Hosts N tenants on one :class:`UnitPool` over one cluster.

    Pass ``opp_table`` (and optionally ``thermal``) to enable the
    frequency axis: each tenant's ``ScalePolicy.freq_governor`` then
    picks an operating point per tick, workload service rates scale by
    the active perf-scale, and hot units throttle down via the thermal
    trip latch. With no table (the default) the power layer is inert.
    """

    def __init__(self, spec: ClusterSpec, tenants: Sequence[Tenant],
                 dt_s: float = 1.0, window_s: float = 10.0,
                 idle_units_off: bool = True,
                 model_wake_latency: bool = False,
                 opp_table: Optional[OPPTable] = None,
                 thermal: Union[ThermalParams, ThermalModel, None] = None,
                 backend: str = "scalar") -> None:
        assert tenants, "need at least one tenant"
        names = [t.name for t in tenants]
        assert len(set(names)) == len(names), f"duplicate tenant names: {names}"
        self.spec = spec
        self.dt_s = dt_s
        self.backend = backend
        self.pool = make_unit_pool(spec, backend=backend,
                                   idle_units_off=idle_units_off,
                                   opp_table=opp_table, thermal=thermal)
        self._t = 0.0
        self._states: Dict[str, _TenantState] = {}
        floors = 0
        for ten in tenants:
            rate = ten.unit_rate
            if rate is None:
                rate = ten.workload.describe().get("unit_rate")
            if rate is None:
                raise ValueError(
                    f"tenant {ten.name!r}: unit_rate not derivable from "
                    "workload.describe(); pass Tenant(unit_rate=...) "
                    "(requests/s one unit sustains) explicitly")
            gov = UnitGovernor(
                spec, rate, ten.policy, window_s=window_s,
                idle_units_off=idle_units_off,
                model_wake_latency=model_wake_latency,
                group_units=ten.group_units,
                pool=self.pool, tenant=ten.name)
            try:
                sig = inspect.signature(ten.workload.step)
                accepts = "perf_scale" in sig.parameters
            except (TypeError, ValueError):
                accepts = False
            self._states[ten.name] = _TenantState(ten, gov,
                                                  accepts_perf=accepts)
            floors += gov._quantize(gov.policy.min_units)
        assert floors <= spec.n_units, \
            f"sum of per-tenant min_units floors ({floors}) exceeds the " \
            f"{spec.n_units}-unit pool"

    # ------------------------------------------------------------------
    @property
    def now(self) -> float:
        return self._t

    @property
    def tenant_names(self) -> List[str]:
        return list(self._states)

    def governor_of(self, tenant: str) -> UnitGovernor:
        return self._states[tenant].governor

    def workload_of(self, tenant: str) -> Workload:
        return self._states[tenant].tenant.workload

    # ------------------------------------------------------------------
    def submit(self, tenant: str, payload: Any = None, *, cost: float = 1.0,
               count: float = 1.0, request: Optional[Request] = None,
               **meta: Any) -> int:
        """Record an arrival for ``tenant`` at the current clock and hand
        the request to its workload. ``count`` weights the arrival-rate
        estimate (use ``count=cost`` for aggregated fluid requests)."""
        st = self._states[tenant]
        req = request or Request(payload=payload, cost=cost,
                                 arrival_s=self._t, meta=meta)
        if req.arrival_s is None:
            req.arrival_s = self._t
        st.governor.record_arrival(self._t, count)
        return st.tenant.workload.submit(req)

    # ------------------------------------------------------------------
    def _tick_all(self, dt_s: Optional[float] = None
                  ) -> Dict[str, StepStats]:
        """One canonical iteration for every tenant: per-tenant demand →
        weighted-fair arbitration → pool allocation → straggler hedging →
        gated workload step → single pool-level energy charge."""
        dt = self.dt_s if dt_s is None else dt_s
        t = self._t
        names = list(self._states)
        govs = {m: self._states[m].governor for m in names}
        desired = {m: govs[m].desired_units(t) for m in names}
        floors = {m: govs[m]._quantize(govs[m].policy.min_units)
                  for m in names}
        weights = {m: self._states[m].tenant.weight for m in names}
        groups = {m: govs[m].group_units for m in names}
        grants = weighted_fair_share(desired, floors, weights,
                                     self.spec.n_units, groups=groups)
        active = {m: govs[m].apply_target(grants[m], t, dt) for m in names}
        # straggler hedging (§5.2): a tenant whose oldest queued request
        # has waited past hedge_after_s borrows one free unit this tick
        free = self.pool.free_units()
        hedges: Dict[str, int] = {}
        for m in names:
            h = 0
            deadline = govs[m].policy.hedge_after_s
            wl = self._states[m].tenant.workload
            unit_cap = govs[m].unit_cap
            if deadline is not None and free > 0 \
                    and (unit_cap is None or active[m] < unit_cap):
                # a borrowed unit must add real capacity: skip when the
                # workload's own concurrency cap (e.g. batcher slots)
                # already binds; a chaos unit_cap (killed units look
                # free to the pool) gates the borrow the same way
                cap_fn = getattr(wl, "max_useful_units", None)
                capped = cap_fn is not None and active[m] + 1 > cap_fn()
                age = None if capped else _oldest_waiting_s(wl, t)
                if age is not None and age > deadline:
                    h = 1
                    free -= 1
                    govs[m].hedged += 1
            hedges[m] = h
        out: Dict[str, StepStats] = {}
        utils: Dict[str, float] = {}
        extras: Dict[str, int] = {}
        for m in names:
            st0 = self._states[m]
            wl = st0.tenant.workload
            # frequency axis: workload capacity scales by the tenant's
            # active perf-scale (throttled units drag it down)
            perf = self.pool.perf_scale(m)
            if st0.accepts_perf:
                s = wl.step(active[m] + hedges[m], dt, t, perf_scale=perf)
            else:
                s = wl.step(active[m] + hedges[m], dt, t)
            s.t, s.dt_s = t, dt
            s.target_units = active[m]
            s.hedge_units = hedges[m]
            s.perf_scale = perf
            govs[m].backlog = s.queued > 0
            # in-flight work that outlived a scale-down stays powered
            over = max(0, (s.units_used or 0) - active[m] - hedges[m])
            extras[m] = hedges[m] + over
            utils[m] = s.utilization
            out[m] = s
        total, p_tenant, powered = self.pool.charge(
            t, dt, utils, extras,
            offered=sum(govs[m]._tick_rate for m in names),
            served=sum(s.work_done for s in out.values()))
        for m in names:
            st = self._states[m]
            out[m].active_units = powered[m]
            out[m].power_w = p_tenant.get(m, 0.0)
            out[m].energy_j = self.pool.tenant_energy_j.get(m, 0.0)
            st.governor.note(t, powered[m], p_tenant.get(m, 0.0),
                             out[m].utilization, served=out[m].work_done)
            # drain() is the single delivery channel into Telemetry:
            # each response reaches a tenant's response log exactly once
            st.responses.extend(st.tenant.workload.drain())
        self._t = t + dt
        return out

    def tick_all(self, dt_s: Optional[float] = None
                 ) -> Dict[str, StepStats]:
        """Advance one tick; returns per-tenant stats. (Named distinctly
        from the single-tenant facade's ``ClusterRuntime.tick``, which
        returns one StepStats.)"""
        return self._tick_all(dt_s)

    @staticmethod
    def _all_idle(stats: Dict[str, StepStats]) -> bool:
        return all(s.queued == 0 and s.concurrency == 0
                   for s in stats.values())

    def _final_drain(self) -> None:
        for st in self._states.values():
            st.responses.extend(st.tenant.workload.drain())

    def run(self, max_ticks: int = 100000) -> Telemetry:
        """Tick until every tenant is fully drained (or ``max_ticks``)."""
        for _ in range(max_ticks):
            if self._all_idle(self._tick_all()):
                break
        self._final_drain()
        return self.cluster_telemetry()

    def play_traces(self, traces: Dict[str, Sequence[float]],
                    dt_s: Optional[float] = None,
                    drain: bool = True) -> Telemetry:
        """Drive every tenant with its own offered-load trace (requests/s
        per tick). Traces may differ in length; shorter ones offer zero
        load once exhausted. Each tick submits one aggregated request of
        ``rate * dt`` request-equivalents per tenant."""
        dt = self.dt_s if dt_s is None else dt_s
        n = max(len(tr) for tr in traces.values())
        # the rate estimator needs the window to cover at least one tick
        saved = {m: self._states[m].governor.window_s for m in self._states}
        for m in self._states:
            self._states[m].governor.window_s = max(saved[m], dt)
        try:
            for i in range(n):
                for m, tr in traces.items():
                    if i < len(tr):
                        work = float(tr[i]) * dt
                        if work > 0:
                            # arrivals spread across the tick; stamp the
                            # aggregate at the tick midpoint so fluid
                            # latency isn't inflated by a full tick width
                            self.submit(m, count=work, request=Request(
                                cost=work, arrival_s=self._t + 0.5 * dt))
                self._tick_all(dt)
            if drain:
                for _ in range(10 * n + 100):
                    if self._all_idle(self._tick_all(dt)):
                        break
        finally:
            for m in self._states:
                self._states[m].governor.window_s = saved[m]
        self._final_drain()
        return self.cluster_telemetry()

    # ------------------------------------------------------------------
    def tenant_telemetry(self, name: str) -> Telemetry:
        """Per-tenant roll-up. ``energy_j`` is the tenant-attributable
        unit energy only — shared infrastructure power is charged once,
        at the cluster level."""
        st = self._states[name]
        gov = st.governor
        p50, p99 = latency_percentiles(st.responses)
        attributed = self.pool.tenant_energy_j.get(name, 0.0)
        return Telemetry(
            time_s=np.asarray(gov.t_hist, float),
            offered_load=np.asarray(gov.offered_hist, float),
            active_units=np.asarray(gov.active_hist, float),
            power_w=np.asarray(gov.power_hist, float),
            utilization=np.asarray(gov.util_hist, float),
            served=gov.served,
            hedged=gov.hedged,
            scale_events=gov.scale_events,
            p50_latency_s=p50,
            p99_latency_s=p99,
            energy_j=attributed,
            unit_energy_j=attributed,
            responses=list(st.responses),
            workload=st.tenant.workload.describe(),
            tenant=name,
        )

    def cluster_telemetry(self) -> Telemetry:
        """Cluster roll-up: the pool's single power integral (shared
        power counted once), merged responses, per-tenant views under
        ``per_tenant``."""
        pool = self.pool
        responses = [r for st in self._states.values()
                     for r in st.responses]
        p50, p99 = latency_percentiles(responses)
        per = {m: self.tenant_telemetry(m) for m in self._states}
        if len(self._states) == 1:
            only = next(iter(self._states.values()))
            wl_desc = only.tenant.workload.describe()
        else:
            wl_desc = {"name": "multi-tenant", "kind": "multi-tenant",
                       "tenants": {m: per[m].workload.get("name")
                                   for m in per}}
        return Telemetry(
            time_s=np.asarray(pool.t_hist, float),
            offered_load=np.asarray(pool.offered_hist, float),
            active_units=np.asarray(pool.active_hist, float),
            power_w=np.asarray(pool.power_hist, float),
            utilization=np.asarray(pool.util_hist, float),
            served=pool.served,
            hedged=sum(st.governor.hedged for st in self._states.values()),
            scale_events=sum(st.governor.scale_events
                             for st in self._states.values()),
            p50_latency_s=p50,
            p99_latency_s=p99,
            energy_j=pool.energy_j,
            unit_energy_j=sum(pool.tenant_energy_j.values()),
            responses=responses,
            workload=wl_desc,
            per_tenant=per,
            max_temp_c=np.asarray(pool.max_temp_hist, float),
            throttled_units=np.asarray(pool.throttled_hist, float),
            fan_power_w=np.asarray(pool.fan_power_hist, float),
        )

    def static_baseline_energy(self, utilization: float = 1.0) -> float:
        """Energy the same span would have cost with every unit powered
        (the monolithic / no-gating baseline of Fig 12)."""
        ts = self.pool.t_hist
        if not ts:
            return 0.0
        # reconstruct per-tick dt from the recorded clock
        dts = [t2 - t1 for t1, t2 in zip(ts, ts[1:])]
        dts.append(dts[-1] if dts else self.dt_s)
        p = self.spec.power(self.spec.n_units, utilization,
                            idle_units_off=False)
        return p * float(sum(dts))
