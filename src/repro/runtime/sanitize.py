"""Runtime invariant sanitizer for the twin-engine parity contract.

Static analysis (``tools/reprolint``) catches the *patterns* that break
scalar/vector parity; this module catches the *state* — it wraps the
mutating entry points of :class:`~repro.runtime.pool.UnitPool`,
:class:`~repro.runtime.pool.VectorUnitPool`, and the
:class:`~repro.fleet.fleet.Fleet` engines with invariant checks that
run after every call:

* **Count-cache ground truth** — the vector pool's exact integer caches
  (``_n_alloc``, ``_n_active_of``, ``_free_g``, ...) must equal the
  ``np.bincount``/``np.nonzero`` recomputation from the state arrays.
* **Legal state transitions** — per unit, only
  ``off -> waking -> active -> off`` moves (plus ``off -> active`` for
  ``force_active``); ``active -> waking`` is impossible, and a unit may
  change owner only by passing through ``off``.
* **State/owner consistency** — a unit is off iff it has no owner.
* **Request conservation** (fleet level) — cumulative injected cost
  equals served + queued pending cost per rack (the fluid model has no
  separate in-flight mass; concurrency is a derived count).
* **OPP indices in range**, **finite bounded temperatures**, and
  **monotone non-negative energy integrals**.

Enable globally with ``REPRO_SANITIZE=1`` (picked up by
:func:`~repro.runtime.pool.make_unit_pool` and
:class:`~repro.fleet.fleet.Fleet`), or per object with their
``sanitize=True`` keyword. Checks are O(n_units) numpy work per
mutating call — cheap on the small configs tier-1 tests use.

A violated invariant raises :class:`InvariantViolation` (an
``AssertionError`` subclass) at the mutating call that broke it, not
ticks later in a telemetry mismatch.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, Optional

import numpy as np

__all__ = [
    "InvariantViolation",
    "sanitizer_enabled",
    "resolve_sanitize",
    "PoolSanitizer",
    "FleetSanitizer",
    "attach_pool_sanitizer",
    "attach_fleet_sanitizer",
    "check_pool",
]

# pool state codes (mirrors pool._OFF/_WAKING/_ACTIVE; pool imports this
# module lazily, so the constants live here too to avoid a cycle)
_OFF, _WAKING, _ACTIVE = 0, 1, 2

#: legal (previous, current) per-unit state moves across one mutating
#: call: anything out of OFF, WAKING forward/back, ACTIVE only to OFF.
_LEGAL_MOVES = frozenset({
    (_OFF, _OFF), (_OFF, _WAKING), (_OFF, _ACTIVE),
    (_WAKING, _WAKING), (_WAKING, _ACTIVE), (_WAKING, _OFF),
    (_ACTIVE, _ACTIVE), (_ACTIVE, _OFF),
})

_TEMP_MIN_C = -40.0
_TEMP_MAX_C = 400.0

# methods whose calls mutate pool state and therefore get re-checked
_POOL_MUTATORS = ("wake", "release", "advance", "force_active",
                  "charge", "set_opp")


class InvariantViolation(AssertionError):
    """A runtime invariant of the parity contract was broken."""


def sanitizer_enabled() -> bool:
    """True when ``REPRO_SANITIZE`` requests sanitized runs."""
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() in (
        "1", "true", "yes", "on")


def resolve_sanitize(flag: Optional[bool]) -> bool:
    """``sanitize=`` keyword semantics: explicit wins, None asks env."""
    return sanitizer_enabled() if flag is None else bool(flag)


def _require(cond: bool, what: str) -> None:
    if not cond:
        raise InvariantViolation(what)


# ---------------------------------------------------------------------------
# pool-level checks


_SCALAR_CODES: Dict[object, int] = {}


def _state_codes(pool: Any) -> np.ndarray:
    """The pool's per-unit state as int codes, backend-agnostic."""
    st = getattr(pool, "_state", None)
    if isinstance(st, np.ndarray):
        return st.copy()
    # scalar backend: List[UnitState] in enum-declaration order
    if not _SCALAR_CODES and pool.state:
        _SCALAR_CODES.update(
            (s, i) for i, s in
            enumerate(type(pool.state[0]).__members__.values()))
    return np.asarray([_SCALAR_CODES[s] for s in pool.state], np.int8)


#: stable name -> id assignment for the scalar backend's owner list
#: (ids must not depend on encounter order, or a snapshot taken before
#: a call and one taken after could number the same tenant differently)
_OWNER_INTERN: Dict[str, int] = {}


def _owner_ids(pool: Any) -> np.ndarray:
    ow = getattr(pool, "_owner", None)
    if isinstance(ow, np.ndarray):
        return ow.copy()
    out = np.empty(pool.spec.n_units, np.int64)
    for u, o in enumerate(pool.owner):
        out[u] = -1 if o is None else \
            _OWNER_INTERN.setdefault(o, len(_OWNER_INTERN))
    return out


def _check_transitions(prev_state: np.ndarray, prev_owner: np.ndarray,
                       state: np.ndarray, owner: np.ndarray) -> None:
    changed = np.nonzero((prev_state != state)
                         | (prev_owner != owner))[0]
    for u in changed:
        move = (int(prev_state[u]), int(state[u]))
        _require(
            move in _LEGAL_MOVES,
            f"unit {u}: illegal state transition {move[0]} -> {move[1]} "
            "(legal: off->waking->active, off->active, waking/active->off)")
        if prev_state[u] != _OFF and state[u] != _OFF:
            _require(
                prev_owner[u] == owner[u],
                f"unit {u}: owner changed {int(prev_owner[u])} -> "
                f"{int(owner[u])} without passing through off")


def _check_vector_caches(pool: Any) -> None:
    st, ow = pool._state, pool._owner
    gi = pool._group_idx
    n_groups = len(pool._groups)
    off = st == _OFF
    _require(int((~off).sum()) == pool._n_alloc,
             f"_n_alloc cache {pool._n_alloc} != ground truth "
             f"{int((~off).sum())}")
    n_waking = int((st == _WAKING).sum())
    _require(n_waking == pool._n_waking_total,
             f"_n_waking_total cache {pool._n_waking_total} != ground "
             f"truth {n_waking}")
    free_truth = np.bincount(gi[off], minlength=n_groups)
    _require(np.array_equal(free_truth, pool._free_g),
             f"_free_g cache {pool._free_g.tolist()} != ground truth "
             f"{free_truth.tolist()}")
    for tid in range(len(pool._tenant_names)):
        mine = ow == tid
        n_act = int((mine & (st == _ACTIVE)).sum())
        n_wak = int((mine & (st == _WAKING)).sum())
        name = pool._tenant_names[tid]
        _require(pool._n_active_of.get(tid, 0) == n_act,
                 f"tenant {name!r}: _n_active_of cache "
                 f"{pool._n_active_of.get(tid, 0)} != ground truth {n_act}")
        _require(pool._n_waking_of.get(tid, 0) == n_wak,
                 f"tenant {name!r}: _n_waking_of cache "
                 f"{pool._n_waking_of.get(tid, 0)} != ground truth {n_wak}")
        mine_truth = np.bincount(gi[mine & ~off], minlength=n_groups)
        act_truth = np.bincount(gi[mine & (st == _ACTIVE)],
                                minlength=n_groups)
        cached_mine = pool._mine_g.get(tid)
        if cached_mine is not None:
            _require(np.array_equal(mine_truth, cached_mine),
                     f"tenant {name!r}: _mine_g cache "
                     f"{cached_mine.tolist()} != ground truth "
                     f"{mine_truth.tolist()}")
        elif mine_truth.any():
            raise InvariantViolation(
                f"tenant {name!r}: owns units but has no _mine_g cache")
        cached_act = pool._act_g.get(tid)
        if cached_act is not None:
            _require(np.array_equal(act_truth, cached_act),
                     f"tenant {name!r}: _act_g cache "
                     f"{cached_act.tolist()} != ground truth "
                     f"{act_truth.tolist()}")
        elif act_truth.any():
            raise InvariantViolation(
                f"tenant {name!r}: has active units but no _act_g cache")
        cached_idx = pool._active_idx.get(tid)
        if cached_idx is not None:
            idx_truth = np.nonzero(mine & (st == _ACTIVE))[0]
            _require(np.array_equal(idx_truth, cached_idx),
                     f"tenant {name!r}: stale _active_idx cache "
                     f"{cached_idx.tolist()} != ground truth "
                     f"{idx_truth.tolist()}")


def _check_thermal(thermal: Any) -> None:
    for field in ("t_die", "t_pcb"):
        temps = np.asarray(getattr(thermal, field), float)
        _require(bool(np.all(np.isfinite(temps))),
                 f"thermal.{field} has non-finite temperatures")
        _require(bool(np.all((temps >= _TEMP_MIN_C)
                             & (temps <= _TEMP_MAX_C))),
                 f"thermal.{field} out of [{_TEMP_MIN_C}, {_TEMP_MAX_C}] C: "
                 f"min {temps.min():.1f}, max {temps.max():.1f}")


def check_pool(pool: Any, prev_state: Optional[np.ndarray] = None,
               prev_owner: Optional[np.ndarray] = None,
               prev_energy: float = 0.0) -> None:
    """Assert every pool invariant; raise :class:`InvariantViolation`.

    Standalone entry point (the property tests call it directly);
    ``prev_*`` enable the transition-legality check across a call.
    """
    state = _state_codes(pool)
    owner = _owner_ids(pool)
    # state/owner consistency: off iff unowned
    no_owner = owner < 0
    bad = np.nonzero((state == _OFF) != no_owner)[0]
    _require(len(bad) == 0,
             f"units {bad.tolist()}: off-state and ownerless disagree "
             "(a unit is off iff it has no owner)")
    if prev_state is not None and prev_owner is not None:
        _check_transitions(prev_state, prev_owner, state, owner)
    if getattr(pool, "_n_alloc", None) is not None \
            and hasattr(pool, "_tenant_names"):
        _check_vector_caches(pool)
    if pool.opp_table is not None:
        k = len(pool.opp_table)
        req = np.asarray(pool._req_opp, np.int64)
        _require(bool(np.all((req >= 0) & (req < k))),
                 f"requested OPP indices out of table range [0, {k})")
        for name, idx in pool._tenant_opp.items():
            _require(0 <= idx < k,
                     f"tenant {name!r}: OPP {idx} out of range [0, {k})")
    if pool.thermal is not None:
        _check_thermal(pool.thermal)
    _require(np.isfinite(pool.energy_j) and pool.energy_j >= 0.0,
             f"energy_j non-finite or negative: {pool.energy_j}")
    _require(pool.energy_j >= prev_energy - 1e-9,
             f"energy integral went backwards: {prev_energy} -> "
             f"{pool.energy_j}")
    _require(np.isfinite(pool.last_power_w) and pool.last_power_w >= 0.0,
             f"last_power_w non-finite or negative: {pool.last_power_w}")


class PoolSanitizer:
    """Wraps a pool's mutating methods with post-call invariant checks.

    Installed by :func:`attach_pool_sanitizer`: each wrapped method
    snapshots state/owner, runs the real method, then re-validates the
    whole pool (caches vs ground truth, transition legality, OPP
    ranges, thermal bounds, energy monotonicity). Nested mutators
    (``force_active`` calls ``release``) each check their own span.
    """

    def __init__(self, pool: Any) -> None:
        self.pool = pool
        for name in _POOL_MUTATORS:
            setattr(pool, name, self._wrap(getattr(pool, name)))
        pool._sanitizer = self
        check_pool(pool)  # construction must already be consistent

    def _wrap(self, method: Callable[..., Any]) -> Callable[..., Any]:
        pool = self.pool

        def checked(*args: Any, **kwargs: Any) -> Any:
            prev_state = _state_codes(pool)
            prev_owner = _owner_ids(pool)
            prev_energy = pool.energy_j
            out = method(*args, **kwargs)
            check_pool(pool, prev_state, prev_owner, prev_energy)
            return out

        checked.__name__ = method.__name__
        checked.__wrapped__ = method  # type: ignore[attr-defined]
        return checked


def attach_pool_sanitizer(pool: Any) -> PoolSanitizer:
    """Idempotently arm a pool with invariant checking."""
    existing = getattr(pool, "_sanitizer", None)
    if isinstance(existing, PoolSanitizer):
        return existing
    return PoolSanitizer(pool)


# ---------------------------------------------------------------------------
# fleet-level checks

# conservation tolerance: the fluid drain forgives up to 1e-12 residual
# cost per completed request, so equality is approximate
_CONS_ATOL = 1e-6
_CONS_RTOL = 1e-9


class FleetSanitizer:
    """Wraps a fleet engine's ``tick`` with conservation checks.

    Tracks the cumulative injected cost per rack (``assign_rps * dt``,
    exactly what the engines submit) and asserts after every tick that
    it matches served + queued pending cost — the fluid model has no
    other place for request mass to live. Also checks per-rack energy
    monotonicity, OPP ranges, and (vector backend) stacked thermal
    bounds. On the scalar backend the deep per-pool checks (count
    caches, transition legality, thermal bounds) run once per fleet
    tick over every rack's pool — per-tick granularity instead of
    per-call keeps the overhead inside the tier-1 budget.
    """

    def __init__(self, fleet: Any) -> None:
        self.fleet = fleet
        engine = fleet.engine
        self.injected = np.zeros(fleet.n_racks)
        self._prev_energy = np.zeros(fleet.n_racks)
        self._prev_served = np.zeros(fleet.n_racks)
        # resurrection check needs per-tick granularity (the jax play
        # wrapper checks once per whole trace, where a rack may serve
        # legitimately before its kill window opens)
        self._per_tick = hasattr(engine, "tick")
        self._pools = [rt.pool for rt in engine.rts] \
            if hasattr(engine, "rts") else []
        for pool in self._pools:
            check_pool(pool)  # construction must already be consistent
        if hasattr(engine, "tick"):
            engine.tick = self._wrap(engine.tick)
        else:
            # jax engine: one play() call covers many ticks — wrap that
            engine.play = self._wrap_play(engine.play)
        fleet._sanitizer = self

    # -- engine accessors (scalar vs vector) ----------------------------
    # np.array (not asarray): the vector engine mutates served_acc /
    # energy in place, so an aliasing view would make the grew-while-dead
    # and energy-monotonicity deltas compare an array against itself
    def _served(self) -> np.ndarray:
        engine = self.fleet.engine
        if hasattr(engine, "served_acc"):
            return np.array(engine.served_acc, float)
        return np.asarray([rt.pool.served for rt in engine.rts], float)

    def _energy(self) -> np.ndarray:
        engine = self.fleet.engine
        if hasattr(engine, "energy"):
            return np.array(engine.energy, float)
        return np.asarray([rt.pool.energy_j for rt in engine.rts], float)

    def _wrap(self, tick: Callable[..., Any]) -> Callable[..., Any]:
        def checked(assign_rps: np.ndarray, dt: float,
                    *args: Any, **kwargs: Any) -> Any:
            self.injected = self.injected + np.asarray(assign_rps,
                                                       float) * dt
            prev = [(_state_codes(p), _owner_ids(p), p.energy_j)
                    for p in self._pools]
            out = tick(assign_rps, dt, *args, **kwargs)
            self.check()
            for pool, (ps, po, pe) in zip(self._pools, prev):
                check_pool(pool, ps, po, pe)
            return out

        checked.__name__ = "tick"
        checked.__wrapped__ = tick  # type: ignore[attr-defined]
        return checked

    def _wrap_play(self, play: Callable[..., Any]) -> Callable[..., Any]:
        """Per-call twin of :meth:`_wrap` for engines whose unit of
        advancement is a whole ``play(trace)`` rather than one tick:
        the injected-cost ledger grows by the routed assignments the
        call reports, then the same invariants run once."""
        def checked(trace_rps: Any, drain: bool = True) -> Any:
            out = play(trace_rps, drain=drain)
            assigned = np.asarray(out[0], float)
            if assigned.size:
                per_rack = np.zeros(assigned.shape[1])
                for row in assigned:  # ordered accumulation
                    per_rack = per_rack + row
                self.injected = self.injected + per_rack * self.fleet.dt_s
            self.check()
            return out

        checked.__name__ = "play"
        checked.__wrapped__ = play  # type: ignore[attr-defined]
        return checked

    def check(self) -> None:
        engine = self.fleet.engine
        served = self._served()
        pending = np.asarray(engine.queued_cost(), float)
        # chaos credit: a full-rack kill evacuates queued cost out of
        # the fluid system (respilled cost re-enters through the router
        # and is re-counted as injected; dropped cost leaves for good)
        evac = getattr(engine, "chaos_evac_by_rack", None)
        balance = self.injected - (served + pending)
        if evac is not None:
            balance = balance - np.asarray(evac, float)
        # degradation credit: deadline-expired queued work was injected
        # but is abandoned, never served (shed-at-the-door mass never
        # reaches an engine, so it needs no credit here — the retry
        # ring re-injects it through the router)
        expired = getattr(engine, "degrade_expired_by_rack", None)
        if expired is not None:
            balance = balance - np.asarray(expired, float)
        tol = _CONS_ATOL + _CONS_RTOL * np.maximum(self.injected, 1.0)
        bad = np.nonzero(np.abs(balance) > tol)[0]
        _require(
            len(bad) == 0,
            "request conservation violated: rack(s) "
            f"{bad.tolist()} injected {self.injected[bad].tolist()} != "
            f"served {served[bad].tolist()} + queued "
            f"{pending[bad].tolist()} (+ evacuated/expired)")
        dead = getattr(engine, "chaos_dead", None)
        if self._per_tick and dead is not None:
            full = np.asarray(dead) >= np.asarray(engine.n_units)
            if full.any():
                grew = served - self._prev_served
                res = np.nonzero(full & (grew > 1e-9))[0]
                _require(
                    len(res) == 0,
                    f"resurrection: fully-killed rack(s) {res.tolist()} "
                    "served requests while dead")
        self._prev_served = served
        energy = self._energy()
        _require(bool(np.all(np.isfinite(energy)) and np.all(energy >= 0)),
                 f"rack energy non-finite or negative: {energy.tolist()}")
        _require(bool(np.all(energy >= self._prev_energy - 1e-9)),
                 "rack energy integral went backwards")
        self._prev_energy = energy
        opp = getattr(engine, "opp", None)
        if opp is not None:
            k = np.asarray(engine.K, np.int64)
            has = np.asarray(engine.has_table, bool)
            ok = ~has | ((opp >= 0) & (opp < k))
            _require(bool(np.all(ok)),
                     f"rack OPP indices out of table range: "
                     f"{np.asarray(opp)[~ok].tolist()}")
        therm = getattr(engine, "therm", None)
        if therm is not None:
            _check_thermal(therm)


def attach_fleet_sanitizer(fleet: Any) -> FleetSanitizer:
    """Idempotently arm a fleet with conservation checking."""
    existing = getattr(fleet, "_sanitizer", None)
    if isinstance(existing, FleetSanitizer):
        return existing
    return FleetSanitizer(fleet)
