"""Unified request/response/telemetry model for the cluster runtime.

One result vocabulary for everything that serves requests on a
:class:`~repro.core.cluster.ClusterSpec` — the discrete-event scheduler
simulation, the live continuous-batching LM engine, and the data-driven
DL-serving/transcoding workloads. Replaces the two near-duplicate structs
the seed repo grew (``core.scheduler.SimResult`` and
``serving.autoscaler.AutoscalerReport``), which survive as aliases /
thin shims of :class:`Telemetry`.

Paper mapping: ``Telemetry.tpe`` is the paper's headline
throughput-per-energy metric (Fig 6, Fig 11b); ``active_units`` /
``mean_active`` is the §5.2 per-unit activation trace (Fig 12).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    """A unit of offered work, workload-agnostic.

    ``payload`` is interpreted by the workload adapter (an LM prompt, a
    batch of inference samples, a video segment, ...); ``cost`` is the
    abstract amount of work in the workload's own capacity units (tokens,
    samples, stream-seconds).
    """

    payload: Any = None
    cost: float = 1.0
    # None = unset; stamped by the runtime (or the workload) at submit.
    # 0.0 is a valid timestamp, not a sentinel.
    arrival_s: Optional[float] = None
    rid: int = -1
    meta: Dict[str, Any] = field(default_factory=dict)


@dataclass
class Response:
    """Completion record for one request."""

    rid: int
    arrival_s: float
    finish_s: float
    output: Any = None
    ok: bool = True

    @property
    def latency_s(self) -> float:
        return max(self.finish_s - self.arrival_s, 0.0)


@dataclass
class StepStats:
    """What one runtime tick did.

    The workload fills in the work-side fields from ``step()``; the
    runtime augments with the activation / power side before handing the
    tick back to the caller.
    """

    t: float = 0.0
    dt_s: float = 1.0
    # work side (from Workload.step)
    concurrency: int = 0          # requests actually in flight this tick
    admitted: int = 0             # requests newly admitted this tick
    completed: int = 0            # requests finished this tick
    queued: int = 0               # still waiting after the tick
    work_done: float = 0.0        # cost units processed this tick
    utilization: float = 0.0      # fraction of powered capacity used
    units_used: int = 0           # units the work actually occupied
    #   (0 = same as the granted target; can exceed it transiently when
    #   in-flight requests outlive a scale-down — the runtime then powers
    #   and charges the overflow units too)
    responses: List[Response] = field(default_factory=list)
    #   per-tick observational view only: the runtime delivers responses
    #   into Telemetry exactly once, via Workload.drain()
    # activation / power side (from the runtime tick)
    target_units: int = 0         # policy's activation target
    active_units: int = 0         # units actually powered this tick
    hedge_units: int = 0          # units borrowed for straggler hedging
    perf_scale: float = 1.0       # mean DVFS perf multiplier of the
    #   tenant's active units (1.0 when no OPP table is configured)
    power_w: float = 0.0
    energy_j: float = 0.0         # cumulative runtime energy after the tick


@dataclass
class Telemetry:
    """The one result struct for a serving run (real or simulated).

    Superset of the seed repo's ``SimResult`` (trace arrays, latency
    percentiles, hedging) and ``AutoscalerReport`` (tick counts, scale
    events, TpE), so both survive as aliases of this class.
    """

    time_s: np.ndarray = field(default_factory=lambda: np.zeros(0))
    offered_load: np.ndarray = field(default_factory=lambda: np.zeros(0))
    active_units: np.ndarray = field(default_factory=lambda: np.zeros(0))
    power_w: np.ndarray = field(default_factory=lambda: np.zeros(0))
    utilization: np.ndarray = field(default_factory=lambda: np.zeros(0))
    served: float = 0.0           # requests completed
    dropped: float = 0.0
    hedged: int = 0
    scale_events: int = 0
    p50_latency_s: float = 0.0
    p99_latency_s: float = 0.0
    energy_j: float = 0.0
    responses: List[Response] = field(default_factory=list)
    workload: Dict[str, Any] = field(default_factory=dict)
    # multi-tenant views (paper §2/§4-5: one cluster, many workloads).
    # For a per-tenant Telemetry, `tenant` is the tenant name and
    # `energy_j` holds only the tenant-attributable unit energy (shared
    # infrastructure power is charged once, at the cluster roll-up).
    tenant: str = ""
    unit_energy_j: float = 0.0    # sum of tenant-attributed unit energy
    per_tenant: Dict[str, "Telemetry"] = field(default_factory=dict)
    # thermal per-tick series (empty unless a thermal model is attached):
    # hottest die, number of trip-latched units, and fan power per tick
    max_temp_c: np.ndarray = field(default_factory=lambda: np.zeros(0))
    throttled_units: np.ndarray = field(default_factory=lambda: np.zeros(0))
    fan_power_w: np.ndarray = field(default_factory=lambda: np.zeros(0))

    # ----- derived ---------------------------------------------------------
    @property
    def ticks(self) -> int:
        return int(len(self.time_s))

    @property
    def duration_s(self) -> float:
        """Covered time: span of tick starts plus the final tick's width
        (taken from the last *actual* delta, so non-uniform tick spacing
        — e.g. stitched traces — is measured correctly)."""
        if len(self.time_s) < 1:
            return 0.0
        if len(self.time_s) == 1:
            return 1.0
        last_dt = self.time_s[-1] - self.time_s[-2]
        return float(self.time_s[-1] - self.time_s[0] + last_dt)

    @property
    def mean_active(self) -> float:
        return float(np.mean(self.active_units)) if len(self.active_units) \
            else 0.0

    @property
    def mean_power_w(self) -> float:
        return float(np.mean(self.power_w)) if len(self.power_w) else 0.0

    @property
    def throughput(self) -> float:
        """Requests per second over the run."""
        return self.served / max(self.duration_s, 1e-9)

    @property
    def tpe(self) -> float:
        """Throughput per energy (requests/J) — the paper's TpE."""
        return self.served / max(self.energy_j, 1e-9)

    def summary(self) -> Dict[str, float]:
        return {
            "ticks": self.ticks,
            "served": self.served,
            "dropped": self.dropped,
            "mean_active": self.mean_active,
            "energy_j": self.energy_j,
            "tpe": self.tpe,
            "throughput_rps": self.throughput,
            "p50_latency_s": self.p50_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "scale_events": self.scale_events,
            "hedged": self.hedged,
        }


def latency_percentiles(responses: List[Response]
                        ) -> "tuple[float, float]":
    """(p50, p99) request latency over a response list."""
    if not responses:
        return 0.0, 0.0
    lat = np.array([r.latency_s for r in responses])
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))
