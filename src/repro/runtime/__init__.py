"""Unified request-lifecycle runtime (paper §2, §4-5.2, Fig 11/12).

The one serving surface for the SoC-Cluster reproduction:

  * :class:`Request` / :class:`Response` / :class:`StepStats` /
    :class:`Telemetry` — the shared result model (also aliased by the
    deprecated ``core.scheduler.SimResult`` and
    ``serving.autoscaler.AutoscalerReport``); ``Telemetry`` carries
    per-tenant views under ``per_tenant``;
  * :class:`Workload` protocol with adapters :class:`LMServingWorkload`
    (live engine + continuous batcher), :class:`DLServingWorkload`
    (Fig 11/12 measured serving points), and
    :class:`TranscodingWorkload` (§4 / Table 3 stream counts);
  * :class:`UnitPool` — per-unit ``off → waking → active`` state over a
    ``ClusterSpec`` with PCB-group-aligned allocations and the cluster's
    single power integral (shared power charged once);
  * :class:`UnitGovernor` / :class:`ScalePolicy` — the activation policy
    engine (windowed rate → group-quantized target → wake/cooldown);
    with an :mod:`repro.power` OPP table on the pool,
    ``ScalePolicy.freq_governor`` adds the frequency axis (activation
    count × operating point co-optimized per tick, thermal throttling
    via the pool's trip latches);
  * :class:`MultiTenantRuntime` — N tenants on one pool, weighted-fair
    arbitration with ``min_units`` floors, runtime-level straggler
    hedging;
  * :class:`ClusterRuntime` — the single-tenant facade: one
    ``ClusterSpec`` + ``ScalePolicy`` + ``Workload``, with the
    activation target *actually gating* workload concurrency.
"""
from repro.runtime.cluster_runtime import ClusterRuntime
from repro.runtime.multi_tenant import (MultiTenantRuntime, Tenant,
                                        weighted_fair_share)
from repro.runtime.policy import ScalePolicy, UnitGovernor
from repro.runtime.pool import (UnitPool, UnitState, VectorUnitPool,
                                make_unit_pool)
from repro.runtime.result import (Request, Response, StepStats, Telemetry,
                                  latency_percentiles)
from repro.runtime.sanitize import (FleetSanitizer, InvariantViolation,
                                    PoolSanitizer, attach_fleet_sanitizer,
                                    attach_pool_sanitizer, check_pool,
                                    sanitizer_enabled)
from repro.runtime.workload import (DLServingWorkload, LMServingWorkload,
                                    QueueWorkload, TranscodingWorkload,
                                    Workload)

__all__ = [
    "ClusterRuntime", "MultiTenantRuntime", "Tenant",
    "weighted_fair_share", "UnitPool", "VectorUnitPool", "make_unit_pool",
    "UnitState", "UnitGovernor", "ScalePolicy",
    "Request", "Response", "StepStats", "Telemetry",
    "latency_percentiles",
    "Workload", "QueueWorkload", "DLServingWorkload", "LMServingWorkload",
    "TranscodingWorkload",
    "InvariantViolation", "PoolSanitizer", "FleetSanitizer",
    "attach_pool_sanitizer", "attach_fleet_sanitizer", "check_pool",
    "sanitizer_enabled",
]
