"""Unified request-lifecycle runtime (paper §5.2, Fig 11/12).

The one serving surface for the SoC-Cluster reproduction:

  * :class:`Request` / :class:`Response` / :class:`StepStats` /
    :class:`Telemetry` — the shared result model (also aliased by the
    deprecated ``core.scheduler.SimResult`` and
    ``serving.autoscaler.AutoscalerReport``);
  * :class:`Workload` protocol with adapters :class:`LMServingWorkload`
    (live engine + continuous batcher), :class:`DLServingWorkload`
    (Fig 11/12 measured serving points), and
    :class:`TranscodingWorkload` (§4 / Table 3 stream counts);
  * :class:`ClusterRuntime` — binds ``ClusterSpec`` + ``ScalePolicy`` +
    ``Workload`` and runs the canonical loop, with the activation target
    *actually gating* workload concurrency.
"""
from repro.runtime.cluster_runtime import ClusterRuntime, UnitGovernor
from repro.runtime.policy import ScalePolicy
from repro.runtime.result import (Request, Response, StepStats, Telemetry,
                                  latency_percentiles)
from repro.runtime.workload import (DLServingWorkload, LMServingWorkload,
                                    QueueWorkload, TranscodingWorkload,
                                    Workload)

__all__ = [
    "ClusterRuntime", "UnitGovernor", "ScalePolicy",
    "Request", "Response", "StepStats", "Telemetry",
    "latency_percentiles",
    "Workload", "QueueWorkload", "DLServingWorkload", "LMServingWorkload",
    "TranscodingWorkload",
]
