"""The ``Workload`` protocol and its adapters.

A workload is anything that accepts requests and makes progress when the
cluster grants it active units. The runtime drives every workload through
the same four calls:

  * ``submit(request) -> rid``   — enqueue work;
  * ``step(n_active_units, dt_s, t) -> StepStats`` — advance one tick
    using *at most* the granted concurrency (this is where the activation
    target actually gates execution). Adapters may additionally accept a
    ``perf_scale=`` keyword (the runtime passes the tenant's mean DVFS
    perf multiplier when the workload's ``step`` signature declares it);
  * ``drain() -> [Response]``    — pop completed responses. This is the
    **single delivery channel**: every response is returned by drain()
    exactly once, and the runtime folds exactly that into
    ``Telemetry.responses``. ``StepStats.responses`` is an observational
    per-tick view of the same objects, never a second delivery path;
  * ``describe() -> dict``       — static metadata (name, unit_rate, ...).

Workloads may additionally expose ``oldest_waiting_s(t) -> float | None``
(the queue-age of the oldest waiting request); the runtime uses it for
straggler hedging (paper §5.2) — a tenant whose oldest request has waited
past ``ScalePolicy.hedge_after_s`` borrows an extra unit for the tick.

Adapters:

  * :class:`LMServingWorkload` — the live continuous-batching LM engine
    (``ServingEngine`` + ``ContinuousBatcher``); active units map to
    decode slots, so gating really limits concurrency.
  * :class:`DLServingWorkload` — DL inference serving from the paper's
    measured per-SoC rates (Fig 11/12, Table 7), as a fluid queue.
  * :class:`TranscodingWorkload` — live video transcoding from the
    paper's Table 3 per-SoC stream counts (§4), as a fluid queue.
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import (Any, Deque, Dict, List, Optional, Protocol,
                    runtime_checkable)

from repro.runtime.result import Request, Response, StepStats


@runtime_checkable
class Workload(Protocol):
    """Structural protocol every runtime workload satisfies."""

    def submit(self, request: Request) -> int:
        ...

    def step(self, n_active_units: int, dt_s: float = 1.0,
             t: float = 0.0) -> StepStats:
        ...

    def drain(self) -> List[Response]:
        ...

    def describe(self) -> Dict[str, Any]:
        ...


# ---------------------------------------------------------------------------
# Fluid-queue workloads (model-driven: DL serving points, transcoding).
# ---------------------------------------------------------------------------
class QueueWorkload:
    """FIFO fluid queue: each active unit processes ``unit_rate`` cost
    units per second. Requests may carry fractional/aggregated cost (e.g.
    one request per trace tick with ``cost = rate * dt``), in which case
    ``work_done`` counts request-equivalents rather than completions.
    """

    def __init__(self, unit_rate: float, name: str = "queue",
                 kind: str = "fluid") -> None:
        assert unit_rate > 0, "unit_rate must be positive"
        self.unit_rate = unit_rate
        self.name = name
        self.kind = kind
        self._rid = itertools.count()
        # O(1) FIFO: head pops are popleft, not list.pop(0)
        self._queue: Deque[List[Any]] = deque()  # [request, remaining_cost]
        self._completed: List[Response] = []

    # -- protocol ----------------------------------------------------------
    def submit(self, request: Request) -> int:
        rid = next(self._rid)
        request.rid = rid
        if request.arrival_s is None:
            request.arrival_s = 0.0
        self._queue.append([request, float(request.cost)])
        return rid

    def _drain_tick(self, n_active_units: int, dt_s: float, t: float,
                    perf_scale: float) -> "tuple[float, float, int, int]":
        """One tick of the fluid FIFO drain — the single copy of the
        arithmetic behind both :meth:`step` and :meth:`step_fast`.
        Completed responses are appended to the :meth:`drain` channel;
        returns ``(work_done, utilization, queued, concurrency)``."""
        capacity = max(0, n_active_units) * self.unit_rate * dt_s \
            * max(perf_scale, 0.0)
        used = 0.0
        touched = 0
        queue = self._queue
        while queue and used < capacity:
            req, remaining = queue[0]
            take = min(remaining, capacity - used)
            used += take
            touched += 1
            if take >= remaining - 1e-12:
                queue.popleft()
                # finish inside the tick, at the fluid completion instant
                # (floored at one service time past arrival — at the
                # *effective* DVFS-scaled rate — latency for fluid
                # workloads has tick resolution, no better)
                frac = used / capacity if capacity > 0 else 1.0
                service_s = 1.0 / (self.unit_rate
                                   * max(perf_scale, 1e-9))
                self._completed.append(Response(
                    rid=req.rid, arrival_s=req.arrival_s,
                    finish_s=max(t + frac * dt_s,
                                 req.arrival_s + service_s),
                    output=req.payload))
            else:
                queue[0][1] = remaining - take
                break
        return (used, used / capacity if capacity > 0 else 0.0,
                len(queue), touched)

    def step(self, n_active_units: int, dt_s: float = 1.0,
             t: float = 0.0, perf_scale: float = 1.0) -> StepStats:
        before = len(self._completed)
        used, util, queued, touched = self._drain_tick(
            n_active_units, dt_s, t, perf_scale)
        responses = self._completed[before:]
        return StepStats(
            t=t, dt_s=dt_s,
            concurrency=touched,
            admitted=0,
            completed=len(responses),
            queued=queued,
            work_done=used,
            utilization=util,
            responses=responses,
        )

    def step_fast(self, n_active_units: int, dt_s: float = 1.0,
                  t: float = 0.0, perf_scale: float = 1.0
                  ) -> "tuple[float, float, int, int]":
        """Allocation-light twin of :meth:`step` for hot loops (the
        vectorized fleet engine calls it ~100k times per sweep): the
        same :meth:`_drain_tick` core, but no :class:`StepStats` —
        returns the plain ``(work_done, utilization, queued,
        concurrency)`` tuple. ``perf_scale`` is the tenant's mean DVFS
        perf multiplier, exactly as ``step`` takes it. Completed
        responses land in the :meth:`drain` channel as with ``step``."""
        return self._drain_tick(n_active_units, dt_s, t, perf_scale)

    def drain(self) -> List[Response]:
        out, self._completed = self._completed, []
        return out

    def describe(self) -> Dict[str, Any]:
        return {"name": self.name, "kind": self.kind,
                "unit_rate": self.unit_rate}

    def oldest_waiting_s(self, t: float) -> Optional[float]:
        """Queue-age of the head request (None when the queue is empty);
        feeds the runtime's straggler-hedging decision."""
        if not self._queue:
            return None
        arrival = self._queue[0][0].arrival_s
        return max(0.0, t - (arrival or 0.0))

    def expire(self, now: float, deadline_s: float) -> "tuple[int, float]":
        """Deadline-aware load shedding (``repro.fleet.degrade``):
        abandon queued requests whose arrival is ``deadline_s`` or more
        in the past, returning ``(n_requests, remaining_cost)``. The
        queue is FIFO by arrival, so expiry only ever pops from the
        head; a partially-drained head is popped too — its remainder
        is voided (the drained part stays counted as served). No
        :class:`Response` is emitted: like :meth:`evacuate`, the fleet
        layer owns the accounting. The cost sum is an explicit
        left-to-right loop so both fleet engines (which share this
        queue class) expire bitwise-identical totals."""
        cutoff = now - deadline_s + 1e-9
        n = 0
        cost = 0.0
        queue = self._queue
        while queue and (queue[0][0].arrival_s or 0.0) <= cutoff:
            _req, rem = queue.popleft()
            n += 1
            cost += rem
        return n, cost

    def evacuate(self) -> "tuple[int, float]":
        """Chaos full-rack kill: discard every queued request, returning
        ``(n_requests, remaining_cost)``. No :class:`Response` is
        emitted — the requests never complete here; the fleet layer
        decides whether their cost is respilled through the router or
        dropped (``repro.fleet.chaos``). The cost sum is an explicit
        left-to-right loop so both fleet engines (which share this
        queue class) evacuate bitwise-identical totals."""
        n = len(self._queue)
        cost = 0.0
        for _req, rem in self._queue:
            cost += rem
        self._queue.clear()
        return n, cost

    # -- helpers -----------------------------------------------------------
    @property
    def pending_cost(self) -> float:
        return sum(rem for _, rem in self._queue)

    def idle(self) -> bool:
        return not self._queue


class DLServingWorkload(QueueWorkload):
    """DL inference serving (paper §5, Fig 11/12): each active unit serves
    ``unit_rate`` samples/s, taken from a measured
    :class:`~repro.workloads.dlserving.ServingPoint` or given directly.
    Request cost is a sample count.
    """

    def __init__(self, unit_rate: float, model: str = "custom",
                 precision: str = "fp32", platform: str = "custom",
                 unit_power_w: Optional[float] = None) -> None:
        super().__init__(unit_rate, name=f"dlserving/{model}",
                         kind="dl-serving")
        self.model = model
        self.precision = precision
        self.platform = platform
        self.unit_power_w = unit_power_w

    @classmethod
    def from_point(cls, model: str, precision: str, platform: str
                   ) -> "DLServingWorkload":
        from repro.workloads.dlserving import point
        p = point(model, precision, platform)
        if p is None:
            raise KeyError(f"no serving point for "
                           f"({model}, {precision}, {platform})")
        return cls(unit_rate=1000.0 / p.latency_ms * p.batch, model=model,
                   precision=precision, platform=platform,
                   unit_power_w=p.unit_power_w)

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(model=self.model, precision=self.precision,
                 platform=self.platform, unit_power_w=self.unit_power_w)
        return d


class TranscodingWorkload(QueueWorkload):
    """Live video transcoding (paper §4, Table 3): each active SoC
    sustains ``streams_per_unit`` simultaneous live streams, i.e. it
    produces ``streams_per_unit`` stream-seconds of output per second.
    Request cost is stream-seconds (``streams * duration_s``).
    """

    def __init__(self, video: Any = None, hw_codec: bool = False,
                 streams_per_unit: Optional[float] = None) -> None:
        if streams_per_unit is None:
            assert video is not None, "need a Video or streams_per_unit"
            streams_per_unit = (video.soc_hw_streams if hw_codec
                                else video.soc_cpu_streams)
        vid = getattr(video, "vid", "custom")
        super().__init__(float(streams_per_unit),
                         name=f"transcoding/{vid}", kind="transcoding")
        self.video = video
        self.hw_codec = hw_codec

    def submit_stream(self, duration_s: float, streams: int = 1,
                      arrival_s: float = 0.0) -> int:
        """Convenience: enqueue a live stream of ``duration_s`` seconds."""
        return self.submit(Request(payload=self.video,
                                   cost=float(streams) * duration_s,
                                   arrival_s=arrival_s))

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(video=getattr(self.video, "vid", None),
                 hw_codec=self.hw_codec)
        return d


# ---------------------------------------------------------------------------
# Live LM serving (engine + continuous batcher).
# ---------------------------------------------------------------------------
class LMServingWorkload:
    """Continuous-batched LM generation behind the workload protocol.

    Active units map to decode slots (``slots_per_unit`` each): the
    runtime's activation target becomes a hard cap on how many slots the
    batcher may fill, so scaling down genuinely reduces concurrency
    instead of being accounting-only (the seed repo's dead-code path).
    """

    def __init__(self, engine: Any, slots: int, slots_per_unit: int = 1,
                 max_new_tokens: int = 16) -> None:
        from repro.serving.batcher import ContinuousBatcher
        self.engine = engine
        self.batcher = ContinuousBatcher(engine, slots=slots)
        self.slots_per_unit = max(1, int(slots_per_unit))
        self.max_new_tokens = max_new_tokens
        self._requests: Dict[int, Request] = {}
        self._completed: List[Response] = []
        self._tokens_done = 0

    # -- protocol ----------------------------------------------------------
    def submit(self, request: Request) -> int:
        mnt = int(request.meta.get("max_new_tokens", self.max_new_tokens))
        rid = self.batcher.submit(request.payload, max_new_tokens=mnt)
        request.rid = rid
        if request.arrival_s is None:
            request.arrival_s = 0.0
        self._requests[rid] = request
        return rid

    def step(self, n_active_units: int, dt_s: float = 1.0,
             t: float = 0.0, perf_scale: float = 1.0) -> StepStats:
        # perf_scale is accepted for protocol uniformity but unused: the
        # live batcher is slot-gated (one decode step per tick); DVFS
        # would change wall-clock per token, which the fluid tick model
        # does not resolve
        cap = min(self.batcher.slots,
                  max(0, n_active_units) * self.slots_per_unit)
        queued_before = len(self.batcher.queue)
        live = self.batcher.step(max_slots=cap)
        admitted = queued_before - len(self.batcher.queue)
        # in-flight requests keep their slots through a scale-down, so the
        # occupied-unit count can transiently exceed the granted target
        units_used = -(-live // self.slots_per_unit)  # ceil
        powered = max(max(0, n_active_units), units_used)
        responses: List[Response] = []
        # consume the batcher's finished list destructively so a long-
        # running serving loop doesn't retain every completed request
        done, self.batcher.finished = self.batcher.finished, []
        for breq in done:
            self._tokens_done += len(breq.generated)
            req = self._requests.pop(breq.rid,
                                     Request(arrival_s=t, rid=breq.rid))
            responses.append(Response(
                rid=breq.rid, arrival_s=req.arrival_s, finish_s=t + dt_s,
                output=list(breq.generated)))
        self._completed.extend(responses)
        return StepStats(
            t=t, dt_s=dt_s,
            concurrency=live,
            admitted=admitted,
            completed=len(responses),
            queued=len(self.batcher.queue),
            work_done=float(len(responses)),
            utilization=live / (powered * self.slots_per_unit)
            if powered > 0 else 0.0,
            units_used=units_used,
            responses=responses,
        )

    def drain(self) -> List[Response]:
        out, self._completed = self._completed, []
        return out

    def oldest_waiting_s(self, t: float) -> Optional[float]:
        """Queue-age of the oldest request still waiting for a decode
        slot (None when none queue); feeds straggler hedging."""
        if not self.batcher.queue:
            return None
        src = self._requests.get(self.batcher.queue[0].rid)
        if src is None or src.arrival_s is None:
            return None
        return max(0.0, t - src.arrival_s)

    def max_useful_units(self) -> int:
        """Beyond this many units the slot cap binds — granting (or
        hedging) more adds no concurrency, only powered silicon."""
        return -(-self.batcher.slots // self.slots_per_unit)

    def describe(self) -> Dict[str, Any]:
        return {"name": f"lm-serving/{self.engine.cfg.name}",
                "kind": "lm-serving",
                "slots": self.batcher.slots,
                "slots_per_unit": self.slots_per_unit,
                "arch": self.engine.cfg.name,
                "quantized": self.engine.scfg.quantize_weights}

    # -- helpers -----------------------------------------------------------
    def idle(self) -> bool:
        return (not self.batcher.queue
                and all(a is None for a in self.batcher.active))

    @property
    def tokens_generated(self) -> int:
        return self._tokens_done \
            + sum(len(r.generated) for r in self.batcher.finished) \
            + sum(len(r.generated) for r in self.batcher.active
                  if r is not None)
