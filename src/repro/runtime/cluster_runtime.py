"""`ClusterRuntime` — the canonical request-lifecycle loop (paper §5.2).

One loop, shared by every workload and benchmark:

  arrival recording → target-unit computation → **gating of workload
  concurrency to the activation target** → per-tick energy accounting.

The seed repo computed the autoscaler's target and then ignored it (the
batcher always filled every slot); here the target is handed to
``Workload.step(n_active_units)`` which must not exceed it, so scaling
down genuinely sheds concurrency — the paper's "activate only the units
the offered load needs" (Fig 12).

Typical use::

    from repro.core.cluster import soc_cluster
    from repro.core.scheduler import ScalePolicy, diurnal_trace
    from repro.runtime import ClusterRuntime, DLServingWorkload

    wl = DLServingWorkload.from_point("resnet-50", "fp32", "soc-gpu")
    rt = ClusterRuntime(soc_cluster(), wl, policy=ScalePolicy())
    tel = rt.play_trace(diurnal_trace(peak_rps=1500, hours=24), dt_s=60.0)
    print(tel.summary())          # energy_j, tpe, mean_active, p99, ...
"""
from __future__ import annotations

import warnings
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.runtime.policy import ScalePolicy
from repro.runtime.result import (Request, Response, StepStats, Telemetry,
                                  latency_percentiles)
from repro.runtime.workload import Workload


class UnitGovernor:
    """Activation policy + energy meter for a :class:`ClusterSpec`.

    Pure bookkeeping (no workload knowledge): records arrivals, estimates
    the offered rate over a sliding window, tracks the active-unit count
    under the :class:`ScalePolicy` (immediate scale-up with optional wake
    latency, cooldown-hysteresis scale-down), and integrates the cluster
    power model per tick. Shared by :class:`ClusterRuntime` and the
    deprecated ``serving.autoscaler.ServingAutoscaler`` shim.
    """

    def __init__(self, spec: ClusterSpec, unit_rate: float,
                 policy: Optional[ScalePolicy] = None,
                 window_s: float = 10.0, idle_units_off: bool = True,
                 model_wake_latency: bool = False, group_units: int = 1):
        assert unit_rate > 0, "unit_rate must be positive"
        self.spec = spec
        self.unit_rate = unit_rate
        self.policy = policy or ScalePolicy()
        if self.policy.hedge_after_s is not None:
            warnings.warn(
                "ScalePolicy.hedge_after_s is only honored by the "
                "ElasticScheduler simulation; UnitGovernor/ClusterRuntime "
                "ignore it", RuntimeWarning, stacklevel=3)
        self.window_s = window_s
        self.idle_units_off = idle_units_off
        self.model_wake_latency = model_wake_latency
        # units activate in groups of this size (e.g. an n-SoC tensor-
        # parallel collaboration group, §5.3): targets are rounded up to
        # a whole number of groups so no unit is stranded in a partial one
        self.group_units = max(1, int(group_units))
        assert self.group_units <= spec.n_units, \
            f"group_units={group_units} exceeds cluster size {spec.n_units}"
        self.active_units = self._quantize(self.policy.min_units)
        self._arrivals: List[Tuple[float, float]] = []   # (t, count)
        self._pending_wake: List[Tuple[float, int]] = []  # (ready_t, count)
        self._last_downscale = -1e9
        self.energy_j = 0.0
        self.served = 0.0
        self.scale_events = 0
        # per-tick history
        self.t_hist: List[float] = []
        self.offered_hist: List[float] = []
        self.active_hist: List[int] = []
        self.power_hist: List[float] = []
        self.util_hist: List[float] = []

    # ------------------------------------------------------------------
    def record_arrival(self, t: float, n: float = 1) -> None:
        if n > 0:
            self._arrivals.append((float(t), float(n)))

    def offered_rate(self, t: float) -> float:
        # strict cutoff: an arrival exactly window_s old has left the
        # window (otherwise tick-bucketed traces double-count the edge)
        cutoff = t - self.window_s
        self._arrivals = [(a, n) for a, n in self._arrivals if a > cutoff]
        return sum(n for _, n in self._arrivals) / self.window_s

    def _quantize(self, units: int) -> int:
        g = self.group_units
        whole = -(-int(units) // g) * g          # ceil to whole groups
        if whole > self.spec.n_units:            # keep only full groups
            whole = self.spec.n_units // g * g
        return max(g, whole)

    def target_units(self, offered: float) -> int:
        need = offered * self.policy.headroom / self.unit_rate
        raw = int(min(self.spec.n_units,
                      max(self.policy.min_units, np.ceil(need))))
        return self._quantize(raw)

    # ------------------------------------------------------------------
    def update(self, t: float, dt_s: float = 1.0,
               offered: Optional[float] = None) -> int:
        """Advance the activation state one tick; returns the active-unit
        count the workload may use this tick.

        Wake handling mirrors the ElasticScheduler simulation: a unit
        waking within the tick serves the whole tick (fluid model), so
        ``model_wake_latency`` only delays activation when
        ``wake_latency_s > dt_s`` — with the 0.5 s default and >= 1 s
        ticks it changes nothing."""
        rate = self.offered_rate(t) if offered is None else offered
        tgt = self.target_units(rate)
        p = self.policy
        wake_s = p.wake_latency_s if self.model_wake_latency else 0.0
        waking = sum(c for _, c in self._pending_wake)
        if tgt > self.active_units + waking:
            self._pending_wake.append(
                (t + wake_s, tgt - self.active_units - waking))
            self.scale_events += 1
        elif tgt < self.active_units and \
                t - self._last_downscale > p.cooldown_s:
            self.active_units = max(self._quantize(p.min_units), tgt)
            self._last_downscale = t
            self.scale_events += 1
        ready = sum(c for rt, c in self._pending_wake if rt <= t + dt_s)
        self._pending_wake = [(rt, c) for rt, c in self._pending_wake
                              if rt > t + dt_s]
        self.active_units = min(self.spec.n_units,
                                self.active_units + ready)
        self._tick_rate = rate
        return self.active_units

    def charge(self, t: float, utilization: float, dt_s: float = 1.0,
               served: float = 0.0, extra_units: int = 0) -> float:
        """Account one tick of energy at the current activation; returns
        the tick's power draw in watts."""
        act = min(self.spec.n_units, self.active_units + extra_units)
        power = self.spec.power(act, min(max(utilization, 0.0), 1.0),
                                idle_units_off=self.idle_units_off)
        self.energy_j += power * dt_s
        self.served += served
        self.t_hist.append(t)
        self.offered_hist.append(getattr(self, "_tick_rate", 0.0))
        self.active_hist.append(act)
        self.power_hist.append(power)
        self.util_hist.append(utilization)
        return power

    # ------------------------------------------------------------------
    def telemetry(self, responses: Optional[List[Response]] = None,
                  workload: Optional[dict] = None) -> Telemetry:
        p50, p99 = latency_percentiles(responses or [])
        return Telemetry(
            time_s=np.asarray(self.t_hist, float),
            offered_load=np.asarray(self.offered_hist, float),
            active_units=np.asarray(self.active_hist, float),
            power_w=np.asarray(self.power_hist, float),
            utilization=np.asarray(self.util_hist, float),
            served=self.served,
            scale_events=self.scale_events,
            p50_latency_s=p50,
            p99_latency_s=p99,
            energy_j=self.energy_j,
            responses=list(responses or []),
            workload=dict(workload or {}),
        )


class ClusterRuntime:
    """Binds a :class:`ClusterSpec`, a :class:`ScalePolicy`, and a
    :class:`Workload`; runs the canonical submit/tick/account loop."""

    def __init__(self, spec: ClusterSpec, workload: Workload,
                 policy: Optional[ScalePolicy] = None,
                 unit_rate: Optional[float] = None,
                 window_s: float = 10.0, dt_s: float = 1.0,
                 idle_units_off: bool = True,
                 model_wake_latency: bool = False, group_units: int = 1):
        # model_wake_latency matters only for sub-tick resolution
        # (wake_latency_s > dt_s); see UnitGovernor.update.
        if unit_rate is None:
            unit_rate = workload.describe().get("unit_rate")
        if unit_rate is None:
            raise ValueError(
                "unit_rate not derivable from workload.describe(); pass "
                "unit_rate= (requests/s one unit sustains) explicitly")
        self.spec = spec
        self.workload = workload
        self.dt_s = dt_s
        self.governor = UnitGovernor(
            spec, unit_rate, policy, window_s=window_s,
            idle_units_off=idle_units_off,
            model_wake_latency=model_wake_latency,
            group_units=group_units)
        self._t = 0.0
        self._responses: List[Response] = []

    # ------------------------------------------------------------------
    @property
    def now(self) -> float:
        return self._t

    @property
    def active_units(self) -> int:
        return self.governor.active_units

    def submit(self, payload: Any = None, *, cost: float = 1.0,
               count: float = 1.0, request: Optional[Request] = None,
               **meta: Any) -> int:
        """Record an arrival at the current runtime clock and hand the
        request to the workload. ``count`` weights the arrival-rate
        estimate (use ``count=cost`` for aggregated fluid requests)."""
        req = request or Request(payload=payload, cost=cost,
                                 arrival_s=self._t, meta=meta)
        if req.arrival_s is None:
            req.arrival_s = self._t
        self.governor.record_arrival(self._t, count)
        return self.workload.submit(req)

    def tick(self, dt_s: Optional[float] = None) -> StepStats:
        """One canonical iteration: update activation target, let the
        workload advance under that concurrency cap, charge energy."""
        dt = self.dt_s if dt_s is None else dt_s
        t = self._t
        active = self.governor.update(t, dt)
        stats = self.workload.step(active, dt, t)
        stats.t, stats.dt_s = t, dt
        stats.target_units = active
        # in-flight work that outlived a scale-down stays powered
        extra = max(0, stats.units_used - active) if stats.units_used else 0
        stats.active_units = active + extra
        stats.power_w = self.governor.charge(
            t, stats.utilization, dt, served=stats.work_done,
            extra_units=extra)
        stats.energy_j = self.governor.energy_j
        self._responses.extend(stats.responses)
        self._t = t + dt
        return stats

    def run(self, max_ticks: int = 100000) -> Telemetry:
        """Tick until the workload is fully drained (or ``max_ticks``)."""
        for _ in range(max_ticks):
            stats = self.tick()
            if stats.queued == 0 and stats.concurrency == 0:
                break
        self.workload.drain()
        return self.telemetry()

    def play_trace(self, trace_rps: Sequence[float],
                   dt_s: Optional[float] = None,
                   drain: bool = True) -> Telemetry:
        """Drive the runtime with an offered-load trace (requests/s per
        tick), e.g. :func:`repro.core.scheduler.diurnal_trace`. Each tick
        submits one aggregated request of ``rate * dt`` request-
        equivalents, then runs the canonical loop."""
        dt = self.dt_s if dt_s is None else dt_s
        # The rate estimator needs the window to cover at least one tick;
        # widen it for the duration of the playback only.
        saved_window = self.governor.window_s
        self.governor.window_s = max(saved_window, dt)
        try:
            for rate in trace_rps:
                work = float(rate) * dt
                if work > 0:
                    # arrivals are spread across the tick; stamp the
                    # aggregate at the tick midpoint so fluid latency
                    # isn't inflated by a full tick width
                    self.submit(count=work, request=Request(
                        cost=work, arrival_s=self._t + 0.5 * dt))
                self.tick(dt)
            if drain:
                for _ in range(10 * len(trace_rps) + 100):
                    stats = self.tick(dt)
                    if stats.queued == 0 and stats.concurrency == 0:
                        break
        finally:
            self.governor.window_s = saved_window
        self.workload.drain()
        return self.telemetry()

    # ------------------------------------------------------------------
    def telemetry(self) -> Telemetry:
        return self.governor.telemetry(self._responses,
                                       self.workload.describe())

    def static_baseline_energy(self, utilization: float = 1.0) -> float:
        """Energy the same span would have cost with every unit powered
        (the monolithic / no-gating baseline of Fig 12)."""
        ticks = len(self.governor.t_hist)
        if ticks == 0:
            return 0.0
        # reconstruct per-tick dt from the recorded clock
        ts = self.governor.t_hist
        dts = [t2 - t1 for t1, t2 in zip(ts, ts[1:])]
        dts.append(dts[-1] if dts else self.dt_s)
        p = self.spec.power(self.spec.n_units, utilization,
                            idle_units_off=False)
        return p * float(sum(dts))
