"""`ClusterRuntime` — the canonical request-lifecycle loop (paper §5.2).

One loop, shared by every workload and benchmark:

  arrival recording → target-unit computation → **gating of workload
  concurrency to the activation target** → per-tick energy accounting.

Since the unit-allocation refactor this is a thin single-tenant facade
over :class:`~repro.runtime.multi_tenant.MultiTenantRuntime`: the
activation state lives in a :class:`~repro.runtime.pool.UnitPool`, the
wake/cooldown policy loop lives once in
:class:`~repro.runtime.policy.UnitGovernor`, and straggler hedging
(``ScalePolicy.hedge_after_s``) is honored by the runtime proper — a
request stuck past the deadline borrows a free unit for the tick and is
charged for it.

Typical use::

    from repro.core.cluster import soc_cluster
    from repro.core.scheduler import ScalePolicy, diurnal_trace
    from repro.runtime import ClusterRuntime, DLServingWorkload

    wl = DLServingWorkload.from_point("resnet-50", "fp32", "soc-gpu")
    rt = ClusterRuntime(soc_cluster(), wl, policy=ScalePolicy())
    tel = rt.play_trace(diurnal_trace(peak_rps=1500, hours=24), dt_s=60.0)
    print(tel.summary())          # energy_j, tpe, mean_active, p99, ...
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Union

from repro.core.cluster import ClusterSpec
from repro.power.opp import OPPTable
from repro.power.thermal import ThermalModel, ThermalParams
from repro.runtime.multi_tenant import MultiTenantRuntime, Tenant
from repro.runtime.policy import ScalePolicy, UnitGovernor
from repro.runtime.result import Request, StepStats, Telemetry
from repro.runtime.workload import Workload

__all__ = ["ClusterRuntime", "UnitGovernor"]


class ClusterRuntime(MultiTenantRuntime):
    """Binds a :class:`ClusterSpec`, a :class:`ScalePolicy`, and a single
    :class:`Workload`; runs the canonical submit/tick/account loop as a
    one-tenant :class:`MultiTenantRuntime`."""

    _TENANT = "default"

    def __init__(self, spec: ClusterSpec, workload: Workload,
                 policy: Optional[ScalePolicy] = None,
                 unit_rate: Optional[float] = None,
                 window_s: float = 10.0, dt_s: float = 1.0,
                 idle_units_off: bool = True,
                 model_wake_latency: bool = False, group_units: int = 1,
                 opp_table: Optional[OPPTable] = None,
                 thermal: Union[ThermalParams, ThermalModel, None] = None,
                 backend: str = "scalar") -> None:
        # model_wake_latency matters only for sub-tick resolution
        # (wake_latency_s > dt_s); see UnitGovernor.apply_target.
        if unit_rate is None:
            unit_rate = workload.describe().get("unit_rate")
        if unit_rate is None:
            raise ValueError(
                "unit_rate not derivable from workload.describe(); pass "
                "unit_rate= (requests/s one unit sustains) explicitly")
        super().__init__(
            spec,
            [Tenant(self._TENANT, workload, policy=policy,
                    unit_rate=unit_rate, group_units=group_units)],
            dt_s=dt_s, window_s=window_s, idle_units_off=idle_units_off,
            model_wake_latency=model_wake_latency,
            opp_table=opp_table, thermal=thermal, backend=backend)
        self.workload = workload

    # ------------------------------------------------------------------
    @property
    def governor(self) -> UnitGovernor:
        return self._states[self._TENANT].governor

    @property
    def active_units(self) -> int:
        return self.governor.active_units

    def submit(self, payload: Any = None, *, cost: float = 1.0,
               count: float = 1.0, request: Optional[Request] = None,
               **meta: Any) -> int:
        """Record an arrival at the current runtime clock and hand the
        request to the workload. ``count`` weights the arrival-rate
        estimate (use ``count=cost`` for aggregated fluid requests)."""
        return super().submit(self._TENANT, payload=payload, cost=cost,
                              count=count, request=request, **meta)

    def tick(self, dt_s: Optional[float] = None) -> StepStats:
        """One canonical iteration: update activation target, let the
        workload advance under that concurrency cap, charge energy.
        ``power_w``/``energy_j`` on the returned stats are cluster-level
        (shared power included)."""
        stats = self._tick_all(dt_s)[self._TENANT]
        stats.power_w = self.pool.last_power_w
        stats.energy_j = self.pool.energy_j
        return stats

    def play_trace(self, trace_rps: Sequence[float],
                   dt_s: Optional[float] = None,
                   drain: bool = True) -> Telemetry:
        """Drive the runtime with an offered-load trace (requests/s per
        tick), e.g. :func:`repro.core.scheduler.diurnal_trace`."""
        return self.play_traces({self._TENANT: trace_rps}, dt_s=dt_s,
                                drain=drain)

    # ------------------------------------------------------------------
    def telemetry(self) -> Telemetry:
        return self.cluster_telemetry()
