"""``UnitPool`` — per-unit activation state over a :class:`ClusterSpec`.

The pool is the single owner of which physical units are powered (paper
§5.2: per-SoC power gating). Every unit is in one of three states —
``off → waking → active`` — and allocations are handed out
**PCB-group-aligned**: a tenant's units are packed into as few
``ClusterSpec.group_size`` groups as possible (filling groups the tenant
already occupies first, then wholly-free groups), so tensor-parallel
collaboration groups (§5.3) are not stranded across half-empty PCBs.

The pool also owns the cluster's **single power integral**: shared
infrastructure power (``ClusterSpec.p_shared`` — fans, switch boards,
BMC) is charged exactly once per tick no matter how many tenants share
the cluster, while each tenant's powered units are metered at that
tenant's utilization and attributed to ``tenant_energy_j``.
"""
from __future__ import annotations

from enum import Enum
from typing import Dict, List, Optional, Tuple

from repro.core.cluster import ClusterSpec


class UnitState(str, Enum):
    OFF = "off"
    WAKING = "waking"
    ACTIVE = "active"


class UnitPool:
    """Tracks per-unit state and hands out group-aligned allocations.

    Tenants are identified by name. ``wake`` claims free units (they
    serve only after ``advance`` passes their ready time), ``release``
    powers active units back off, and ``charge`` integrates the cluster
    power model for one tick. Waking units draw the same rest power as
    off/idle units (they are not serving yet) but are *owned* — they are
    unavailable to other tenants and to hedging.
    """

    def __init__(self, spec: ClusterSpec, idle_units_off: bool = True):
        self.spec = spec
        self.idle_units_off = idle_units_off
        n = spec.n_units
        self.state: List[UnitState] = [UnitState.OFF] * n
        self.owner: List[Optional[str]] = [None] * n
        self._ready_t: List[float] = [0.0] * n
        self._groups = spec.groups()
        # accounting (cluster level; shared power charged once)
        self.energy_j = 0.0
        self.served = 0.0
        self.tenant_energy_j: Dict[str, float] = {}
        self.last_power_w = 0.0
        # cluster-level per-tick history
        self.t_hist: List[float] = []
        self.power_hist: List[float] = []
        self.active_hist: List[int] = []
        self.util_hist: List[float] = []
        self.offered_hist: List[float] = []
        self.served_hist: List[float] = []

    # -- queries -----------------------------------------------------------
    def active(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is UnitState.ACTIVE)

    def waking(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is UnitState.WAKING)

    def owned(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is not UnitState.OFF)

    def units_of(self, tenant: str) -> List[int]:
        return [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is not UnitState.OFF]

    def n_allocated(self) -> int:
        return sum(1 for s in self.state if s is not UnitState.OFF)

    def n_active(self) -> int:
        return sum(1 for s in self.state if s is UnitState.ACTIVE)

    def free_units(self) -> int:
        return self.spec.n_units - self.n_allocated()

    # -- placement ---------------------------------------------------------
    def _group_key(self, gi: int, tenant: str) -> Tuple[int, int, int, int]:
        g = self._groups[gi]
        mine = sum(1 for u in g if self.owner[u] == tenant
                   and self.state[u] is not UnitState.OFF)
        free = sum(1 for u in g if self.state[u] is UnitState.OFF)
        # pack into groups the tenant already occupies, then wholly-free
        # groups, then whatever has the most room
        return (0 if mine else 1, 0 if free == len(g) else 1, -free, gi)

    def _pick_units(self, tenant: str, k: int) -> List[int]:
        if k <= 0:
            return []
        out: List[int] = []
        for gi in sorted(range(len(self._groups)),
                         key=lambda gi: self._group_key(gi, tenant)):
            for u in self._groups[gi]:
                if self.state[u] is UnitState.OFF:
                    out.append(u)
                    if len(out) == k:
                        return out
        return out

    # -- transitions -------------------------------------------------------
    def wake(self, tenant: str, k: int, ready_t: float) -> int:
        """Claim up to ``k`` free units for ``tenant``; they become active
        once ``advance`` passes ``ready_t``. Returns the claimed count."""
        picked = self._pick_units(tenant, k)
        for u in picked:
            self.state[u] = UnitState.WAKING
            self.owner[u] = tenant
            self._ready_t[u] = ready_t
        return len(picked)

    def release(self, tenant: str, k: int) -> int:
        """Power off up to ``k`` of the tenant's *active* units, vacating
        its least-occupied groups first so allocations stay packed."""
        if k <= 0:
            return 0
        mine = [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is UnitState.ACTIVE]
        occupancy = {gi: 0 for gi in range(len(self._groups))}
        for u in mine:
            occupancy[u // self.spec.group_size] += 1
        mine.sort(key=lambda u: (occupancy[u // self.spec.group_size], -u))
        released = 0
        for u in mine[:k]:
            self.state[u] = UnitState.OFF
            self.owner[u] = None
            released += 1
        return released

    def advance(self, t: float, dt_s: float,
                tenant: Optional[str] = None) -> int:
        """Waking units whose ready time falls within this tick become
        active (fluid model: a unit waking within the tick serves it)."""
        woke = 0
        for u in range(self.spec.n_units):
            if self.state[u] is UnitState.WAKING \
                    and (tenant is None or self.owner[u] == tenant) \
                    and self._ready_t[u] <= t + dt_s:
                self.state[u] = UnitState.ACTIVE
                woke += 1
        return woke

    def force_active(self, tenant: str, k: int) -> None:
        """Set the tenant's active-unit count to exactly ``k``, skipping
        wake latency (initial floors, tests, compatibility setters)."""
        cur = self.active(tenant)
        if cur > k:
            self.release(tenant, cur - k)
        elif cur < k:
            for u in self._pick_units(tenant, k - cur):
                self.state[u] = UnitState.ACTIVE
                self.owner[u] = tenant

    # -- accounting --------------------------------------------------------
    def charge(self, t: float, dt_s: float, utils: Dict[str, float],
               extra: Optional[Dict[str, int]] = None,
               offered: float = 0.0, served: float = 0.0,
               ) -> Tuple[float, Dict[str, float], Dict[str, int]]:
        """Integrate one tick of cluster power: shared power once, each
        tenant's powered units (allocation + borrowed/overflow ``extra``)
        at that tenant's utilization, the rest at the off/idle floor.

        Returns ``(total_power_w, per_tenant_power_w, per_tenant_powered)``.
        """
        extra = extra or {}
        n = self.spec.n_units
        powered: Dict[str, int] = {
            name: self.active(name) + max(0, int(extra.get(name, 0)))
            for name in utils}
        total_powered = sum(powered.values())
        if total_powered > n:
            # can't power more than n units: trim the extras, largest first
            over = total_powered - n
            for name in sorted(powered, key=lambda m: -powered[m]):
                cut = min(over, max(0, powered[name] - self.active(name)))
                powered[name] -= cut
                over -= cut
                if over == 0:
                    break
            total_powered = sum(powered.values())
        unit = self.spec.unit
        p_tenant: Dict[str, float] = {}
        p_units = 0.0
        for name, cnt in powered.items():
            u = min(max(utils[name], 0.0), 1.0)
            p = cnt * unit.power(u)
            p_tenant[name] = p
            p_units += p
        rest = n - total_powered
        p_rest = rest * (unit.p_off if self.idle_units_off else unit.p_idle)
        total = self.spec.p_shared + p_units + p_rest
        self.energy_j += total * dt_s
        self.served += served
        for name, p in p_tenant.items():
            self.tenant_energy_j[name] = \
                self.tenant_energy_j.get(name, 0.0) + p * dt_s
        self.last_power_w = total
        cap = float(total_powered)
        util_agg = sum(powered[m] * min(max(utils[m], 0.0), 1.0)
                       for m in powered) / cap if cap else 0.0
        self.t_hist.append(t)
        self.power_hist.append(total)
        self.active_hist.append(total_powered)
        self.util_hist.append(util_agg)
        self.offered_hist.append(offered)
        self.served_hist.append(served)
        return total, p_tenant, powered
