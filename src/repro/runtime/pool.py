"""``UnitPool`` — per-unit activation state over a :class:`ClusterSpec`.

The pool is the single owner of which physical units are powered (paper
§5.2: per-SoC power gating). Every unit is in one of three states —
``off → waking → active`` — and allocations are handed out
**PCB-group-aligned**: a tenant's units are packed into as few
``ClusterSpec.group_size`` groups as possible (filling groups the tenant
already occupies first, then wholly-free groups), so tensor-parallel
collaboration groups (§5.3) are not stranded across half-empty PCBs.

The pool also owns the cluster's **single power integral**: shared
infrastructure power (``ClusterSpec.p_shared`` — fans, switch boards,
BMC) is charged exactly once per tick no matter how many tenants share
the cluster, while each tenant's powered units are metered at that
tenant's utilization and attributed to ``tenant_energy_j``.

With an :class:`~repro.power.opp.OPPTable` attached the pool also owns
the **frequency axis**: every unit carries a requested operating point
(set per tenant via :meth:`set_opp`), a thermal trip latch may force it
down to the lowest OPP, and :meth:`charge` meters each unit at its
*effective* OPP's f·V² power scale while stepping the RC thermal
network (fan power rides on the shared rail). With no table configured
— the default — every DVFS path is skipped and the pool behaves
bit-for-bit like the pre-power-layer code.

Two interchangeable backends implement the same API:

  * :class:`UnitPool` (``backend="scalar"``) — the reference
    implementation: Python lists and per-unit loops;
  * :class:`VectorUnitPool` (``backend="vector"``) — numpy state
    arrays, mask/lexsort transitions, and exact integer caches for the
    hot-path queries.

Both backends route every floating-point reduction through the same
order-pinned helpers (:func:`_power_from_opp_counts`,
:func:`_perf_from_opp_counts`), so their telemetry — energy integrals,
power/active histories, temperature and throttle histograms — is
**bitwise identical**; only the wall-clock differs. Construct via
:func:`make_unit_pool` (or the runtimes' ``backend=`` argument).
"""
from __future__ import annotations

from enum import Enum
from typing import (TYPE_CHECKING, Any, Dict, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.core.cluster import ClusterSpec, UnitSpec
from repro.power.opp import OPPTable, unit_power
from repro.power.thermal import (ThermalModel, ThermalParams,
                                 VectorThermalModel)

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (obs is optional)
    from repro.obs.attribution import EnergyLedger


class UnitState(str, Enum):
    OFF = "off"
    WAKING = "waking"
    ACTIVE = "active"


# Integer state codes of the vector backend (index == _STATE_ENUM order).
_OFF, _WAKING, _ACTIVE = 0, 1, 2
_STATE_ENUM = (UnitState.OFF, UnitState.WAKING, UnitState.ACTIVE)


def _power_from_opp_counts(unit: UnitSpec, util: float, table: OPPTable,
                           counts: Sequence[int],
                           ) -> Tuple[float, List[float]]:
    """Tenant unit power from per-OPP active-unit counts.

    Accumulates in ascending OPP order in *both* backends, so the
    floating-point sum is order-pinned — this (plus exact integer
    counts) is what makes ``backend="vector"`` bitwise-identical to
    ``"scalar"``. Returns ``(tenant_power_w, per_opp_unit_power_w)``.
    """
    total = 0.0
    pw = [0.0] * len(counts)
    for k in range(len(counts)):
        c = counts[k]
        if c:
            w = unit_power(unit, util, table[k])
            pw[k] = w
            total += c * w
    return total, pw


def _perf_from_opp_counts(table: OPPTable, counts: Sequence[int]) -> float:
    """Mean perf-scale over active units, from per-OPP counts (same
    order-pinning argument as :func:`_power_from_opp_counts`)."""
    s = 0.0
    n = 0
    for k in range(len(counts)):
        c = counts[k]
        if c:
            s += c * table[k].perf_scale
            n += c
    return s / n


class UnitPool:
    """Tracks per-unit state and hands out group-aligned allocations.

    Tenants are identified by name. ``wake`` claims free units (they
    serve only after ``advance`` passes their ready time), ``release``
    powers active units back off, and ``charge`` integrates the cluster
    power model for one tick. Waking units draw the same rest power as
    off/idle units (they are not serving yet) but are *owned* — they are
    unavailable to other tenants and to hedging.
    """

    backend = "scalar"

    def __init__(self, spec: ClusterSpec, idle_units_off: bool = True,
                 opp_table: Optional[OPPTable] = None,
                 thermal: Union[ThermalParams, ThermalModel, None] = None) -> None:
        if isinstance(thermal, ThermalParams):
            thermal = ThermalModel(spec, thermal)
        self._init_common(spec, idle_units_off, opp_table, thermal)
        n = spec.n_units
        nominal = opp_table.nominal if opp_table is not None else 0
        self.state: List[UnitState] = [UnitState.OFF] * n
        self.owner: List[Optional[str]] = [None] * n
        self._ready_t: List[float] = [0.0] * n
        self._req_opp: List[int] = [nominal] * n

    def _init_common(self, spec: ClusterSpec, idle_units_off: bool,
                     opp_table: Optional[OPPTable],
                     thermal: Optional[ThermalModel]) -> None:
        self.spec = spec
        self.idle_units_off = idle_units_off
        self._groups = spec.groups()
        # DVFS state (absent by default: strictly additive)
        assert opp_table is not None or thermal is None, \
            "thermal throttling needs an opp_table to throttle within"
        self.opp_table = opp_table
        self.thermal: Optional[ThermalModel] = thermal
        self._max_sustainable: Optional[int] = None
        self._tenant_opp: Dict[str, int] = {}
        # accounting (cluster level; shared power charged once)
        self.energy_j = 0.0
        self.served = 0.0
        self.tenant_energy_j: Dict[str, float] = {}
        self.last_power_w = 0.0
        # cluster-level per-tick history
        self.t_hist: List[float] = []
        self.power_hist: List[float] = []
        self.active_hist: List[int] = []
        self.util_hist: List[float] = []
        self.offered_hist: List[float] = []
        self.served_hist: List[float] = []
        # filled only when a thermal model is attached
        self.max_temp_hist: List[float] = []
        self.throttled_hist: List[int] = []
        self.fan_power_hist: List[float] = []
        # observability (attach_ledger): when unattached — the default —
        # charge() pays exactly one is-None check per tick
        self._obs_ledger: Optional["EnergyLedger"] = None
        self._obs_rack = ""

    # -- queries -----------------------------------------------------------
    def active(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is UnitState.ACTIVE)

    def waking(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is UnitState.WAKING)

    def owned(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is not UnitState.OFF)

    def units_of(self, tenant: str) -> List[int]:
        return [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is not UnitState.OFF]

    def n_allocated(self) -> int:
        return sum(1 for s in self.state if s is not UnitState.OFF)

    def n_active(self) -> int:
        return sum(1 for s in self.state if s is UnitState.ACTIVE)

    def n_waking_total(self) -> int:
        return sum(1 for s in self.state if s is UnitState.WAKING)

    def free_units(self) -> int:
        return self.spec.n_units - self.n_allocated()

    # -- DVFS --------------------------------------------------------------
    def set_opp(self, tenant: str, idx: int) -> None:
        """Request an operating point for all of ``tenant``'s units (a
        thermal trip latch can still force individual units lower)."""
        if self.opp_table is None:
            return
        idx = self.opp_table.clamp(idx)
        self._tenant_opp[tenant] = idx
        for u in range(self.spec.n_units):
            if self.owner[u] == tenant:
                self._req_opp[u] = idx

    def effective_opp(self, u: int) -> int:
        """The OPP unit ``u`` actually runs at: its requested point, or
        the table's lowest while its thermal trip latch is set."""
        assert self.opp_table is not None
        if self.thermal is not None and self.thermal.throttled[u]:
            return self.opp_table.lowest
        return self._req_opp[u]

    def _tenant_opp_of(self, tenant: str) -> int:
        assert self.opp_table is not None
        return self._tenant_opp.get(tenant, self.opp_table.nominal)

    def perf_scale(self, tenant: str) -> float:
        """Mean service-rate multiplier over the tenant's active units
        (1.0 with no OPP table, or at the nominal point). Throttled
        units drag the mean down — this is what the workload's capacity
        is scaled by."""
        if self.opp_table is None:
            return 1.0
        mine = self._active_units_of(tenant)
        if len(mine) == 0:
            return self.opp_table[self._tenant_opp_of(tenant)].perf_scale
        return _perf_from_opp_counts(self.opp_table, self._opp_counts(mine))

    def max_sustainable_opp(self) -> Optional[int]:
        """Thermal ceiling for governors (None without a thermal model):
        the highest OPP a fully-loaded, fully-occupied PCB group can
        hold forever without tripping. Constant over the pool's lifetime
        (params, unit, and table are fixed at construction), so it is
        computed once and cached — governors consult it every tick."""
        if self.thermal is None or self.opp_table is None:
            return None
        if self._max_sustainable is None:
            self._max_sustainable = self.thermal.max_sustainable_index(
                self.spec.unit, self.opp_table)
        return self._max_sustainable

    # -- placement ---------------------------------------------------------
    def _group_key(self, gi: int, tenant: str) -> Tuple[int, int, int, int]:
        g = self._groups[gi]
        mine = sum(1 for u in g if self.owner[u] == tenant
                   and self.state[u] is not UnitState.OFF)
        free = sum(1 for u in g if self.state[u] is UnitState.OFF)
        # pack into groups the tenant already occupies, then wholly-free
        # groups, then whatever has the most room
        return (0 if mine else 1, 0 if free == len(g) else 1, -free, gi)

    def _pick_units(self, tenant: str, k: int) -> List[int]:
        if k <= 0:
            return []
        out: List[int] = []
        for gi in sorted(range(len(self._groups)),
                         key=lambda gi: self._group_key(gi, tenant)):
            for u in self._groups[gi]:
                if self.state[u] is UnitState.OFF:
                    out.append(u)
                    if len(out) == k:
                        return out
        return out

    # -- transitions -------------------------------------------------------
    def wake(self, tenant: str, k: int, ready_t: float) -> int:
        """Claim up to ``k`` free units for ``tenant``; they become active
        once ``advance`` passes ``ready_t``. Returns the claimed count."""
        picked = self._pick_units(tenant, k)
        for u in picked:
            self.state[u] = UnitState.WAKING
            self.owner[u] = tenant
            self._ready_t[u] = ready_t
            if self.opp_table is not None:
                self._req_opp[u] = self._tenant_opp_of(tenant)
        return len(picked)

    def release(self, tenant: str, k: int) -> int:
        """Power off up to ``k`` of the tenant's units. Still-waking
        units are cancelled first (they are not serving yet, so dropping
        them loses nothing); active units then vacate the tenant's
        least-occupied groups first so allocations stay packed."""
        if k <= 0:
            return 0
        released = 0
        # cancel pending wakes first, newest ready time first
        waking = [u for u in range(self.spec.n_units)
                  if self.owner[u] == tenant
                  and self.state[u] is UnitState.WAKING]
        waking.sort(key=lambda u: (-self._ready_t[u], -u))
        for u in waking[:k]:
            self.state[u] = UnitState.OFF
            self.owner[u] = None
            released += 1
        if released == k:
            return released
        mine = [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is UnitState.ACTIVE]
        occupancy = {gi: 0 for gi in range(len(self._groups))}
        for u in mine:
            occupancy[u // self.spec.group_size] += 1
        mine.sort(key=lambda u: (occupancy[u // self.spec.group_size], -u))
        for u in mine[:k - released]:
            self.state[u] = UnitState.OFF
            self.owner[u] = None
            released += 1
        return released

    def advance(self, t: float, dt_s: float,
                tenant: Optional[str] = None) -> int:
        """Waking units whose ready time falls within this tick become
        active (fluid model: a unit waking within the tick serves it)."""
        woke = 0
        for u in range(self.spec.n_units):
            if self.state[u] is UnitState.WAKING \
                    and (tenant is None or self.owner[u] == tenant) \
                    and self._ready_t[u] <= t + dt_s:
                self.state[u] = UnitState.ACTIVE
                woke += 1
        return woke

    def force_active(self, tenant: str, k: int) -> None:
        """Set the tenant's active-unit count to exactly ``k``, skipping
        wake latency (initial floors, tests, compatibility setters).
        Pending wakes are cancelled first — a hard reset would otherwise
        drift above ``k`` when they landed (and ``release`` prefers
        waking units, so trimming actives needs them gone)."""
        waking = self.waking(tenant)
        if waking:
            self.release(tenant, waking)
        cur = self.active(tenant)
        if cur > k:
            self.release(tenant, cur - k)
        elif cur < k:
            for u in self._pick_units(tenant, k - cur):
                self.state[u] = UnitState.ACTIVE
                self.owner[u] = tenant
                if self.opp_table is not None:
                    self._req_opp[u] = self._tenant_opp_of(tenant)

    # -- backend hooks (overridden by VectorUnitPool) ----------------------
    def _active_units_of(self, tenant: str) -> Sequence[int]:
        """The tenant's active unit indices, in ascending unit order."""
        return [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is UnitState.ACTIVE]

    def _opp_counts(self, mine: Sequence[int]) -> List[int]:
        """Active-unit count per effective OPP index (exact integers)."""
        counts = [0] * len(self.opp_table)
        for u in mine:
            counts[self.effective_opp(u)] += 1
        return counts

    def _scatter_unit_power(self, buf: Union[List[float], np.ndarray],
                            mine: Sequence[int],
                            pw_per_opp: Sequence[float]) -> None:
        for u in mine:
            buf[u] = pw_per_opp[self.effective_opp(u)]

    def _spare_units(self) -> List[int]:
        """Non-active unit indices (ascending); extras' heat is parked
        here for the thermal step, consumed from the back."""
        return [u for u in range(self.spec.n_units)
                if self.state[u] is not UnitState.ACTIVE]

    def _new_power_buf(self, fill: float) -> Union[List[float], np.ndarray]:
        return [fill] * self.spec.n_units

    def _n_latched_of(self, mine: Sequence[int]) -> int:
        """Trip-latched dies among ``mine`` (ledger cause split)."""
        assert self.thermal is not None
        thr = self.thermal.throttled
        return sum(1 for u in mine if thr[u])

    # -- accounting --------------------------------------------------------
    def attach_ledger(self, ledger: "EnergyLedger", rack: str = "") -> None:
        """Meter every subsequent ``charge`` tick into ``ledger`` under
        rack label ``rack`` (default: the spec's name). The ledger's
        replay starts from the pool's current ``energy_j``, so its
        :meth:`~repro.obs.attribution.EnergyLedger.rack_energy_j` stays
        bitwise-equal to this pool's integral even when attached
        mid-run."""
        self._obs_rack = rack or self.spec.name
        self._obs_ledger = ledger
        ledger.register_pool(self._obs_rack, base_energy_j=self.energy_j)

    def charge(self, t: float, dt_s: float, utils: Dict[str, float],
               extra: Optional[Dict[str, int]] = None,
               offered: float = 0.0, served: float = 0.0,
               ) -> Tuple[float, Dict[str, float], Dict[str, int]]:
        """Integrate one tick of cluster power: shared power once, each
        tenant's powered units (allocation + borrowed/overflow ``extra``)
        at that tenant's utilization, the rest at the off/idle floor.

        With an OPP table attached, each of a tenant's active units is
        metered at its *effective* operating point's f·V² power scale
        (extra borrowed/overflow units at the tenant's requested point),
        the thermal network advances one tick on the per-unit draw, and
        the fan's power lands on the shared rail. Without a table this
        is the exact pre-DVFS computation.

        Returns ``(total_power_w, per_tenant_power_w, per_tenant_powered)``.
        """
        extra = extra or {}
        n = self.spec.n_units
        powered: Dict[str, int] = {
            name: self.active(name) + max(0, int(extra.get(name, 0)))
            for name in utils}
        total_powered = sum(powered.values())
        if total_powered > n:
            # can't power more than n units: trim the extras, largest first
            over = total_powered - n
            for name in sorted(powered, key=lambda m: -powered[m]):
                cut = min(over, max(0, powered[name] - self.active(name)))
                powered[name] -= cut
                over -= cut
                if over == 0:
                    break
            total_powered = sum(powered.values())
        unit = self.spec.unit
        p_base = unit.p_off if self.idle_units_off else unit.p_idle
        p_tenant: Dict[str, float] = {}
        p_units = 0.0
        fan_w = 0.0
        ledger = self._obs_ledger
        # leaf groups mirror this loop's accumulation order exactly, so
        # the ledger replay reproduces energy_j bitwise (see repro.obs)
        groups: Optional[List[Any]] = [] if ledger is not None else None
        if self.opp_table is None:
            for name, cnt in powered.items():
                u = min(max(utils[name], 0.0), 1.0)
                p = cnt * unit.power(u)
                p_tenant[name] = p
                p_units += p
                if groups is not None:
                    groups.append((name, [("active", p, cnt)], 0, 0.0))
        else:
            table = self.opp_table
            # per-unit draw, for thermal: off/waking units at the floor
            per_unit_w = self._new_power_buf(p_base) \
                if self.thermal is not None else None
            # borrowed/overflow units have no allocation of their own;
            # their heat still lands on physical silicon, so park it on
            # otherwise-inactive units for the thermal step
            spare: Optional[List[int]] = None
            for name, cnt in powered.items():
                u = min(max(utils[name], 0.0), 1.0)
                mine = self._active_units_of(name)
                counts = self._opp_counts(mine)
                p, pw_per_opp = _power_from_opp_counts(
                    unit, u, table, counts)
                if per_unit_w is not None:
                    self._scatter_unit_power(per_unit_w, mine, pw_per_opp)
                # extras are metered at the tenant's requested point
                n_extra = cnt - len(mine)
                if n_extra > 0:
                    pw = unit_power(unit, u,
                                    table[self._tenant_opp_of(name)])
                    p += n_extra * pw
                    if per_unit_w is not None:
                        if spare is None:
                            spare = self._spare_units()
                        for _ in range(n_extra):
                            if not spare:
                                break
                            per_unit_w[spare.pop()] = pw
                p_tenant[name] = p
                p_units += p
                if groups is not None:
                    # same products, same ascending-OPP order, same
                    # zero-count skips as _power_from_opp_counts
                    leaves: List[Tuple[str, float, int]] = [
                        ("active:opp%d" % k, counts[k] * pw_per_opp[k],
                         counts[k])
                        for k in range(len(counts)) if counts[k]]
                    if n_extra > 0:
                        leaves.append(("hedge", n_extra * pw, n_extra))
                    fu = self._n_latched_of(mine) \
                        if self.thermal is not None else 0
                    fw = pw_per_opp[table.lowest] if fu else 0.0
                    groups.append((name, leaves, fu, fw))
            if self.thermal is not None:
                fan_w = self.thermal.step(dt_s, per_unit_w)
                self.max_temp_hist.append(self.thermal.max_die_temp_c())
                self.throttled_hist.append(self.thermal.n_throttled())
                self.fan_power_hist.append(fan_w)
        rest = n - total_powered
        p_rest = rest * p_base
        total = self.spec.p_shared + fan_w + p_units + p_rest
        self.energy_j += total * dt_s
        if ledger is not None:
            assert groups is not None
            ledger.record_pool_tick(
                self._obs_rack, t, dt_s, shared_w=self.spec.p_shared,
                fan_w=fan_w, groups=groups, rest_w=p_rest, rest_units=rest,
                waking_units=self.n_waking_total())
        self.served += served
        for name, p in p_tenant.items():
            self.tenant_energy_j[name] = \
                self.tenant_energy_j.get(name, 0.0) + p * dt_s
        self.last_power_w = total
        cap = float(total_powered)
        util_agg = sum(powered[m] * min(max(utils[m], 0.0), 1.0)
                       for m in powered) / cap if cap else 0.0
        self.t_hist.append(t)
        self.power_hist.append(total)
        self.active_hist.append(total_powered)
        self.util_hist.append(util_agg)
        self.offered_hist.append(offered)
        self.served_hist.append(served)
        return total, p_tenant, powered


class VectorUnitPool(UnitPool):
    """Array-backed :class:`UnitPool` (``backend="vector"``).

    State lives in numpy arrays (int8 state codes, int64 owner ids,
    float64 ready times), transitions are mask/lexsort operations, and
    the per-(tenant, state) unit counts are maintained as exact integer
    caches so the hot-path queries (``active``/``waking``/
    ``free_units``) are O(1) instead of O(n_units). All float
    reductions go through the shared order-pinned helpers, so telemetry
    is bitwise-identical to the scalar backend — asserted by
    ``tests/test_vector_parity.py``.
    """

    backend = "vector"

    def __init__(self, spec: ClusterSpec, idle_units_off: bool = True,
                 opp_table: Optional[OPPTable] = None,
                 thermal: Union[ThermalParams, ThermalModel, None] = None) -> None:
        if isinstance(thermal, ThermalParams):
            thermal = VectorThermalModel(spec, thermal)
        elif isinstance(thermal, ThermalModel) \
                and not isinstance(thermal, VectorThermalModel):
            raise TypeError(
                "backend='vector' needs a VectorThermalModel; pass "
                "ThermalParams and let the pool build one")
        self._init_common(spec, idle_units_off, opp_table, thermal)
        n = spec.n_units
        nominal = opp_table.nominal if opp_table is not None else 0
        self._state = np.zeros(n, np.int8)
        self._owner = np.full(n, -1, np.int64)
        self._ready = np.zeros(n, float)
        self._req = np.full(n, nominal, np.int64)
        self._tenant_ids: Dict[str, int] = {}
        self._tenant_names: List[str] = []
        self._group_idx = np.asarray(
            [u // spec.group_size for u in range(n)], np.int64)
        self._group_len = np.asarray([len(g) for g in self._groups],
                                     np.int64)
        # exact integer caches (updated on every transition)
        self._n_waking_of: Dict[int, int] = {}
        self._n_active_of: Dict[int, int] = {}
        self._n_alloc = 0
        self._n_waking_total = 0
        # incrementally-maintained per-group counts: free units per group,
        # and per tenant the owned (not-off) / active units per group.
        # Placement and release read these instead of re-deriving them
        # with bincount + lexsort on every operation.
        self._free_g = self._group_len.copy()
        self._mine_g: Dict[int, np.ndarray] = {}
        self._act_g: Dict[int, np.ndarray] = {}
        # composite placement-key constants: (no-units-here, not-wholly-
        # free, fullness) packed into one int so a single stable argsort
        # reproduces the scalar _group_key ordering (gi breaks ties)
        self._lmax = int(self._group_len.max())
        # cached per-tenant active-index arrays (invalidated whenever a
        # transition changes an active set; callers must not mutate)
        self._active_idx: Dict[int, np.ndarray] = {}
        self._pwbuf: Optional[np.ndarray] = None

    # -- compatibility views ----------------------------------------------
    # Tuples, not lists: code written against the scalar backend's mutable
    # attributes (pool.state[u] = ...) must fail fast here rather than
    # silently mutating a materialized temporary.
    @property  # type: ignore[override]  # read-only view of the base's list
    def state(self) -> Tuple[UnitState, ...]:
        """Read-only scalar-compatible view (tests/debugging); mutate
        through wake/release/advance/force_active instead."""
        return tuple(_STATE_ENUM[c] for c in self._state)

    @property  # type: ignore[override]  # read-only view of the base's list
    def owner(self) -> Tuple[Optional[str], ...]:
        return tuple(self._tenant_names[o] if o >= 0 else None
                     for o in self._owner)

    @property  # type: ignore[override]  # read-only view of the base's list
    def _req_opp(self) -> Tuple[int, ...]:
        return tuple(int(r) for r in self._req)

    def _tid(self, tenant: str, create: bool = False) -> Optional[int]:
        tid = self._tenant_ids.get(tenant)
        if tid is None and create:
            tid = len(self._tenant_names)
            self._tenant_ids[tenant] = tid
            self._tenant_names.append(tenant)
        return tid

    # -- queries -----------------------------------------------------------
    def active(self, tenant: str) -> int:
        return self._n_active_of.get(self._tenant_ids.get(tenant), 0)

    def waking(self, tenant: str) -> int:
        return self._n_waking_of.get(self._tenant_ids.get(tenant), 0)

    def owned(self, tenant: str) -> int:
        return self.active(tenant) + self.waking(tenant)

    def units_of(self, tenant: str) -> List[int]:
        tid = self._tenant_ids.get(tenant)
        if tid is None:
            return []
        mask = (self._owner == tid) & (self._state != _OFF)
        return [int(u) for u in np.nonzero(mask)[0]]

    def n_allocated(self) -> int:
        return self._n_alloc

    def n_active(self) -> int:
        return sum(self._n_active_of.values())

    def n_waking_total(self) -> int:
        return self._n_waking_total

    def _n_latched_of(self, mine: Sequence[int]) -> int:
        assert self.thermal is not None
        return int(np.count_nonzero(
            np.asarray(self.thermal.throttled)[np.asarray(mine, np.int64)]))

    # -- DVFS --------------------------------------------------------------
    def set_opp(self, tenant: str, idx: int) -> None:
        if self.opp_table is None:
            return
        idx = self.opp_table.clamp(idx)
        prev = self._tenant_opp.get(tenant, self.opp_table.nominal)
        self._tenant_opp[tenant] = idx
        if idx == prev:
            # every acquisition (wake / force_active) stamps the tenant's
            # current point onto the unit, so owned units already carry
            # ``idx`` — skip the per-unit write on the steady-state tick
            return
        tid = self._tenant_ids.get(tenant)
        if tid is not None:
            self._req[self._owner == tid] = idx

    def effective_opp(self, u: int) -> int:
        assert self.opp_table is not None
        if self.thermal is not None and bool(self.thermal.throttled[u]):
            return self.opp_table.lowest
        return int(self._req[u])

    def _eff_opp_arr(self) -> np.ndarray:
        if self.thermal is not None:
            return np.where(self.thermal.throttled,
                            self.opp_table.lowest, self._req)
        return self._req

    # -- placement ---------------------------------------------------------
    def _group_counts_of(self, tid: int) -> "tuple[np.ndarray, np.ndarray]":
        n_groups = len(self._groups)
        mine = self._mine_g.get(tid)
        if mine is None:
            mine = self._mine_g[tid] = np.zeros(n_groups, np.int64)
        act = self._act_g.get(tid)
        if act is None:
            act = self._act_g[tid] = np.zeros(n_groups, np.int64)
        return mine, act

    def _pick_units(self, tenant: str, k: int) -> List[int]:
        if k <= 0 or self._n_alloc == self.spec.n_units:
            return []
        tid = self._tid(tenant, create=True)
        mine_g, _ = self._group_counts_of(tid)
        free_g = self._free_g
        # the scalar _group_key — (no units here, not wholly free, -free)
        # with gi tie-break — packed into one int; stable argsort keeps
        # ascending gi among equal keys
        key = ((mine_g == 0).astype(np.int64) * 2
               + (free_g != self._group_len)) * (self._lmax + 1) \
            + (self._lmax - free_g)
        order = np.argsort(key, kind="stable")
        out: List[int] = []
        gs = self.spec.group_size
        state = self._state
        for gi in order:
            if free_g[gi] == 0:
                continue
            lo = gi * gs
            for u in np.nonzero(state[lo:lo + int(self._group_len[gi])]
                                == _OFF)[0]:
                out.append(lo + int(u))
                if len(out) == k:
                    return out
        return out

    # -- transitions -------------------------------------------------------
    def _count_groups(self, idx: np.ndarray) -> np.ndarray:
        return np.bincount(self._group_idx[idx],
                           minlength=len(self._groups))

    def wake(self, tenant: str, k: int, ready_t: float) -> int:
        picked = self._pick_units(tenant, k)
        if picked:
            tid = self._tid(tenant, create=True)
            idx = np.asarray(picked, np.int64)
            self._state[idx] = _WAKING
            self._owner[idx] = tid
            self._ready[idx] = ready_t
            if self.opp_table is not None:
                self._req[idx] = self._tenant_opp_of(tenant)
            self._n_waking_of[tid] = \
                self._n_waking_of.get(tid, 0) + len(picked)
            self._n_alloc += len(picked)
            self._n_waking_total += len(picked)
            g = self._count_groups(idx)
            mine_g, _ = self._group_counts_of(tid)
            mine_g += g
            self._free_g -= g
        return len(picked)

    def release(self, tenant: str, k: int) -> int:
        if k <= 0:
            return 0
        tid = self._tenant_ids.get(tenant)
        if tid is None:
            return 0
        released = 0
        if self._n_waking_of.get(tid, 0):
            widx = np.nonzero((self._owner == tid)
                              & (self._state == _WAKING))[0]
            # newest ready time first, then highest unit index
            order = np.lexsort((-widx, -self._ready[widx]))
            take = widx[order[:k]]
            self._state[take] = _OFF
            self._owner[take] = -1
            released = len(take)
            self._n_waking_of[tid] -= released
            self._n_alloc -= released
            self._n_waking_total -= released
            g = self._count_groups(take)
            mine_g, _ = self._group_counts_of(tid)
            mine_g -= g
            self._free_g += g
        if released == k:
            return released
        if self._n_active_of.get(tid, 0):
            aidx = self._active_units_of(tenant)
            # least-occupied groups first, then highest unit index —
            # the cached per-group active counts *are* the occupancy the
            # scalar backend derives per call, and packing (occupancy,
            # n_units - u) into one key makes a single argsort reproduce
            # the scalar ordering (keys are unique: one per unit)
            _, act_g = self._group_counts_of(tid)
            key = act_g[self._group_idx[aidx]] * (self.spec.n_units + 1) \
                + (self.spec.n_units - aidx)
            order = np.argsort(key)  # reprolint: ok[RPL005] integer composite key, one per unit (see comment above): keys are unique, so sort stability is irrelevant
            take = aidx[order[:k - released]]
            self._state[take] = _OFF
            self._owner[take] = -1
            self._n_active_of[tid] = \
                self._n_active_of.get(tid, 0) - len(take)
            self._n_alloc -= len(take)
            g = self._count_groups(take)
            mine_g, act_g = self._group_counts_of(tid)
            mine_g -= g
            act_g -= g
            self._free_g += g
            self._active_idx.pop(tid, None)
            released += len(take)
        return released

    def advance(self, t: float, dt_s: float,
                tenant: Optional[str] = None) -> int:
        if self._n_waking_total == 0:
            return 0
        mask = (self._state == _WAKING) & (self._ready <= t + dt_s)
        if tenant is not None:
            tid = self._tenant_ids.get(tenant)
            if tid is None:
                return 0
            mask &= self._owner == tid
        idx = np.nonzero(mask)[0]
        if len(idx) == 0:
            return 0
        self._state[idx] = _ACTIVE
        owners, cnts = np.unique(self._owner[idx], return_counts=True)
        for o, c in zip(owners, cnts):
            o, c = int(o), int(c)
            self._n_waking_of[o] -= c
            self._n_active_of[o] = self._n_active_of.get(o, 0) + c
            self._n_waking_total -= c
            sel = idx[self._owner[idx] == o]
            _, act_g = self._group_counts_of(o)
            act_g += self._count_groups(sel)
            self._active_idx.pop(o, None)
        return len(idx)

    def force_active(self, tenant: str, k: int) -> None:
        waking = self.waking(tenant)
        if waking:
            self.release(tenant, waking)
        cur = self.active(tenant)
        if cur > k:
            self.release(tenant, cur - k)
        elif cur < k:
            picked = self._pick_units(tenant, k - cur)
            if picked:
                tid = self._tid(tenant, create=True)
                idx = np.asarray(picked, np.int64)
                self._state[idx] = _ACTIVE
                self._owner[idx] = tid
                if self.opp_table is not None:
                    self._req[idx] = self._tenant_opp_of(tenant)
                self._n_active_of[tid] = \
                    self._n_active_of.get(tid, 0) + len(picked)
                self._n_alloc += len(picked)
                g = self._count_groups(idx)
                mine_g, act_g = self._group_counts_of(tid)
                mine_g += g
                act_g += g
                self._free_g -= g
                self._active_idx.pop(tid, None)

    # -- backend hooks -----------------------------------------------------
    def _latch_free(self) -> bool:
        """True when no die carries a trip latch — then every unit of a
        tenant runs at the tenant's requested OPP (wake/force_active/
        set_opp maintain that invariant) and the per-unit effective-OPP
        gathers collapse to a single bucket. Read live off the thermal
        model (tests may set latches by hand)."""
        return self.thermal is None or not self.thermal.throttled.any()

    def _active_units_of(self, tenant: str) -> np.ndarray:  # type: ignore[override]
        tid = self._tenant_ids.get(tenant)
        if tid is None:
            return np.empty(0, np.int64)
        cached = self._active_idx.get(tid)
        if cached is None:
            cached = np.nonzero((self._owner == tid)
                                & (self._state == _ACTIVE))[0]
            self._active_idx[tid] = cached
        return cached

    def perf_scale(self, tenant: str) -> float:
        if self.opp_table is None:
            return 1.0
        k = self.active(tenant)
        if k == 0:
            return self.opp_table[self._tenant_opp_of(tenant)].perf_scale
        if self._latch_free():
            # single bucket: same accumulation as _perf_from_opp_counts
            # with one non-zero count
            return (k * self.opp_table[self._tenant_opp_of(tenant)]
                    .perf_scale) / k
        return _perf_from_opp_counts(
            self.opp_table, self._opp_counts(self._active_units_of(tenant)))

    def _opp_counts(self, mine: np.ndarray) -> List[int]:  # type: ignore[override]
        counts = [0] * len(self.opp_table)
        if len(mine) == 0:
            return counts
        if self._latch_free():
            counts[int(self._req[mine[0]])] = len(mine)
            return counts
        eff = self._eff_opp_arr()[mine]
        return np.bincount(eff, minlength=len(self.opp_table)).tolist()

    def _scatter_unit_power(self, buf: np.ndarray,  # type: ignore[override]
                            mine: np.ndarray,
                            pw_per_opp: Sequence[float]) -> None:
        if len(mine) == 0:
            return
        if self._latch_free():
            buf[mine] = pw_per_opp[int(self._req[mine[0]])]
            return
        buf[mine] = np.asarray(pw_per_opp)[self._eff_opp_arr()[mine]]

    def _spare_units(self) -> List[int]:
        return np.nonzero(self._state != _ACTIVE)[0].tolist()

    def _new_power_buf(self, fill: float) -> np.ndarray:
        # one reusable buffer: charge() consumes it within the tick and
        # the thermal step never retains it
        buf = self._pwbuf
        if buf is None:
            buf = self._pwbuf = np.empty(self.spec.n_units, float)
        buf.fill(fill)
        return buf


def make_unit_pool(spec: ClusterSpec, backend: str = "scalar",
                   sanitize: Optional[bool] = None,
                   **kwargs: Any) -> UnitPool:
    """Construct a pool backend: ``"scalar"`` (reference, per-unit
    loops) or ``"vector"`` (numpy arrays, bitwise-identical telemetry).

    ``sanitize=True`` (or ``REPRO_SANITIZE=1`` with ``sanitize=None``)
    arms the pool with :mod:`repro.runtime.sanitize` invariant checks
    on every mutating call."""
    if backend == "scalar":
        pool: UnitPool = UnitPool(spec, **kwargs)
    elif backend == "vector":
        pool = VectorUnitPool(spec, **kwargs)
    else:
        raise ValueError(
            f"unknown pool backend {backend!r}; use 'scalar' or 'vector'")
    from repro.runtime.sanitize import attach_pool_sanitizer, resolve_sanitize
    if resolve_sanitize(sanitize):
        attach_pool_sanitizer(pool)
    return pool
