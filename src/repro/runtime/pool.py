"""``UnitPool`` — per-unit activation state over a :class:`ClusterSpec`.

The pool is the single owner of which physical units are powered (paper
§5.2: per-SoC power gating). Every unit is in one of three states —
``off → waking → active`` — and allocations are handed out
**PCB-group-aligned**: a tenant's units are packed into as few
``ClusterSpec.group_size`` groups as possible (filling groups the tenant
already occupies first, then wholly-free groups), so tensor-parallel
collaboration groups (§5.3) are not stranded across half-empty PCBs.

The pool also owns the cluster's **single power integral**: shared
infrastructure power (``ClusterSpec.p_shared`` — fans, switch boards,
BMC) is charged exactly once per tick no matter how many tenants share
the cluster, while each tenant's powered units are metered at that
tenant's utilization and attributed to ``tenant_energy_j``.

With an :class:`~repro.power.opp.OPPTable` attached the pool also owns
the **frequency axis**: every unit carries a requested operating point
(set per tenant via :meth:`set_opp`), a thermal trip latch may force it
down to the lowest OPP, and :meth:`charge` meters each unit at its
*effective* OPP's f·V² power scale while stepping the RC thermal
network (fan power rides on the shared rail). With no table configured
— the default — every DVFS path is skipped and the pool behaves
bit-for-bit like the pre-power-layer code.
"""
from __future__ import annotations

from enum import Enum
from typing import Dict, List, Optional, Tuple, Union

from repro.core.cluster import ClusterSpec
from repro.power.opp import OPPTable, unit_power
from repro.power.thermal import ThermalModel, ThermalParams


class UnitState(str, Enum):
    OFF = "off"
    WAKING = "waking"
    ACTIVE = "active"


class UnitPool:
    """Tracks per-unit state and hands out group-aligned allocations.

    Tenants are identified by name. ``wake`` claims free units (they
    serve only after ``advance`` passes their ready time), ``release``
    powers active units back off, and ``charge`` integrates the cluster
    power model for one tick. Waking units draw the same rest power as
    off/idle units (they are not serving yet) but are *owned* — they are
    unavailable to other tenants and to hedging.
    """

    def __init__(self, spec: ClusterSpec, idle_units_off: bool = True,
                 opp_table: Optional[OPPTable] = None,
                 thermal: Union[ThermalParams, ThermalModel, None] = None):
        self.spec = spec
        self.idle_units_off = idle_units_off
        n = spec.n_units
        self.state: List[UnitState] = [UnitState.OFF] * n
        self.owner: List[Optional[str]] = [None] * n
        self._ready_t: List[float] = [0.0] * n
        self._groups = spec.groups()
        # DVFS state (absent by default: strictly additive)
        assert opp_table is not None or thermal is None, \
            "thermal throttling needs an opp_table to throttle within"
        self.opp_table = opp_table
        if isinstance(thermal, ThermalParams):
            thermal = ThermalModel(spec, thermal)
        self.thermal: Optional[ThermalModel] = thermal
        nominal = opp_table.nominal if opp_table is not None else 0
        self._req_opp: List[int] = [nominal] * n
        self._tenant_opp: Dict[str, int] = {}
        # accounting (cluster level; shared power charged once)
        self.energy_j = 0.0
        self.served = 0.0
        self.tenant_energy_j: Dict[str, float] = {}
        self.last_power_w = 0.0
        # cluster-level per-tick history
        self.t_hist: List[float] = []
        self.power_hist: List[float] = []
        self.active_hist: List[int] = []
        self.util_hist: List[float] = []
        self.offered_hist: List[float] = []
        self.served_hist: List[float] = []
        # filled only when a thermal model is attached
        self.max_temp_hist: List[float] = []
        self.throttled_hist: List[int] = []
        self.fan_power_hist: List[float] = []

    # -- queries -----------------------------------------------------------
    def active(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is UnitState.ACTIVE)

    def waking(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is UnitState.WAKING)

    def owned(self, tenant: str) -> int:
        return sum(1 for u in range(self.spec.n_units)
                   if self.owner[u] == tenant
                   and self.state[u] is not UnitState.OFF)

    def units_of(self, tenant: str) -> List[int]:
        return [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is not UnitState.OFF]

    def n_allocated(self) -> int:
        return sum(1 for s in self.state if s is not UnitState.OFF)

    def n_active(self) -> int:
        return sum(1 for s in self.state if s is UnitState.ACTIVE)

    def free_units(self) -> int:
        return self.spec.n_units - self.n_allocated()

    # -- DVFS --------------------------------------------------------------
    def set_opp(self, tenant: str, idx: int) -> None:
        """Request an operating point for all of ``tenant``'s units (a
        thermal trip latch can still force individual units lower)."""
        if self.opp_table is None:
            return
        idx = self.opp_table.clamp(idx)
        self._tenant_opp[tenant] = idx
        for u in range(self.spec.n_units):
            if self.owner[u] == tenant:
                self._req_opp[u] = idx

    def effective_opp(self, u: int) -> int:
        """The OPP unit ``u`` actually runs at: its requested point, or
        the table's lowest while its thermal trip latch is set."""
        assert self.opp_table is not None
        if self.thermal is not None and self.thermal.throttled[u]:
            return self.opp_table.lowest
        return self._req_opp[u]

    def _tenant_opp_of(self, tenant: str) -> int:
        assert self.opp_table is not None
        return self._tenant_opp.get(tenant, self.opp_table.nominal)

    def perf_scale(self, tenant: str) -> float:
        """Mean service-rate multiplier over the tenant's active units
        (1.0 with no OPP table, or at the nominal point). Throttled
        units drag the mean down — this is what the workload's capacity
        is scaled by."""
        if self.opp_table is None:
            return 1.0
        mine = [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is UnitState.ACTIVE]
        if not mine:
            return self.opp_table[self._tenant_opp_of(tenant)].perf_scale
        return sum(self.opp_table[self.effective_opp(u)].perf_scale
                   for u in mine) / len(mine)

    def max_sustainable_opp(self) -> Optional[int]:
        """Thermal ceiling for governors (None without a thermal model):
        the highest OPP a fully-loaded, fully-occupied PCB group can
        hold forever without tripping."""
        if self.thermal is None or self.opp_table is None:
            return None
        return self.thermal.max_sustainable_index(self.spec.unit,
                                                  self.opp_table)

    # -- placement ---------------------------------------------------------
    def _group_key(self, gi: int, tenant: str) -> Tuple[int, int, int, int]:
        g = self._groups[gi]
        mine = sum(1 for u in g if self.owner[u] == tenant
                   and self.state[u] is not UnitState.OFF)
        free = sum(1 for u in g if self.state[u] is UnitState.OFF)
        # pack into groups the tenant already occupies, then wholly-free
        # groups, then whatever has the most room
        return (0 if mine else 1, 0 if free == len(g) else 1, -free, gi)

    def _pick_units(self, tenant: str, k: int) -> List[int]:
        if k <= 0:
            return []
        out: List[int] = []
        for gi in sorted(range(len(self._groups)),
                         key=lambda gi: self._group_key(gi, tenant)):
            for u in self._groups[gi]:
                if self.state[u] is UnitState.OFF:
                    out.append(u)
                    if len(out) == k:
                        return out
        return out

    # -- transitions -------------------------------------------------------
    def wake(self, tenant: str, k: int, ready_t: float) -> int:
        """Claim up to ``k`` free units for ``tenant``; they become active
        once ``advance`` passes ``ready_t``. Returns the claimed count."""
        picked = self._pick_units(tenant, k)
        for u in picked:
            self.state[u] = UnitState.WAKING
            self.owner[u] = tenant
            self._ready_t[u] = ready_t
            if self.opp_table is not None:
                self._req_opp[u] = self._tenant_opp_of(tenant)
        return len(picked)

    def release(self, tenant: str, k: int) -> int:
        """Power off up to ``k`` of the tenant's units. Still-waking
        units are cancelled first (they are not serving yet, so dropping
        them loses nothing); active units then vacate the tenant's
        least-occupied groups first so allocations stay packed."""
        if k <= 0:
            return 0
        released = 0
        # cancel pending wakes first, newest ready time first
        waking = [u for u in range(self.spec.n_units)
                  if self.owner[u] == tenant
                  and self.state[u] is UnitState.WAKING]
        waking.sort(key=lambda u: (-self._ready_t[u], -u))
        for u in waking[:k]:
            self.state[u] = UnitState.OFF
            self.owner[u] = None
            released += 1
        if released == k:
            return released
        mine = [u for u in range(self.spec.n_units)
                if self.owner[u] == tenant
                and self.state[u] is UnitState.ACTIVE]
        occupancy = {gi: 0 for gi in range(len(self._groups))}
        for u in mine:
            occupancy[u // self.spec.group_size] += 1
        mine.sort(key=lambda u: (occupancy[u // self.spec.group_size], -u))
        for u in mine[:k - released]:
            self.state[u] = UnitState.OFF
            self.owner[u] = None
            released += 1
        return released

    def advance(self, t: float, dt_s: float,
                tenant: Optional[str] = None) -> int:
        """Waking units whose ready time falls within this tick become
        active (fluid model: a unit waking within the tick serves it)."""
        woke = 0
        for u in range(self.spec.n_units):
            if self.state[u] is UnitState.WAKING \
                    and (tenant is None or self.owner[u] == tenant) \
                    and self._ready_t[u] <= t + dt_s:
                self.state[u] = UnitState.ACTIVE
                woke += 1
        return woke

    def force_active(self, tenant: str, k: int) -> None:
        """Set the tenant's active-unit count to exactly ``k``, skipping
        wake latency (initial floors, tests, compatibility setters).
        Pending wakes are cancelled first — a hard reset would otherwise
        drift above ``k`` when they landed (and ``release`` prefers
        waking units, so trimming actives needs them gone)."""
        waking = self.waking(tenant)
        if waking:
            self.release(tenant, waking)
        cur = self.active(tenant)
        if cur > k:
            self.release(tenant, cur - k)
        elif cur < k:
            for u in self._pick_units(tenant, k - cur):
                self.state[u] = UnitState.ACTIVE
                self.owner[u] = tenant
                if self.opp_table is not None:
                    self._req_opp[u] = self._tenant_opp_of(tenant)

    # -- accounting --------------------------------------------------------
    def charge(self, t: float, dt_s: float, utils: Dict[str, float],
               extra: Optional[Dict[str, int]] = None,
               offered: float = 0.0, served: float = 0.0,
               ) -> Tuple[float, Dict[str, float], Dict[str, int]]:
        """Integrate one tick of cluster power: shared power once, each
        tenant's powered units (allocation + borrowed/overflow ``extra``)
        at that tenant's utilization, the rest at the off/idle floor.

        With an OPP table attached, each of a tenant's active units is
        metered at its *effective* operating point's f·V² power scale
        (extra borrowed/overflow units at the tenant's requested point),
        the thermal network advances one tick on the per-unit draw, and
        the fan's power lands on the shared rail. Without a table this
        is the exact pre-DVFS computation.

        Returns ``(total_power_w, per_tenant_power_w, per_tenant_powered)``.
        """
        extra = extra or {}
        n = self.spec.n_units
        powered: Dict[str, int] = {
            name: self.active(name) + max(0, int(extra.get(name, 0)))
            for name in utils}
        total_powered = sum(powered.values())
        if total_powered > n:
            # can't power more than n units: trim the extras, largest first
            over = total_powered - n
            for name in sorted(powered, key=lambda m: -powered[m]):
                cut = min(over, max(0, powered[name] - self.active(name)))
                powered[name] -= cut
                over -= cut
                if over == 0:
                    break
            total_powered = sum(powered.values())
        unit = self.spec.unit
        p_base = unit.p_off if self.idle_units_off else unit.p_idle
        p_tenant: Dict[str, float] = {}
        p_units = 0.0
        fan_w = 0.0
        if self.opp_table is None:
            for name, cnt in powered.items():
                u = min(max(utils[name], 0.0), 1.0)
                p = cnt * unit.power(u)
                p_tenant[name] = p
                p_units += p
        else:
            table = self.opp_table
            # per-unit draw, for thermal: off/waking units at the floor
            per_unit_w = [p_base] * n if self.thermal is not None else None
            # borrowed/overflow units have no allocation of their own;
            # their heat still lands on physical silicon, so park it on
            # otherwise-inactive units for the thermal step
            spare = [i for i in range(n)
                     if self.state[i] is not UnitState.ACTIVE] \
                if per_unit_w is not None else []
            for name, cnt in powered.items():
                u = min(max(utils[name], 0.0), 1.0)
                mine = [i for i in range(n) if self.owner[i] == name
                        and self.state[i] is UnitState.ACTIVE]
                p = 0.0
                for i in mine:
                    pw = unit_power(unit, u, table[self.effective_opp(i)])
                    p += pw
                    if per_unit_w is not None:
                        per_unit_w[i] = pw
                # extras are metered at the tenant's requested point
                n_extra = cnt - len(mine)
                if n_extra > 0:
                    pw = unit_power(unit, u,
                                    table[self._tenant_opp_of(name)])
                    p += n_extra * pw
                    if per_unit_w is not None:
                        for _ in range(n_extra):
                            if not spare:
                                break
                            per_unit_w[spare.pop()] = pw
                p_tenant[name] = p
                p_units += p
            if self.thermal is not None:
                fan_w = self.thermal.step(dt_s, per_unit_w)
                self.max_temp_hist.append(self.thermal.max_die_temp_c())
                self.throttled_hist.append(self.thermal.n_throttled())
                self.fan_power_hist.append(fan_w)
        rest = n - total_powered
        p_rest = rest * p_base
        total = self.spec.p_shared + fan_w + p_units + p_rest
        self.energy_j += total * dt_s
        self.served += served
        for name, p in p_tenant.items():
            self.tenant_energy_j[name] = \
                self.tenant_energy_j.get(name, 0.0) + p * dt_s
        self.last_power_w = total
        cap = float(total_powered)
        util_agg = sum(powered[m] * min(max(utils[m], 0.0), 1.0)
                       for m in powered) / cap if cap else 0.0
        self.t_hist.append(t)
        self.power_hist.append(total)
        self.active_hist.append(total_powered)
        self.util_hist.append(util_agg)
        self.offered_hist.append(offered)
        self.served_hist.append(served)
        return total, p_tenant, powered
