"""Correlated fault injection for the fleet — chaos engineering (§8).

The paper calls fault tolerance "crucial for the success of SoC
Cluster": a 60-SoC rack must survive single-SoC death, and the
architecture is uniquely exposed to *correlated* failures no per-unit
model captures — a shared fan rail feeding a whole rack, a site power
cap forcing every die to the floor OPP at once. This module injects
exactly those faults mid-trace, identically into all three fleet
engines:

  * ``kill`` — a rack, a PCB group, or a fraction of units goes dark.
    Killed units are power-gated (they keep drawing the gated floor
    ``p_base``; chassis/shared/fan power stays up — an SoC-level
    failure, not a site outage). Kills are *count-granular*: the
    engines model units as interchangeable prefix counts, so "kill 20
    units" caps the rack's activation at ``n_units - 20`` rather than
    naming physical dies.
  * ``fan_fail`` — the rack's shared fan rail dies: airflow drops to
    zero (``fan_frac = 0``), the PCB-to-ambient resistance snaps to its
    no-airflow value, and throttling cascades through the RC network
    exactly as the thermal model dictates.
  * ``power_cap`` — a rack-level power cap pins every die at the floor
    OPP for the duration (the frequency governor keeps running but its
    choice is overridden, so state-free governors resume correctly on
    release).

Queue policy on a *full-rack* kill (``ChaosSchedule.on_kill``):

  * ``"respill"`` (default) — the dead rack's queue is evacuated and
    its cost re-offered through the router in the same tick, merged
    into the fleet-level offered load. Respilled requests restart their
    latency clock (the fluid queues aggregate per-tick arrivals, so
    original arrival stamps are not recoverable per request — and an
    operator-visible retry restarts the clock anyway). If no rack is
    alive to take them, the router assigns ~0 and the cost is lost.
  * ``"drop"`` — the queue is discarded and counted.

Either way the evacuated cost is credited in the sanitizer's
conservation check, and a dead rack serving requests is an invariant
violation ("resurrection") the sanitizer traps.

Parity contract: the masks produced here drive the scalar and vector
engines through the *same* schedule object, so scalar/vector stay
bitwise-identical under chaos; the jax engine lowers the schedule to
per-tick mask rows (``LoweredChaos.rows``) consumed inside
``lax.scan`` and rides the documented tolerance budgets.

Seed workflow: ``ChaosSchedule.random(..., seed=chaos_seed())`` reads
``REPRO_CHAOS_SEED`` (CI derives it from ``github.run_id`` and echoes
it to the step summary), so any red chaos run reproduces locally with
``REPRO_CHAOS_SEED=<n> pytest tests/test_chaos.py``.
"""

from __future__ import annotations

import dataclasses
import math
import os
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.distributed.fault import HealthTracker
    from repro.fleet.fleet import RackConfig
    from repro.fleet.router import Router
    from repro.fleet.telemetry import FleetTelemetry

__all__ = [
    "ChaosEvent",
    "ChaosSchedule",
    "LoweredChaos",
    "ChaosMonitor",
    "RecoveryReport",
    "chaos_seed",
    "recovery_report",
    "recovery_window_p99",
    "hedging_delta",
]

KILL = "kill"
FAN_FAIL = "fan_fail"
POWER_CAP = "power_cap"
_KINDS = (KILL, FAN_FAIL, POWER_CAP)
_ON_KILL = ("respill", "drop")


def chaos_seed(default: int = 0) -> int:
    """The chaos seed for this process: ``REPRO_CHAOS_SEED`` env var
    (set by the CI chaos job from ``github.run_id``) or ``default``."""
    return int(os.environ.get("REPRO_CHAOS_SEED", default))


@dataclass(frozen=True)
class ChaosEvent:
    """One fault window ``[start_s, end_s)`` on one rack.

    ``units`` applies to ``kill`` events only: how many units are down
    (0 = the whole rack). Restoration is implicit at ``end_s``
    (``math.inf`` = never restored)."""

    kind: str
    rack: int
    start_s: float
    end_s: float = math.inf
    units: int = 0

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown chaos event kind {self.kind!r}")
        if not self.end_s > self.start_s:
            raise ValueError(
                f"empty chaos window [{self.start_s}, {self.end_s})"
            )

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s

    def to_record(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclass
class ChaosSchedule:
    """A seeded, declarative fault plan; lower it against a fleet's
    per-rack unit counts to get tick-sampled masks."""

    events: List[ChaosEvent] = field(default_factory=list)
    on_kill: str = "respill"

    def __post_init__(self) -> None:
        if self.on_kill not in _ON_KILL:
            raise ValueError(
                f"on_kill must be one of {_ON_KILL}, got {self.on_kill!r}"
            )

    # -- builders ------------------------------------------------------
    def add(self, event: ChaosEvent) -> "ChaosSchedule":
        self.events.append(event)
        return self

    def kill_rack(
        self, rack: int, start_s: float, end_s: float = math.inf
    ) -> "ChaosSchedule":
        """The whole rack goes dark (queue evacuated per ``on_kill``)."""
        return self.add(ChaosEvent(KILL, rack, start_s, end_s, units=0))

    def kill_units(
        self, rack: int, units: int, start_s: float, end_s: float = math.inf
    ) -> "ChaosSchedule":
        """``units`` of the rack go dark (count-granular, see module
        docstring); the rack keeps serving on what is left."""
        if units <= 0:
            raise ValueError("kill_units needs units >= 1")
        return self.add(ChaosEvent(KILL, rack, start_s, end_s, units=units))

    def kill_group(
        self,
        rack: int,
        group_units: int,
        start_s: float,
        end_s: float = math.inf,
        groups: int = 1,
    ) -> "ChaosSchedule":
        """Kill ``groups`` PCB groups' worth of units (the paper's
        board-granular fail-out: one PCB takes its SoCs with it)."""
        return self.kill_units(rack, groups * group_units, start_s, end_s)

    def fail_fan(
        self, rack: int, start_s: float, end_s: float = math.inf
    ) -> "ChaosSchedule":
        """Shared fan rail failure: zero airflow into the rack's RC
        network for the window (no-op on racks without a thermal model)."""
        return self.add(ChaosEvent(FAN_FAIL, rack, start_s, end_s))

    def power_cap(
        self, rack: int, start_s: float, end_s: float = math.inf
    ) -> "ChaosSchedule":
        """Rack power cap: every die pinned at the floor OPP for the
        window (no-op on racks without an OPP table)."""
        return self.add(ChaosEvent(POWER_CAP, rack, start_s, end_s))

    # -- derived -------------------------------------------------------
    @property
    def fault_t(self) -> float:
        """Start of the earliest fault (``inf`` on an empty schedule)."""
        t = math.inf
        for ev in self.events:
            t = min(t, ev.start_s)
        return t

    def lower(self, n_units: Sequence[int]) -> "LoweredChaos":
        """Bind the schedule to a fleet (per-rack unit counts); kills
        clamp to the rack size, rack indices are validated here."""
        nu = np.asarray(n_units, np.int64)
        for ev in self.events:
            if not 0 <= ev.rack < len(nu):
                raise ValueError(
                    f"chaos event rack {ev.rack} out of range "
                    f"(fleet has {len(nu)} racks)"
                )
        return LoweredChaos(nu, list(self.events), self.on_kill)

    @classmethod
    def random(
        cls,
        n_racks: int,
        horizon_s: float,
        *,
        seed: int,
        n_events: int = 3,
        on_kill: str = "respill",
        kinds: Sequence[str] = _KINDS,
    ) -> "ChaosSchedule":
        """A seeded random schedule: ``n_events`` fault windows in the
        middle ~[10%, 90%] of the horizon so pre-fault baselines and
        post-fault recovery are both observable. Same seed, same
        schedule — the CI chaos job prints its seed for replay."""
        rng = np.random.default_rng(seed)
        sched = cls(on_kill=on_kill)
        for _ in range(n_events):
            kind = str(kinds[int(rng.integers(len(kinds)))])
            rack = int(rng.integers(n_racks))
            start = float(rng.uniform(0.1, 0.6) * horizon_s)
            dur = float(rng.uniform(0.05, 0.3) * horizon_s)
            end = min(start + dur, 0.9 * horizon_s)
            if kind == KILL:
                # half whole-rack kills, half partial (fraction of units,
                # resolved against the rack size at lower() time)
                units = 0 if rng.random() < 0.5 else int(rng.integers(1, 64))
                sched.add(ChaosEvent(KILL, rack, start, end, units=units))
            else:
                sched.add(ChaosEvent(kind, rack, start, end))
        return sched


class LoweredChaos:
    """A schedule bound to a fleet: pure time -> mask functions.

    Masks are sampled at tick *start* (the engines apply them before
    routing), so an event is visible on the first tick whose start
    falls inside its window. ``masks_at`` is what the scalar/vector
    drivers consume per tick; ``rows`` pre-samples a whole block of
    ticks for the jax engine's ``lax.scan``.
    """

    def __init__(
        self, n_units: np.ndarray, events: List[ChaosEvent], on_kill: str
    ) -> None:
        self.n_units = np.asarray(n_units, np.int64)
        self.events = list(events)
        self.on_kill = on_kill

    def masks_at(
        self, t: float
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(dead_units, fan_failed, power_capped)`` at sim time ``t``:
        int64 down-unit counts and two bool masks, each length
        n_racks. Overlapping kills take the max, not the sum — two
        events naming the same units must not double-kill."""
        n = len(self.n_units)
        dead = np.zeros(n, np.int64)
        fan = np.zeros(n, bool)
        cap = np.zeros(n, bool)
        for ev in self.events:
            if not ev.active(t):
                continue
            if ev.kind == KILL:
                d = (
                    int(self.n_units[ev.rack])
                    if ev.units <= 0
                    else min(ev.units, int(self.n_units[ev.rack]))
                )
                dead[ev.rack] = max(int(dead[ev.rack]), d)
            elif ev.kind == FAN_FAIL:
                fan[ev.rack] = True
            else:
                cap[ev.rack] = True
        return dead, fan, cap

    def rows(
        self, t0: float, n_ticks: int, dt_s: float
    ) -> Dict[str, np.ndarray]:
        """Per-tick mask rows for ticks ``t0, t0+dt, ...`` (the jax
        lowering): dead counts, fan/power-cap masks, plus the full-kill
        edge (newly fully-dead vs the previous tick) that triggers
        queue evacuation in-scan."""
        n = len(self.n_units)
        dead = np.zeros((n_ticks, n), np.int64)
        fan = np.zeros((n_ticks, n), bool)
        cap = np.zeros((n_ticks, n), bool)
        edge = np.zeros((n_ticks, n), bool)
        prev_full = self.masks_at(t0 - dt_s)[0] >= self.n_units
        for k in range(n_ticks):
            d, f, c = self.masks_at(t0 + k * dt_s)
            dead[k] = d
            fan[k] = f
            cap[k] = c
            full = d >= self.n_units
            edge[k] = full & ~prev_full
            prev_full = full
        return {"dead": dead, "fan_fail": fan, "power_cap": cap,
                "kill_edge": edge}

    def any_events(self) -> bool:
        return bool(self.events)


# ---------------------------------------------------------------------------
# Recovery metrics.
# ---------------------------------------------------------------------------
@dataclass
class RecoveryReport:
    """How the fleet rode out a chaos schedule.

    Re-convergence: ticks from the first fault until the rolling p95
    latency returns within ``within`` (default 10%) of its pre-fault
    baseline. ``p99_blowup`` is the worst rolling p99 during the
    recovery window over the pre-fault p99. ``None`` re-convergence
    means the run ended still degraded."""

    fault_t: float
    baseline_p95_s: float
    baseline_p99_s: float
    reconverged_t: Optional[float]
    reconvergence_ticks: Optional[int]
    p99_blowup: float
    dropped_requests: int = 0
    dropped_cost: float = 0.0
    respilled_requests: int = 0
    respilled_cost: float = 0.0

    def to_record(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _rolling_pct(
    times: np.ndarray,
    dt_s: float,
    fins: np.ndarray,
    lats: np.ndarray,
    window_ticks: int,
    q: float,
) -> np.ndarray:
    """Rolling latency percentile per tick: completions whose finish
    falls in the trailing ``window_ticks``-tick window ending at each
    tick's end. NaN where the window holds no completions."""
    out = np.full(len(times), np.nan)
    order = np.argsort(fins, kind="stable")
    fins = fins[order]
    lats = lats[order]
    for i, t in enumerate(times):
        hi = float(t) + dt_s
        lo = hi - window_ticks * dt_s
        a = int(np.searchsorted(fins, lo, side="left"))
        b = int(np.searchsorted(fins, hi, side="right"))
        if b > a:
            out[i] = float(np.percentile(lats[a:b], q))
    return out


def _completions(tel: "FleetTelemetry") -> Tuple[np.ndarray, np.ndarray]:
    fins: List[float] = []
    lats: List[float] = []
    for rack_tel in tel.per_rack:
        for resp in rack_tel.responses:
            fins.append(float(resp.finish_s))
            lats.append(float(resp.latency_s))
    return np.asarray(fins, float), np.asarray(lats, float)


def recovery_window_p99(tel: "FleetTelemetry", fault_t: float) -> float:
    """p99 latency over completions finishing at/after the first fault
    — the recovery-window tail the hedging-benefit delta compares."""
    fins, lats = _completions(tel)
    sel = lats[fins >= fault_t]
    if len(sel) == 0:
        return 0.0
    return float(np.percentile(sel, 99))


def recovery_report(
    tel: "FleetTelemetry",
    fault_t: float,
    *,
    within: float = 0.10,
    window_ticks: int = 5,
    dropped_requests: int = 0,
    dropped_cost: float = 0.0,
    respilled_requests: int = 0,
    respilled_cost: float = 0.0,
) -> RecoveryReport:
    """Post-hoc recovery metrics from finished telemetry (engine
    agnostic: only completions and tick times are consulted, so one
    implementation serves all three backends)."""
    times = np.asarray(tel.time_s, float)
    dt = float(times[1] - times[0]) if len(times) > 1 else 1.0
    fins, lats = _completions(tel)
    p95 = _rolling_pct(times, dt, fins, lats, window_ticks, 95.0)
    p99 = _rolling_pct(times, dt, fins, lats, window_ticks, 99.0)
    i_fault = int(np.searchsorted(times, fault_t, side="left"))
    base95 = base99 = math.nan
    for i in range(min(i_fault, len(times)) - 1, -1, -1):
        if not math.isnan(p95[i]):
            base95 = float(p95[i])
            base99 = float(p99[i])
            break
    reconverged_t: Optional[float] = None
    reconvergence_ticks: Optional[int] = None
    blowup = 1.0
    if not math.isnan(base95) and i_fault < len(times):
        thresh = base95 * (1.0 + within)
        # re-converged = the first tick after which the rolling p95
        # STAYS within tolerance. Scanning for the first in-tolerance
        # tick instead would report ~0 whenever the damage is lagged —
        # a fault's backlog only surfaces in completions finishing
        # (much) later, so the tick right after the fault often still
        # looks clean.
        i_conv: Optional[int] = i_fault
        for i in range(len(times) - 1, i_fault - 1, -1):
            if not math.isnan(p95[i]) and p95[i] > thresh:
                i_conv = i + 1 if i + 1 < len(times) else None
                break
        if i_conv is not None:
            reconverged_t = float(times[i_conv])
            reconvergence_ticks = i_conv - i_fault
        hi = (i_conv + 1) if i_conv is not None else len(times)
        window = p99[i_fault:hi]
        if len(window) and not bool(np.all(np.isnan(window))):
            worst = float(np.nanmax(window))
            if base99 > 0.0:
                blowup = worst / base99
    return RecoveryReport(
        fault_t=float(fault_t),
        baseline_p95_s=0.0 if math.isnan(base95) else base95,
        baseline_p99_s=0.0 if math.isnan(base99) else base99,
        reconverged_t=reconverged_t,
        reconvergence_ticks=reconvergence_ticks,
        p99_blowup=blowup,
        dropped_requests=dropped_requests,
        dropped_cost=dropped_cost,
        respilled_requests=respilled_requests,
        respilled_cost=respilled_cost,
    )


def hedging_delta(
    racks: Sequence["RackConfig"],
    trace: np.ndarray,
    schedule: ChaosSchedule,
    *,
    router: Optional["Router"] = None,
    dt_s: float = 60.0,
    backend: str = "vector",
) -> Dict[str, float]:
    """The hedging-benefit delta: the same chaos trace with hedging as
    configured vs ``hedge_after_s=None``, compared on recovery-window
    p99. Positive ``hedging_benefit_s`` = hedging cut the tail."""
    from repro.fleet.fleet import Fleet

    def run(hedge: bool) -> "FleetTelemetry":
        cfgs = []
        for rc in racks:
            pol = rc.policy
            if not hedge and pol is not None and pol.hedge_after_s is not None:
                pol = dataclasses.replace(pol, hedge_after_s=None)
            cfgs.append(dataclasses.replace(rc, policy=pol))
        fleet = Fleet(
            cfgs, router=router, dt_s=dt_s, backend=backend, chaos=schedule
        )
        return fleet.play_trace(np.asarray(trace, float))

    fault_t = schedule.fault_t
    p_with = recovery_window_p99(run(True), fault_t)
    p_without = recovery_window_p99(run(False), fault_t)
    return {
        "recovery_p99_with_hedge_s": p_with,
        "recovery_p99_without_hedge_s": p_without,
        "hedging_benefit_s": p_without - p_with,
    }


# ---------------------------------------------------------------------------
# Sim-clocked failure detection (composes distributed.fault).
# ---------------------------------------------------------------------------
class ChaosMonitor:
    """Rack-level failure detection on the *simulation* clock.

    Wraps :class:`repro.distributed.fault.HealthTracker` (one "unit"
    per rack) with an injected clock driven by the fleet's tick times —
    ``HealthTracker``'s default ``time.monotonic`` would silently mix
    wall time into sim-time timeout detection, making failed-rack sets
    depend on host speed. Racks that are not fully dead heartbeat every
    observed tick; a fully-dead rack stops heartbeating and crosses
    ``timeout_s`` of *sim* time later — tick-deterministic by
    construction (``tests/test_chaos.py``)."""

    def __init__(
        self,
        n_racks: int,
        timeout_s: float,
        straggler_factor: float = 2.0,
    ) -> None:
        # deferred import: keeps repro.fleet importable without the
        # distributed subpackage and breaks a potential import cycle
        from repro.distributed.fault import HealthTracker

        self._t = 0.0
        self.tracker: "HealthTracker" = HealthTracker(
            list(range(n_racks)),
            timeout_s=timeout_s,
            straggler_factor=straggler_factor,
            clock=lambda: self._t,
        )

    def observe(
        self, t: float, dead: np.ndarray, n_units: np.ndarray
    ) -> None:
        """One tick's liveness: advance the sim clock, heartbeat every
        rack that still has live units."""
        self._t = float(t)
        for r in range(len(n_units)):
            if int(dead[r]) < int(n_units[r]):
                self.tracker.heartbeat(r, 0.0)

    def failed_racks(self) -> List[int]:
        return self.tracker.failed_units()

    def failed_mask(self, n_racks: int) -> np.ndarray:
        """Boolean per-rack failure mask — the array form the
        degradation layer's circuit breakers consume (``degrade.py``
        mirrors this timeout in whole ticks so every engine agrees on
        the transition instant)."""
        mask = np.zeros(n_racks, bool)
        for r in self.failed_racks():
            if r < n_racks:
                mask[r] = True
        return mask
