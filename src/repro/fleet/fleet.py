"""``Fleet`` — N racks behind a geo-routed load balancer.

The paper prototypes one 60-SoC rack; public edge platforms aggregate
hundreds of such sites behind request routers. A :class:`Fleet` holds N
racks (mixed :class:`~repro.core.cluster.ClusterSpec`\\ s allowed), a
:class:`~repro.fleet.router.Router` that shards the fleet-level offered
load across racks each tick, and per-rack elastic unit governors — the
same activation policy the single-rack runtime uses, applied one level
up. A rack may additionally carry the full power stack: an
:class:`~repro.power.opp.OPPTable` with a frequency governor
(``ScalePolicy.freq_governor``), an RC thermal network
(:class:`~repro.power.thermal.ThermalParams`), and straggler hedging
(``ScalePolicy.hedge_after_s``).

Two engines implement the same simulation:

  * ``backend="scalar"`` — one full per-unit
    :class:`~repro.runtime.ClusterRuntime` per rack (the reference:
    every unit is an object, every tick walks every rack's pool);
  * ``backend="vector"`` — rack state stacked into numpy arrays:
    activation targets, cooldown timers, per-rack OPP indices with the
    per-OPP perf/power scales stacked as (racks, opps) tables, the
    frequency governors (``fixed`` / ``race-to-idle`` / ``schedutil``'s
    lowest-energy OPP×unit-count search / the ``ThermalAwareGovernor``
    ceiling clamp) evaluated as masked argmin passes over the OPP axis,
    hedging as a per-rack borrowed-unit counter in the fluid drain, and
    the RC thermal networks of every thermal-modelled rack flattened
    into one stacked per-die state. Per-rack fluid FIFO queues are kept
    for exact request latencies.

The vector engine replicates the scalar engine's arithmetic operation
for operation, so the two produce **bitwise-identical** telemetry —
energy integrals, latency percentiles, and temperature/throttle/fan
histories — while the vector engine runs an order of magnitude faster:
fast enough to sweep 100 racks x 24 simulated hours in seconds
(``benchmarks/fig16_fleet.py``), with or without a frequency governor.
Governors outside the built-in set still work: they fall back to a
per-rack ``select`` call against a real
:class:`~repro.power.governor.FreqContext` (correct, just not stacked).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.fleet.chaos import (
    ChaosMonitor,
    ChaosSchedule,
    LoweredChaos,
    recovery_report,
)
from repro.fleet.degrade import (
    BRK_CLOSED,
    DegradeDriver,
    DegradePolicy,
    LoweredDegrade,
)
from repro.fleet.engine_state import (
    GOV_FIXED,
    GOV_RACE,
    GOV_SCHED,
    ThermalLayout,
    build_fleet_arrays,
)
from repro.fleet.router import FleetView, JoinShortestQueueRouter, Router
from repro.fleet.telemetry import FleetTelemetry
from repro.power.governor import FreqContext
from repro.power.opp import OPPTable
from repro.power.thermal import ThermalParams
from repro.runtime import (
    ClusterRuntime,
    QueueWorkload,
    Request,
    ScalePolicy,
    Telemetry,
    latency_percentiles,
)

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (obs is optional)
    from repro.obs import FleetObs

__all__ = ["RackConfig", "Fleet", "homogeneous_fleet"]


@dataclass
class RackConfig:
    """One rack's binding into the fleet.

    ``opp_table`` enables the frequency axis for the rack (consulted by
    ``policy.freq_governor``); ``thermal`` attaches the per-die RC
    network with trip-latch throttling (requires an ``opp_table`` to
    throttle within, exactly like the pool)."""

    spec: ClusterSpec
    unit_rate: float  # requests/s one unit sustains
    policy: Optional[ScalePolicy] = None
    name: str = ""
    opp_table: Optional[OPPTable] = None
    thermal: Optional[ThermalParams] = None


def homogeneous_fleet(
    spec: ClusterSpec,
    n_racks: int,
    unit_rate: float,
    policy: Optional[ScalePolicy] = None,
    opp_table: Optional[OPPTable] = None,
    thermal: Optional[ThermalParams] = None,
) -> List[RackConfig]:
    """N identical racks (the common case for a single-platform fleet)."""
    return [
        RackConfig(
            spec,
            unit_rate,
            policy,
            name=f"{spec.name}/{i}",
            opp_table=opp_table,
            thermal=thermal,
        )
        for i in range(n_racks)
    ]


def _init_chaos_state(engine: Any, n: int) -> None:
    """Shared chaos bookkeeping both tick engines carry (inert until
    ``apply_chaos`` is first called — the no-chaos fast paths check a
    single bool). ``chaos_dead`` is the *current* per-rack down-unit
    count; the cumulative counters feed telemetry and the sanitizer's
    conservation credit."""
    engine._chaos_active = False
    engine.chaos_on_kill = "respill"
    engine.chaos_dead = np.zeros(n, np.int64)
    engine.chaos_fan = np.zeros(n, bool)
    engine.chaos_cap = np.zeros(n, bool)
    engine.chaos_evac_cost = 0.0
    engine.chaos_evac_by_rack = np.zeros(n)
    engine.chaos_dropped = 0
    engine.chaos_dropped_cost = 0.0
    engine.chaos_respilled = 0
    engine.chaos_respilled_cost = 0.0


def _init_degrade_state(engine: Any, n: int) -> None:
    """Shared degradation bookkeeping both tick engines carry (inert
    until the fleet calls ``expire``). The cumulative expired cost is
    the sanitizer's conservation credit — work abandoned past its
    deadline was injected but will never be served."""
    engine.degrade_expired = 0
    engine.degrade_expired_cost = 0.0
    engine.degrade_expired_by_rack = np.zeros(n)


def _tier_requests(
    work: float, arrival_s: float,
    tier_split: Sequence[Tuple[Optional[str], float]],
) -> List[Tuple[float, Request]]:
    """Split one rack's tick work into per-tier sub-requests (shared by
    both host engines so the sub-costs are the same float expressions).
    Slice existence is decided by ``frac > 0`` alone — never by cost
    rounding dust — and the *last positive-fraction* slice takes the
    exact remainder, so the slices sum back to ``work`` bitwise and
    splitting never perturbs conservation. The jax engine mirrors this
    split host-side from its emitted per-tier admitted rows (its
    fractions agree within tolerance, so the frac-positivity predicate
    keeps sub-request counts identical across engines). Only the first
    slice carries the arrival-rate ``count`` weight; the rest weigh
    ``0.0`` (adding 0.0 to the non-negative windowed accumulator is a
    bitwise no-op), keeping the scalar governor's rate estimate
    identical to the unsplit path — the vector engine's ``work / dt``."""
    out: List[Tuple[float, Request]] = []
    idx = [i for i, (_name, frac) in enumerate(tier_split) if frac > 0.0]
    if not idx:
        return out
    acc = 0.0
    cnt = work
    for i in idx[:-1]:
        name, frac = tier_split[i]
        c = work * frac
        out.append(
            (cnt, Request(payload=name, cost=c, arrival_s=arrival_s)))
        cnt = 0.0
        acc += c
    c = work - acc
    if c > 0.0:
        out.append(
            (cnt, Request(payload=tier_split[idx[-1]][0], cost=c,
                          arrival_s=arrival_s)))
    return out


class _ScalarFleetEngine:
    """Reference engine: one per-unit ClusterRuntime per rack."""

    backend = "scalar"

    def __init__(
        self,
        racks: Sequence[RackConfig],
        dt_s: float,
        idle_units_off: bool,
    ) -> None:
        self.dt_s = dt_s
        self.now = 0.0
        self.obs: Optional["FleetObs"] = None
        self._any_thermal = any(rc.thermal is not None for rc in racks)
        self.rts: List[ClusterRuntime] = []
        for i, rc in enumerate(racks):
            wl = QueueWorkload(rc.unit_rate, name=rc.name or f"rack{i}")
            self.rts.append(
                ClusterRuntime(
                    rc.spec,
                    wl,
                    policy=rc.policy,
                    window_s=dt_s,
                    dt_s=dt_s,
                    idle_units_off=idle_units_off,
                    opp_table=rc.opp_table,
                    thermal=rc.thermal,
                    backend="scalar",
                )
            )
        self.n_units = np.array([rc.spec.n_units for rc in racks], np.int64)
        _init_chaos_state(self, len(self.rts))
        _init_degrade_state(self, len(self.rts))

    def queued_cost(self) -> np.ndarray:
        return np.array([rt.workload.pending_cost for rt in self.rts], float)

    def expire(self, deadline_s: float) -> None:
        """Abandon queued work older than ``deadline_s`` (deadline-aware
        load shedding; called by the fleet driver before routing)."""
        t = self.now
        for r, rt in enumerate(self.rts):
            n_req, cost = rt.workload.expire(t, deadline_s)
            if n_req:
                self.degrade_expired += n_req
                self.degrade_expired_cost += cost
                self.degrade_expired_by_rack[r] += cost

    def active_units(self) -> np.ndarray:
        return np.array([rt.active_units for rt in self.rts], np.int64)

    def apply_chaos(
        self,
        dead: np.ndarray,
        fan_fail: np.ndarray,
        power_cap: np.ndarray,
    ) -> float:
        """Impose one tick's fault masks on every rack (called by the
        fleet driver *before* routing). Kills are count-granular: the
        governor's ``unit_cap`` force-releases units beyond the cap and
        blocks hedging past it, so the pool's charge arithmetic never
        changes. A full-rack kill *edge* evacuates the rack's queue;
        the evacuated cost is returned for the driver to re-offer
        (``on_kill="respill"``) or counted as dropped. Racks are walked
        in ascending order so the respilled total accumulates in the
        same float order as the vector engine's."""
        spill = 0.0
        prev = self.chaos_dead
        respill = self.chaos_on_kill == "respill"
        for r, rt in enumerate(self.rts):
            d = int(dead[r])
            nu = int(self.n_units[r])
            if d >= nu and prev[r] < nu:
                n_req, cost = rt.workload.evacuate()
                self.chaos_evac_cost += cost
                self.chaos_evac_by_rack[r] += cost
                if respill:
                    spill += cost
                    self.chaos_respilled += n_req
                    self.chaos_respilled_cost += cost
                else:
                    self.chaos_dropped += n_req
                    self.chaos_dropped_cost += cost
            gov = rt.governor
            gov.unit_cap = (nu - d) if d > 0 else None
            gov.force_floor_opp = bool(power_cap[r])
            pool_th = rt.pool.thermal
            if pool_th is not None:
                pool_th.fan_failed = bool(fan_fail[r])
        np.copyto(self.chaos_dead, dead)
        np.copyto(self.chaos_fan, fan_fail)
        np.copyto(self.chaos_cap, power_cap)
        self._chaos_active = bool(
            dead.any() or fan_fail.any() or power_cap.any()
        )
        return spill

    def tick(
        self, assign_rps: np.ndarray, dt: float,
        tier_split: Optional[Sequence[Tuple[Optional[str], float]]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        t = self.now
        for r, rt in enumerate(self.rts):
            work = float(assign_rps[r]) * dt
            if work > 0:
                if tier_split is None:
                    rt.submit(
                        count=work,
                        request=Request(cost=work, arrival_s=t + 0.5 * dt),
                    )
                else:
                    for cnt, req in _tier_requests(
                        work, t + 0.5 * dt, tier_split
                    ):
                        rt.submit(count=cnt, request=req)
        n = len(self.rts)
        queued = np.zeros(n, np.int64)
        conc = np.zeros(n, np.int64)
        obs = self.obs
        emit = (
            obs is not None
            and obs.probes is not None
            and obs.probes.active
        )
        hedges = np.zeros(n, np.int64) if emit else None
        for r, rt in enumerate(self.rts):
            stats = rt.tick(dt)
            queued[r] = stats.queued
            conc[r] = stats.concurrency
            if hedges is not None:
                hedges[r] = stats.hedge_units
        if hedges is not None:
            self._emit_probes(t, dt, queued, hedges)
        self.now = t + dt
        return queued, conc

    def _emit_probes(
        self, t: float, dt: float, queued: np.ndarray, hedges: np.ndarray
    ) -> None:
        """One probe row from the pools' just-appended tick histories.
        The ledger surface needs no fleet-level hook here: each pool
        meters its own charge() ticks (``UnitPool.attach_ledger``)."""
        assert self.obs is not None and self.obs.probes is not None
        pools = [rt.pool for rt in self.rts]
        row = {
            "power_w": np.array([p.power_hist[-1] for p in pools]),
            "queued": queued.astype(float),
            "active_units": np.array(
                [float(p.active_hist[-1]) for p in pools]
            ),
            "waking_units": np.array(
                [float(p.n_waking_total()) for p in pools]
            ),
            "utilization": np.array([p.util_hist[-1] for p in pools]),
            "opp_index": np.array(
                [
                    float(p._tenant_opp_of(rt._TENANT))
                    if p.opp_table is not None
                    else 0.0
                    for p, rt in zip(pools, self.rts)
                ]
            ),
            "hedge_units": hedges.astype(float),
        }
        if self._any_thermal:
            row["max_temp_c"] = np.array(
                [
                    p.max_temp_hist[-1] if p.thermal is not None else np.nan
                    for p in pools
                ]
            )
            row["throttled_units"] = np.array(
                [
                    float(p.throttled_hist[-1])
                    if p.thermal is not None
                    else 0.0
                    for p in pools
                ]
            )
        self.obs.probes.emit_tick(t, dt, row)

    def per_rack_telemetry(self) -> List[Telemetry]:
        return [rt.cluster_telemetry() for rt in self.rts]


class _StackedThermal:
    """Every thermal-modelled rack's RC network in one flat state.

    Per-die temperatures, per-PCB-group temperatures, and trip latches
    of all racks live in single arrays; the Euler substeps are
    elementwise, per-group heat flows are contiguous ``reduceat``
    segment sums (same ascending-unit accumulation order as the scalar
    :class:`~repro.power.thermal.ThermalModel` loop), and per-rack fan
    fractions are segment maxima. Racks whose sub-step count differs
    (different specs/params) are frozen with zero-deltas once their own
    sub-steps are done — adding ``0.0`` leaves a temperature bitwise
    unchanged — so every rack integrates exactly as its scalar twin.
    """

    def __init__(self, layout: ThermalLayout) -> None:
        # static layout + RC parameters are shared with the jax engine
        # (built once in engine_state.build_thermal_layout)
        self.layout = layout
        self.t_idx = layout.t_idx  # fleet rack indices
        self.r_die = layout.r_die
        self.c_die = layout.c_die
        self.r_pcb0 = layout.r_pcb0
        self.c_pcb = layout.c_pcb
        self.t_amb = layout.t_amb
        self.fan_low = layout.fan_low
        self.fan_span = layout.fan_span
        self.fan_rmin = layout.fan_rmin
        self.fan_pmax = layout.fan_pmax
        self.trip = layout.trip
        self.release = layout.release
        self.last_unit = layout.last_unit
        self.n_flat_units = layout.n_flat_units
        self.unit_starts = layout.unit_starts
        self.group_starts = layout.group_starts
        self.rack_u = layout.rack_u
        self.rack_g = layout.rack_g
        self.local_idx = layout.local_idx
        self.group_of_u = layout.group_of_u
        self.r_die_u = layout.r_die_u
        self.c_die_u = layout.c_die_u
        self.c_pcb_g = layout.c_pcb_g
        self.t_amb_g = layout.t_amb_g
        self.max_sustainable = layout.max_sustainable
        # mutable state: per-die / per-group temperatures + trip latches
        self.t_die = layout.t_amb[layout.rack_u].copy()
        self.t_pcb = layout.t_amb[layout.rack_g].copy()
        self.latched = np.zeros(layout.n_flat_units, bool)
        self._pw = np.empty(layout.n_flat_units, float)

    def any_latched(self) -> bool:
        return bool(self.latched.any())

    def step(
        self, dt: float, pw: np.ndarray,
        fan_fail: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Advance every stacked network one tick under the flat
        per-unit power draw; returns per-thermal-rack ``(fan_w,
        max_die_temp_c, n_throttled)`` — the three pool histograms.
        ``fan_fail`` (chaos, per thermal rack) pins a failed shared fan
        rail's airflow at zero: frac = 0.0 collapses ``r_pcb`` to the
        no-airflow resistance and ``fan_w`` to 0.0, bitwise the scalar
        ``ThermalModel.fan_failed`` path; healthy racks' fracs are left
        untouched."""
        hottest = np.maximum.reduceat(self.t_pcb, self.group_starts)
        raw_frac = (hottest - self.fan_low) / self.fan_span
        frac = np.minimum(1.0, np.maximum(0.0, raw_frac))
        if fan_fail is not None and fan_fail.any():
            frac = np.where(fan_fail, 0.0, frac)
        r_pcb = self.r_pcb0 * (1.0 - (1.0 - self.fan_rmin) * frac)
        tau = np.minimum(self.r_die * self.c_die, r_pcb * self.c_pcb)
        denom = np.maximum(0.25 * tau, 1e-6)
        n_sub = np.maximum(1, (dt / denom).astype(np.int64) + 1)
        h = dt / n_sub
        h_u = h[self.rack_u]
        h_g = h[self.rack_g]
        r_pcb_g = r_pcb[self.rack_g]
        max_sub = int(n_sub.max())
        uniform = bool((n_sub == max_sub).all())
        n_groups = len(self.t_pcb)
        for s in range(max_sub):
            f = (self.t_die - self.t_pcb[self.group_of_u]) / self.r_die_u
            # weighted bincount adds in input order — bitwise-identical
            # to the scalar per-unit accumulation loop, which float
            # add.reduceat is not (its reduction is not left-to-right)
            flows = np.bincount(self.group_of_u, weights=f, minlength=n_groups)
            d_die = h_u * (pw - f) / self.c_die_u
            out = (self.t_pcb - self.t_amb_g) / r_pcb_g
            d_pcb = h_g * (flows - out) / self.c_pcb_g
            if not uniform:
                live = s < n_sub
                d_die = np.where(live[self.rack_u], d_die, 0.0)
                d_pcb = np.where(live[self.rack_g], d_pcb, 0.0)
            self.t_die += d_die
            self.t_pcb += d_pcb
        self.latched = np.where(
            self.latched,
            ~(self.t_die <= self.release[self.rack_u]),
            self.t_die >= self.trip[self.rack_u],
        )
        fan_w = self.fan_pmax * frac
        max_temp = np.maximum.reduceat(self.t_die, self.unit_starts)
        n_thr = np.add.reduceat(  # reprolint: ok[RPL001] int64 counts: integer addition is exact in any order
            self.latched.astype(np.int64), self.unit_starts)
        return fan_w, max_temp, n_thr


class _VectorFleetEngine:
    """Stacked engine: rack state as arrays, one numpy pass per tick.

    Every floating-point expression mirrors the scalar engine's
    operation order exactly (``UnitGovernor.target_units``,
    ``UnitPool.charge``, the windowed rate estimate, the frequency
    governors, and the thermal Euler step collapse to closed forms when
    ``window_s == dt_s``, group size is 1, and each rack hosts one
    fluid tenant), so per-rack telemetry is bitwise-identical to the
    scalar engine's. The fluid FIFO queues stay as per-rack
    :class:`QueueWorkload` objects — both engines share that code, so
    request latencies match by construction.

    The frequency axis: each rack carries one OPP index (single tenant,
    so the pool's per-unit requested points collapse to it), the per-OPP
    perf/power scales are stacked as (racks, opps) tables, and the
    built-in governors run as masked argmin passes over the OPP axis.
    Straggler hedging is a per-rack borrowed-unit counter folded into
    the fluid drain and the power integral, exactly as the runtime
    charges it. Trip-latched dies are metered at the floor OPP through
    per-rack latched-active counts from the stacked thermal state.
    """

    backend = "vector"

    def __init__(
        self,
        racks: Sequence[RackConfig],
        dt_s: float,
        idle_units_off: bool,
    ) -> None:
        # every static per-rack array — activation policy, stacked OPP
        # tables, governor classification, thermal layout — comes from
        # the shared builder (also consumed by the jax engine)
        arr = build_fleet_arrays(racks, idle_units_off)
        self.arrays = arr
        self.dt_s = dt_s
        self.now = 0.0
        self.obs: Optional["FleetObs"] = None
        self._any_table = bool(np.any(arr.has_table))
        self._any_hedge = any(dl is not None for dl in arr.hedge_deadline)
        self._obs_zeros: Optional[np.ndarray] = None
        self.n_units = arr.n_units
        self.unit_rate = arr.unit_rate
        self.headroom = arr.headroom
        self.min_units = arr.min_units
        self.minq = arr.minq
        self.cooldown = arr.cooldown
        self.p_shared = arr.p_shared
        self.p_idle = arr.p_idle
        self.p_peak = arr.p_peak
        self.gamma = arr.gamma
        self.span = arr.span
        self.p_base = arr.p_base
        self.wls = [
            QueueWorkload(rc.unit_rate, name=arr.names[i])
            for i, rc in enumerate(racks)
        ]
        n = arr.n_racks
        self._rr = np.arange(n)
        self.has_table = arr.has_table
        self.K = arr.K
        self.Kmax = arr.Kmax
        self.perf_tab = arr.perf_tab
        self.spk_tab = arr.spk_tab
        self.opp = arr.opp0.copy()
        self.nominal = arr.nominal
        self.highest = arr.highest
        self.therm: Optional[_StackedThermal] = (
            _StackedThermal(arr.thermal) if arr.thermal is not None else None
        )
        self.t_idx = arr.t_idx
        self._gov_kind = arr.gov_kind
        self._fixed_opp = arr.fixed_opp
        self._sched_headroom = arr.sched_headroom
        self._ceiling = arr.ceiling
        self._has_ceiling = arr.has_ceiling
        self._generic = arr.generic
        self._tables = arr.tables
        self._unit_specs = arr.unit_specs
        self._max_sust = arr.max_sust
        self._fixed_idx = np.nonzero(arr.gov_kind == GOV_FIXED)[0]
        self._race_idx = np.nonzero(arr.gov_kind == GOV_RACE)[0]
        self._sched_idx = np.nonzero(arr.gov_kind == GOV_SCHED)[0]
        # hedging config (None = off), per rack
        self._hedge_deadline = arr.hedge_deadline
        self.backlog = np.zeros(n, bool)
        self.active = arr.minq.copy()
        self.last_down = np.full(n, -1e9)
        self.scale_events = np.zeros(n, np.int64)
        self.hedged_cnt = np.zeros(n, np.int64)
        self.energy = np.zeros(n)
        self.unit_energy = np.zeros(n)
        self.served_acc = np.zeros(n)
        self.responses: List[list] = [[] for _ in range(n)]
        self._t_hist: List[float] = []
        self._offered_rows: List[np.ndarray] = []
        self._active_rows: List[np.ndarray] = []
        self._power_rows: List[np.ndarray] = []
        self._util_rows: List[np.ndarray] = []
        self._fan_rows: List[np.ndarray] = []
        self._temp_rows: List[np.ndarray] = []
        self._thr_rows: List[np.ndarray] = []
        _init_chaos_state(self, n)
        _init_degrade_state(self, n)

    def queued_cost(self) -> np.ndarray:
        return np.array([wl.pending_cost for wl in self.wls], float)

    def expire(self, deadline_s: float) -> None:
        """Vector twin of the scalar ``expire`` — the deque walk lives
        in the shared :class:`QueueWorkload`, so the popped requests and
        reclaimed cost are identical by construction."""
        t = self.now
        for r, wl in enumerate(self.wls):
            n_req, cost = wl.expire(t, deadline_s)
            if n_req:
                self.degrade_expired += n_req
                self.degrade_expired_cost += cost
                self.degrade_expired_by_rack[r] += cost

    def active_units(self) -> np.ndarray:
        return self.active.copy()

    def apply_chaos(
        self,
        dead: np.ndarray,
        fan_fail: np.ndarray,
        power_cap: np.ndarray,
    ) -> float:
        """Vector twin of the scalar engine's ``apply_chaos``: same
        ascending-rack evacuation order (so the respilled total is the
        same float accumulation), same counters. The masks themselves
        are folded into :meth:`tick` as overlays — carried governor
        state (``self.opp``, cooldown stamps) is never clobbered."""
        spill = 0.0
        prev = self.chaos_dead
        respill = self.chaos_on_kill == "respill"
        nu = self.n_units
        for r in np.nonzero((dead >= nu) & (prev < nu))[0]:
            n_req, cost = self.wls[r].evacuate()
            self.chaos_evac_cost += cost
            self.chaos_evac_by_rack[r] += cost
            if respill:
                spill += cost
                self.chaos_respilled += n_req
                self.chaos_respilled_cost += cost
            else:
                self.chaos_dropped += n_req
                self.chaos_dropped_cost += cost
        np.copyto(self.chaos_dead, dead)
        np.copyto(self.chaos_fan, fan_fail)
        np.copyto(self.chaos_cap, power_cap)
        self._chaos_active = bool(
            dead.any() or fan_fail.any() or power_cap.any()
        )
        return spill

    # ------------------------------------------------------------------
    def _select_opps(self, rate: np.ndarray, t: float) -> None:
        """One frequency-governor decision per rack, vectorized over the
        OPP axis for the built-in governors (mirrors
        ``UnitGovernor._select_opp`` + each governor's ``select``)."""
        if self._fixed_idx.size:
            self.opp[self._fixed_idx] = self._fixed_opp[self._fixed_idx]
        ri = self._race_idx
        if ri.size:
            busy = (rate[ri] > 0.0) | self.backlog[ri]
            self.opp[ri] = np.where(busy, self.highest[ri], self.nominal[ri])
        si = self._sched_idx
        if si.size:
            d = rate[si]
            need = d * self._sched_headroom[si]
            ur = self.unit_rate[si]
            nu = self.n_units[si]
            mu = self.min_units[si]
            pg = self.p_base[si]
            pi = self.p_idle[si]
            ga = self.gamma[si]
            kv = self.K[si]
            best = self.highest[si].copy()
            bestp = np.full(si.size, np.inf)
            pos = need > 0.0
            for c in range(self.Kmax):
                eff = ur * self.perf_tab[si, c]
                ncnt = np.maximum(mu, np.ceil(need / eff)).astype(np.int64)
                util = np.minimum(1.0, d / (np.maximum(ncnt, 1) * eff))
                spk = self.spk_tab[si, c]
                power = ncnt * (pi + spk * util**ga) + (nu - ncnt) * pg
                upd = (c < kv) & (ncnt <= nu) & pos & (power < bestp - 1e-12)
                best = np.where(upd, c, best)
                bestp = np.where(upd, power, bestp)
            self.opp[si] = np.where(pos, best, 0)
        for r, gov in self._generic:
            tb = self._tables[r]
            ctx = FreqContext(
                demand_rate=float(rate[r]),
                unit_rate=float(self.unit_rate[r]),
                headroom=float(self.headroom[r]),
                n_units=int(self.n_units[r]),
                table=tb,
                unit=self._unit_specs[r],
                min_units=int(self.min_units[r]),
                max_sustainable=self._max_sust[r],
                backlog=bool(self.backlog[r]),
                p_gated_w=float(self.p_base[r]),
            )
            self.opp[r] = tb.clamp(gov.select(ctx))
        if self._has_ceiling.any():
            clamped = np.minimum(self.opp, self._ceiling)
            self.opp = np.where(self._has_ceiling, clamped, self.opp)

    # ------------------------------------------------------------------
    def tick(
        self, assign_rps: np.ndarray, dt: float,
        tier_split: Optional[Sequence[Tuple[Optional[str], float]]] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        t = self.now
        work = assign_rps * dt
        if tier_split is None:
            for r in np.nonzero(work > 0)[0]:
                req = Request(cost=float(work[r]), arrival_s=t + 0.5 * dt)
                self.wls[r].submit(req)
        else:
            for r in np.nonzero(work > 0)[0]:
                for _cnt, req in _tier_requests(
                    float(work[r]), t + 0.5 * dt, tier_split
                ):
                    self.wls[r].submit(req)
        # windowed rate estimate with window == dt: this tick's work
        rate = work / dt
        # frequency governors pick this tick's OPP; the activation
        # target is then sized against that point's effective rate
        self._select_opps(rate, t)
        # chaos overlays (inert fast path: one bool when no fault is
        # live). Killed units shrink the usable rack; a power-capped
        # rack *runs* at the floor point this tick while the carried
        # governor state (self.opp) is untouched — exactly the scalar
        # governor's force_floor_opp / unit_cap semantics.
        chaos = self._chaos_active
        if chaos:
            cap_units = self.n_units - self.chaos_dead
            opp_eff = np.where(self.chaos_cap & self.has_table, 0, self.opp)
        else:
            cap_units = self.n_units
            opp_eff = self.opp
        # the chosen points' perf scales, for both activation sizing and
        # the workload's mean perf multiplier
        perf_req = self.perf_tab[self._rr, opp_eff]
        perf_sz = np.where(self.has_table, perf_req, 1.0)
        # UnitGovernor.target_units with group == 1
        need = rate * self.headroom / (self.unit_rate * np.maximum(perf_sz, 1e-9))
        raw = np.minimum(self.n_units, np.maximum(self.min_units, np.ceil(need)))
        tgt = np.maximum(1, raw.astype(np.int64))
        # UnitGovernor.apply_target: immediate scale-up, cooldown-gated
        # scale-down to max(min floor, target)
        active = self.active
        if chaos:
            # killed units are force-released (no cooldown stamp, no
            # scale event — a fault is not a scaling decision) and the
            # target is capped, mirroring apply_target's unit_cap path
            tgt = np.minimum(tgt, cap_units)
            active = np.minimum(active, cap_units)
        up = tgt > active
        keep = np.maximum(self.minq, tgt)
        in_cooldown = t - self.last_down > self.cooldown
        down = (tgt < active) & in_cooldown & (keep < active)
        new_active = np.where(up, tgt, np.where(down, keep, active))
        self.scale_events += up
        self.scale_events += down
        self.last_down = np.where(down, t, self.last_down)
        self.active = new_active
        k_f = new_active.astype(float)
        # mean perf-scale over each rack's active units (pool.perf_scale:
        # trip-latched units are dragged to the floor point). A fully
        # killed rack has k == 0: the pool returns the requested point's
        # perf there (k_div only rewrites the k == 0 lanes — for k >= 1
        # the division is bitwise the original expression)
        if chaos:
            k_div = np.maximum(k_f, 1.0)
            perf_used = np.where(
                self.has_table,
                np.where(new_active > 0, (k_f * perf_req) / k_div, perf_req),
                1.0,
            )
        else:
            perf_used = np.where(self.has_table, (k_f * perf_req) / k_f, 1.0)
        latched_any = self.therm is not None and self.therm.any_latched()
        floor_all = None
        if latched_any:
            th = self.therm
            ti = self.t_idx
            am = th.local_idx < new_active[ti][th.rack_u]
            lam = (am & th.latched).astype(np.int64)
            c_low_t = np.add.reduceat(lam, th.unit_starts)  # reprolint: ok[RPL001] lam is int64 0/1 flags: integer addition is exact in any order
            c_low_f = c_low_t.astype(float)
            k_t = k_f[ti]
            p0 = self.perf_tab[ti, 0]
            pr = self.perf_tab[ti, opp_eff[ti]]
            # single product when everything lands in the floor bucket,
            # the two-bucket ascending accumulation otherwise — exactly
            # _perf_from_opp_counts
            floor_all = (opp_eff[ti] == 0) & (c_low_t > 0)
            mixed = c_low_f * p0 + (k_t - c_low_f) * pr
            if chaos:
                k_div_t = np.maximum(k_t, 1.0)
                perf_used[ti] = np.where(
                    k_t > 0,
                    np.where(floor_all, k_t * p0, mixed) / k_div_t,
                    pr,
                )
            else:
                perf_used[ti] = np.where(floor_all, k_t * p0, mixed) / k_t
        else:
            am = c_low_f = None
        # fluid FIFO drain per rack (QueueWorkload.step_fast — the
        # allocation-light twin of step(), identical arithmetic), with
        # straggler hedging: a rack whose oldest queued request has
        # waited past hedge_after_s borrows one free unit this tick
        n = len(self.wls)
        acts = new_active.tolist()
        nu_l = self.n_units.tolist()
        # hedging may only borrow a *live* unit (scalar: unit_cap gates
        # the borrow in MultiTenantRuntime)
        cap_l = cap_units.tolist() if chaos else nu_l
        perf_l = perf_used.tolist()
        hedges = [0] * n
        utils_l: List[float] = []
        served_l: List[float] = []
        queued_l: List[int] = []
        conc_l: List[int] = []
        for r in range(n):
            wl = self.wls[r]
            a = acts[r]
            h = 0
            dl = self._hedge_deadline[r]
            if dl is not None and a < cap_l[r]:
                age = wl.oldest_waiting_s(t)
                if age is not None and age > dl:
                    h = 1
                    self.hedged_cnt[r] += 1
            hedges[r] = h
            used, util, q, c = wl.step_fast(a + h, dt, t, perf_l[r])
            utils_l.append(util)
            served_l.append(used)
            queued_l.append(q)
            conc_l.append(c)
            if wl._completed:
                self.responses[r].extend(wl.drain())
        utils = np.asarray(utils_l, float)
        served = np.asarray(served_l, float)
        queued = np.asarray(queued_l, np.int64)
        conc = np.asarray(conc_l, np.int64)
        h_arr = np.asarray(hedges, np.int64)
        self.backlog = queued > 0
        # UnitPool.charge, elementwise per rack: active units at the
        # rack's OPP (latched dies at the floor point), the borrowed
        # hedge unit at the requested point, the rest at the gated floor
        u = np.minimum(np.maximum(utils, 0.0), 1.0)
        ug = u**self.gamma
        w_req = self.p_idle + self.spk_tab[self._rr, opp_eff] * ug
        h_f = h_arr.astype(float)
        powered = new_active + h_arr
        powered_f = powered.astype(float)
        p_act = k_f * w_req
        w_low = None
        if latched_any:
            w_low = self.p_idle + self.spk_tab[:, 0] * ug
            ti = self.t_idx
            mixed = c_low_f * w_low[ti] + (k_f[ti] - c_low_f) * w_req[ti]
            p_act[ti] = np.where(floor_all, k_f[ti] * w_low[ti], mixed)
        p_units = np.where(self.has_table, p_act + h_f * w_req, powered_f * w_req)
        fan_w = np.zeros(n)
        if self.therm is not None:
            th = self.therm
            ti = self.t_idx
            if am is None:
                am = th.local_idx < new_active[ti][th.rack_u]
            pw = th._pw
            np.copyto(pw, self.p_base[ti][th.rack_u])
            np.copyto(pw, w_req[ti][th.rack_u], where=am)
            if latched_any:
                np.copyto(pw, w_low[ti][th.rack_u], where=am & th.latched)
            for j in np.nonzero(h_arr[ti] > 0)[0]:
                pw[th.last_unit[j]] = w_req[ti[j]]
            f_t, temp_t, thr_t = th.step(
                dt, pw, fan_fail=self.chaos_fan[ti] if chaos else None
            )
            fan_w[ti] = f_t
            self._fan_rows.append(f_t)
            self._temp_rows.append(temp_t)
            self._thr_rows.append(thr_t)
        p_rest = (self.n_units - powered).astype(float) * self.p_base
        total = self.p_shared + fan_w + p_units + p_rest
        self.energy += total * dt
        self.unit_energy += p_units * dt
        self.served_acc += served
        util_agg = np.divide(
            powered_f * u,
            powered_f,
            out=np.zeros(n),
            where=powered_f > 0,
        )
        self._t_hist.append(t)
        self._offered_rows.append(rate)
        self._active_rows.append(powered)
        self._power_rows.append(total)
        self._util_rows.append(util_agg)
        if self.obs is not None:
            self._emit_obs(
                t,
                dt,
                total=total,
                opp_eff=opp_eff,
                queued=queued,
                powered=powered,
                powered_f=powered_f,
                h_arr=h_arr,
                util_agg=util_agg,
                fan_w=fan_w,
                p_act=p_act,
                w_req=w_req,
                p_rest=p_rest,
                latched_any=latched_any,
                c_low_f=c_low_f,
                w_low=w_low,
            )
        self.now = t + dt
        return queued, conc

    def _emit_obs(
        self,
        t: float,
        dt: float,
        *,
        total: np.ndarray,
        opp_eff: np.ndarray,
        queued: np.ndarray,
        powered: np.ndarray,
        powered_f: np.ndarray,
        h_arr: np.ndarray,
        util_agg: np.ndarray,
        fan_w: np.ndarray,
        p_act: np.ndarray,
        w_req: np.ndarray,
        p_rest: np.ndarray,
        latched_any: bool,
        c_low_f: Optional[np.ndarray],
        w_low: Optional[np.ndarray],
    ) -> None:
        """Ledger leaves + probe row for one tick. The ledger arrays
        replay bitwise: ``active_w + hedge_w`` re-performs the exact
        binary add this tick's ``p_units`` came from (table racks), or
        adds ``0.0`` — a bitwise no-op on the non-negative draws —
        for racks without one (see ``repro.obs.attribution``)."""
        obs = self.obs
        assert obs is not None
        n = len(self.wls)
        ledger = obs.ledger
        if ledger is not None:
            h_f = h_arr.astype(float)
            active_w = np.where(self.has_table, p_act, powered_f * w_req)
            hedge_w = np.where(self.has_table, h_f * w_req, 0.0)
            floor_units = floor_w = None
            if latched_any:
                assert c_low_f is not None and w_low is not None
                ti = self.t_idx
                floor_units = np.zeros(n)
                floor_units[ti] = c_low_f
                floor_w = np.zeros(n)
                floor_w[ti] = w_low[ti]
            ledger.record_fleet_tick(
                t,
                dt,
                fan_w=fan_w,
                active_w=active_w,
                hedge_w=hedge_w,
                rest_w=p_rest,
                hedge_units=h_arr,
                rest_units=self.n_units - powered,
                floor_units=floor_units,
                floor_w=floor_w,
            )
        probes = obs.probes
        if probes is not None and probes.active:
            # shared all-zeros row: never mutated, so sinks may keep a
            # reference across ticks without copying
            zeros = self._obs_zeros
            if zeros is None:
                zeros = self._obs_zeros = np.zeros(n)
            row = {
                "power_w": total,
                "queued": queued.astype(float),
                "active_units": powered.astype(float),
                "waking_units": zeros,
                "utilization": util_agg,
                "opp_index": (
                    np.where(self.has_table, opp_eff, 0).astype(float)
                    if self._any_table
                    else zeros
                ),
                "hedge_units": (
                    h_arr.astype(float) if self._any_hedge else zeros
                ),
            }
            if self.therm is not None and self._temp_rows:
                ti = self.t_idx
                temp = np.full(n, np.nan)
                temp[ti] = self._temp_rows[-1]
                thr = np.zeros(n)
                thr[ti] = self._thr_rows[-1]
                row["max_temp_c"] = temp
                row["throttled_units"] = thr
            probes.emit_tick(t, dt, row)

    def per_rack_telemetry(self) -> List[Telemetry]:
        ts = np.asarray(self._t_hist, float)
        offered = np.stack(self._offered_rows)  # (ticks, racks)
        active = np.stack(self._active_rows)
        power = np.stack(self._power_rows)
        util = np.stack(self._util_rows)
        empty = np.zeros(0)
        if self.therm is not None and self._fan_rows:
            fan = np.stack(self._fan_rows)  # (ticks, thermal racks)
            temp = np.stack(self._temp_rows)
            thr = np.stack(self._thr_rows)
            col_of = {int(r): j for j, r in enumerate(self.t_idx)}
        else:
            fan = temp = thr = None
            col_of = {}
        out = []
        for r in range(len(self.wls)):
            p50, p99 = latency_percentiles(self.responses[r])
            j = col_of.get(r)
            if j is None:
                temp_r = thr_r = fan_r = empty
            else:
                temp_r = temp[:, j].copy()
                thr_r = thr[:, j].astype(float)
                fan_r = fan[:, j].copy()
            out.append(
                Telemetry(
                    time_s=ts,
                    offered_load=offered[:, r].copy(),
                    active_units=active[:, r].astype(float),
                    power_w=power[:, r].copy(),
                    utilization=util[:, r].copy(),
                    served=float(self.served_acc[r]),
                    hedged=int(self.hedged_cnt[r]),
                    scale_events=int(self.scale_events[r]),
                    p50_latency_s=p50,
                    p99_latency_s=p99,
                    energy_j=float(self.energy[r]),
                    unit_energy_j=float(self.unit_energy[r]),
                    responses=list(self.responses[r]),
                    workload=self.wls[r].describe(),
                    max_temp_c=temp_r,
                    throttled_units=thr_r,
                    fan_power_w=fan_r,
                )
            )
        return out


class Fleet:
    """N racks + a router, played against a fleet-level offered load.

    ``dt_s`` is fixed at construction (the per-rack rate windows are
    sized to it). ``play_trace`` advances tick by tick: route the
    tick's offered rps across racks, submit each rack's shard, advance
    every rack's governor/queue/power integral, then keep ticking until
    every queue drains.
    """

    def __init__(
        self,
        racks: Sequence[RackConfig],
        router: Optional[Router] = None,
        dt_s: float = 60.0,
        backend: str = "vector",
        idle_units_off: bool = True,
        sanitize: Optional[bool] = None,
        obs: Optional["FleetObs"] = None,
        chaos: Optional[ChaosSchedule] = None,
        degrade: Optional[DegradePolicy] = None,
    ) -> None:
        assert racks, "need at least one rack"
        self.racks = list(racks)
        self.router = router or JoinShortestQueueRouter()
        self.dt_s = dt_s
        self.backend = backend
        self.engine: Any
        if backend == "scalar":
            self.engine = _ScalarFleetEngine(self.racks, dt_s, idle_units_off)
        elif backend == "vector":
            self.engine = _VectorFleetEngine(self.racks, dt_s, idle_units_off)
        elif backend == "jax":
            # deferred import: jax is optional for the other backends
            from repro.fleet.jax_engine import _JaxFleetEngine

            self.engine = _JaxFleetEngine(
                self.racks, dt_s, idle_units_off, self.router
            )
        else:
            raise ValueError(
                f"unknown fleet backend {backend!r}; "
                "use 'scalar', 'vector', or 'jax'"
            )
        self._capacity = np.array(
            [rc.spec.n_units * rc.unit_rate for rc in self.racks], float
        )
        self._n_units = np.array([rc.spec.n_units for rc in self.racks], np.int64)
        self._jpr = np.array(
            [
                (rc.spec.p_shared + rc.spec.n_units * rc.spec.unit.power(1.0))
                / (rc.spec.n_units * rc.unit_rate)
                for rc in self.racks
            ],
            float,
        )
        self.rack_names = [
            rc.name or f"{rc.spec.name}/{i}" for i, rc in enumerate(self.racks)
        ]
        self.chaos = chaos
        self._lowered: Optional[LoweredChaos] = None
        self.chaos_monitor: Optional[ChaosMonitor] = None
        if chaos is not None:
            self._lowered = chaos.lower([int(u) for u in self._n_units])
            if hasattr(self.engine, "set_chaos"):
                # jax: lowered once into per-tick mask rows, scanned
                self.engine.set_chaos(self._lowered)
            else:
                self.engine.chaos_on_kill = self._lowered.on_kill
            # a rack that misses two tick heartbeats is declared failed
            self.chaos_monitor = ChaosMonitor(
                self.n_racks, timeout_s=2.0 * dt_s
            )
        self.degrade = degrade
        self._degrade_lowered: Optional[LoweredDegrade] = None
        self._degrade_driver: Optional[DegradeDriver] = None
        self._tier_payloads: List[Optional[str]] = []
        if degrade is not None:
            low = degrade.lower([int(u) for u in self._n_units], dt_s)
            self._degrade_lowered = low
            # tier payloads tag each sub-request; the trailing None slot
            # is untiered chaos respill (bypasses admission)
            self._tier_payloads = [t.name for t in low.tiers] + [None]
            if hasattr(self.engine, "set_degrade"):
                # jax: lowered to branchless per-tick rows in the scan
                self.engine.set_degrade(low)
            else:
                # ONE driver instance serves whichever host engine runs,
                # so scalar and vector degradation decisions are the
                # same Python objects (bitwise parity by construction)
                self._degrade_driver = DegradeDriver(low)
        # cumulative per-tick driver history (grows across play_trace calls,
        # in lockstep with the engines' own cumulative state)
        self._offered: List[float] = []
        self._assigned: List[np.ndarray] = []
        self._queued_rows: List[np.ndarray] = []
        self._wall_s = 0.0
        self._drained = True
        self.obs = obs
        if obs is not None:
            self._wire_obs(obs)
        from repro.runtime.sanitize import (attach_fleet_sanitizer,
                                            resolve_sanitize)
        if resolve_sanitize(sanitize):
            attach_fleet_sanitizer(self)

    def _wire_obs(self, obs: "FleetObs") -> None:
        """Bind the observability config into whichever engine runs.

        The scalar engine's ledger surface is each rack's own
        ``UnitPool.charge`` (pool-side leaves, per tenant); the vector
        engine records per-rack arrays per tick; the jax engine stays
        pure inside ``lax.scan`` and its rows are expanded host-side
        after each ``play`` (``_obs_expand_jax``)."""
        if obs.probes is not None:
            obs.probes.bind(self.rack_names)
        self.engine.obs = obs
        ledger = obs.ledger
        if ledger is None:
            return
        if self.backend == "scalar":
            for name, rt in zip(self.rack_names, self.engine.rts):
                rt.pool.attach_ledger(ledger, rack=name)
        elif self.backend == "vector":
            ledger.register_fleet(self.rack_names, self.engine.p_shared)
        else:
            # jax: the scan reorders/fuses float ops, so the replay is
            # promised within the engines' documented parity tolerance
            # (the fig16 gate), not bitwise
            ledger.tolerance = 1e-9
            ledger.register_fleet(
                self.rack_names, self.engine.arrays.p_shared
            )

    @property
    def n_racks(self) -> int:
        return len(self.racks)

    @property
    def capacity_rps(self) -> float:
        """Aggregate peak service rate of the fleet."""
        return float(self._capacity.sum())  # reprolint: ok[RPL001] roll-up-only fleet metric; never enters the bitwise-compared telemetry

    def view(self) -> FleetView:
        capacity = self._capacity
        alive = None
        if self._lowered is not None:
            # routers see the degraded fleet: killed units shrink a
            # rack's advertised capacity, a fully dead rack is excluded
            # outright (alive mask). With no live fault both fields are
            # bitwise the no-chaos view.
            dead = getattr(self.engine, "chaos_dead", None)
            if dead is not None and dead.any():
                live = (self._n_units - dead).astype(float)
                capacity = self._capacity * (
                    live / self._n_units.astype(float)
                )
                alive = dead < self._n_units
        return FleetView(
            t=self.engine.now,
            dt_s=self.dt_s,
            capacity_rps=capacity,
            queued_cost=self.engine.queued_cost(),
            active_units=self.engine.active_units(),
            n_units=self._n_units,
            full_load_j_per_req=self._jpr,
            alive=alive,
        )

    def _chaos_step(self) -> float:
        """Apply the schedule's masks at the engine clock's current
        tick; returns the respill *rate* (rps) to fold into this tick's
        routed total (0.0 unless a full-rack kill edge fired under
        ``on_kill="respill"``)."""
        assert self._lowered is not None
        dead, fan, cap = self._lowered.masks_at(self.engine.now)
        if self.chaos_monitor is not None:
            self.chaos_monitor.observe(self.engine.now, dead, self._n_units)
        return self.engine.apply_chaos(dead, fan, cap) / self.dt_s

    def _degrade_pre(
        self, rps: float, respill_rps: float
    ) -> Tuple[float, Optional[List[Tuple[Optional[str], float]]], FleetView]:
        """One tick of the degradation control plane (host engines):
        deadline expiry, then breaker/retry/admission in the shared
        :class:`DegradeDriver`, then the breaker-scaled router view.
        Returns ``(routed_total_rps, tier_split, view)``."""
        drv = self._degrade_driver
        low = self._degrade_lowered
        assert drv is not None and low is not None
        deadline = low.policy.queue_deadline_s
        if deadline is not None:
            self.engine.expire(deadline)
        view = self.view()  # chaos-degraded capacity, post-expiry queue
        total, frac = drv.pre_route(
            len(self._offered),
            rps,
            respill_rps,
            view.queued_cost,
            view.capacity_rps,
            self.engine.chaos_dead,
        )
        split = None
        if frac is not None:
            split = list(zip(self._tier_payloads, frac.tolist()))
        if low.breaker_on:
            view = view.scaled(drv.breaker_scale())
        return total, split, view

    def play_trace(
        self, trace_rps: Sequence[float], drain: bool = True
    ) -> FleetTelemetry:
        """Route and serve ``trace_rps`` tick by tick, then keep ticking
        until every rack's queue drains (bounded by a 10x-trace-length
        safety cap; if backlog still remains — a sustained-overload
        trace — the returned telemetry carries ``drained=False`` and its
        latency percentiles cover completed requests only). The
        telemetry always covers the fleet's *entire* history — calling
        ``play_trace`` again continues the same simulation (clock,
        queues, energy) and returns the cumulative roll-up, mirroring
        the engines' own cumulative state."""
        dt = self.dt_s
        trace = np.asarray(trace_rps, float)
        t0 = time.perf_counter()
        if hasattr(self.engine, "play"):
            # jax engine: routing happens in-scan, the whole trace plus
            # drain runs as one jitted program
            assigned, queued_rows, n_drain, jdrained = self.engine.play(
                trace, drain=drain
            )
            # chaos respill re-entered the in-scan routed total; mirror
            # it into the driver's offered series (the scalar/vector
            # loops add _chaos_step's respill rate before routing)
            extra = None
            n_new = len(trace) + n_drain
            if (
                self._lowered is not None
                and self._lowered.on_kill == "respill"
                and n_new > 0
            ):
                ev = self.engine._full("evac")
                if ev.shape[0] >= n_new:
                    extra = ev[-n_new:].sum(axis=1) / dt  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
            # with degradation on the offered series is the *admitted*
            # total the scan actually routed (post-shed, plus released
            # retries and respill) — the same total the host drivers
            # append after DegradeDriver.pre_route
            adm = None
            if self._degrade_lowered is not None and n_new > 0:
                rows = self.engine._full("dg_admitted")
                if rows.shape[0] >= n_new:
                    adm = rows[-n_new:]
            for i, rps in enumerate(trace):
                if adm is not None:
                    off = float(adm[i])
                else:
                    off = float(rps)
                    if extra is not None:
                        off += float(extra[i])
                self._offered.append(off)
                self._assigned.append(np.asarray(assigned[i], float))
            for j in range(n_drain):
                if adm is not None:
                    off = float(adm[len(trace) + j])
                else:
                    off = 0.0
                    if extra is not None:
                        off += float(extra[len(trace) + j])
                self._offered.append(off)
                self._assigned.append(
                    np.asarray(assigned[len(trace) + j], float)
                )
            if self.chaos_monitor is not None and n_new > 0:
                # replay the tick heartbeats the in-scan run could not
                # deliver live (tick-deterministic, same masks)
                assert self._lowered is not None
                for t in np.asarray(self.engine._t_hist, float)[-n_new:]:
                    d, _, _ = self._lowered.masks_at(float(t))
                    self.chaos_monitor.observe(float(t), d, self._n_units)
            for row in queued_rows:
                self._queued_rows.append(np.asarray(row, np.int64))
            if jdrained is not None:
                self._drained = bool(jdrained)
            n_rows = len(trace) + n_drain
            if self.obs is not None and n_rows > 0:
                self._obs_expand_jax(n_rows)
            self._wall_s += time.perf_counter() - t0
            return self._build_telemetry()
        zero = np.zeros(self.n_racks)
        queued = conc = None
        lowered = self._lowered
        drv = self._degrade_driver
        for rps in trace:
            respill = self._chaos_step() if lowered is not None else 0.0
            if drv is not None:
                total, split, view = self._degrade_pre(float(rps), respill)
            else:
                total, split, view = float(rps) + respill, None, self.view()
            assign = np.asarray(self.router.route(total, view), float)
            self._offered.append(total)
            self._assigned.append(assign)
            queued, conc = self.engine.tick(assign, dt, tier_split=split)
            self._queued_rows.append(queued)
        if drain:
            for _ in range(10 * len(trace) + 100):
                respill = self._chaos_step() if lowered is not None else 0.0
                if drv is not None:
                    # released retry mass re-enters during drain, routed
                    # like any offered load
                    total, split, view = self._degrade_pre(0.0, respill)
                else:
                    total, split, view = respill, None, None
                if total > 0.0:
                    # a kill edge during drain respills the dead rack's
                    # backlog through the router like any offered load
                    assign = np.asarray(
                        self.router.route(
                            total, view if view is not None else self.view()
                        ),
                        float,
                    )
                else:
                    assign = zero
                self._offered.append(total)
                self._assigned.append(assign)
                queued, conc = self.engine.tick(assign, dt, tier_split=split)
                self._queued_rows.append(queued)
                ring = drv.ring_mass() if drv is not None else 0.0
                if (
                    int(queued.sum()) == 0 and int(conc.sum()) == 0  # reprolint: ok[RPL001] zero-test only: sum()==0 iff all elements are 0, order-free
                    and ring <= 0.0
                ):
                    break
        if queued is not None:
            self._drained = (
                int(queued.sum()) == 0 and int(conc.sum()) == 0  # reprolint: ok[RPL001] zero-test only: sum()==0 iff all elements are 0, order-free
                and (drv is None or drv.ring_mass() <= 0.0)
            )
        self._wall_s += time.perf_counter() - t0
        return self._build_telemetry()

    # ------------------------------------------------------------------
    def _obs_expand_jax(self, n_rows: int) -> None:
        """Expand the jax engine's scanned per-tick rows (the last
        ``n_rows`` of its cumulative history) into the obs surfaces.
        The jitted scan stays pure — it only emits the extra arrays
        (``opp``, ``w_req``, thermal floor counts) when obs is attached
        — and this host loop mirrors the vector engine's per-tick
        emission, so ledger causes and probe rows match the other
        backends (ledger replay within ``ledger.tolerance``)."""
        obs = self.obs
        assert obs is not None
        eng = self.engine
        arr = eng.arrays
        dt = eng.dt_s
        n = eng.n_racks
        ts = np.asarray(eng._t_hist, float)[-n_rows:]
        power = eng._full("power")[-n_rows:]
        active = eng._full("active")[-n_rows:]
        util = eng._full("util")[-n_rows:]
        hedge = eng._full("hedge")[-n_rows:]
        opp_rows = eng._full("opp")[-n_rows:]
        queued = np.stack(self._queued_rows[-n_rows:])
        thermal = arr.thermal is not None and "temp" in eng._hist
        if thermal:
            t_idx = arr.thermal.t_idx
            temp_rows = np.concatenate(eng._hist["temp"])[-n_rows:]
            thr_rows = np.concatenate(eng._hist["thr"])[-n_rows:]
            fan_rows = np.concatenate(eng._hist["fan"])[-n_rows:]
            c_low_rows = np.concatenate(eng._hist["c_low"])[-n_rows:]
            w_low_rows = np.concatenate(eng._hist["w_low"])[-n_rows:]
        ledger = obs.ledger
        if ledger is not None:
            w_req_rows = eng._full("w_req")[-n_rows:]
            has_table = arr.has_table
            n_units = arr.n_units
            p_base = arr.p_base
            for i in range(n_rows):
                h_i = hedge[i].astype(np.int64)
                pw_cnt = active[i].astype(np.int64)
                k_f = (pw_cnt - h_i).astype(float)
                w_req = w_req_rows[i]
                p_act = k_f * w_req
                fan_w = np.zeros(n)
                floor_units = floor_w = None
                if thermal:
                    c_low = c_low_rows[i]
                    w_low = w_low_rows[i]
                    floor_all = (opp_rows[i][t_idx] == 0) & (c_low > 0)
                    mixed = (
                        c_low * w_low[t_idx]
                        + (k_f[t_idx] - c_low) * w_req[t_idx]
                    )
                    p_act[t_idx] = np.where(
                        floor_all, k_f[t_idx] * w_low[t_idx], mixed
                    )
                    fan_w[t_idx] = fan_rows[i]
                    floor_units = np.zeros(n)
                    floor_units[t_idx] = c_low
                    floor_w = np.zeros(n)
                    floor_w[t_idx] = w_low[t_idx]
                ledger.record_fleet_tick(
                    float(ts[i]),
                    dt,
                    fan_w=fan_w,
                    active_w=np.where(
                        has_table, p_act, pw_cnt.astype(float) * w_req
                    ),
                    hedge_w=np.where(
                        has_table, h_i.astype(float) * w_req, 0.0
                    ),
                    rest_w=(n_units - pw_cnt).astype(float) * p_base,
                    hedge_units=h_i,
                    rest_units=n_units - pw_cnt,
                    floor_units=floor_units,
                    floor_w=floor_w,
                )
        probes = obs.probes
        if probes is not None and probes.active:
            for i in range(n_rows):
                row = {
                    "power_w": power[i].copy(),
                    "queued": queued[i].astype(float),
                    "active_units": active[i].astype(float),
                    "waking_units": np.zeros(n),
                    "utilization": util[i].copy(),
                    "opp_index": np.where(
                        arr.has_table, opp_rows[i], 0
                    ).astype(float),
                    "hedge_units": hedge[i].astype(float),
                }
                if thermal:
                    temp = np.full(n, np.nan)
                    temp[t_idx] = temp_rows[i]
                    thr = np.zeros(n)
                    thr[t_idx] = thr_rows[i]
                    row["max_temp_c"] = temp
                    row["throttled_units"] = thr
                probes.emit_tick(float(ts[i]), dt, row)

    # ------------------------------------------------------------------
    def _build_telemetry(self) -> FleetTelemetry:
        offered = self._offered
        assigned = self._assigned
        queued_rows = self._queued_rows
        wall = self._wall_s
        per_rack = self.engine.per_rack_telemetry()
        power = np.stack([tel.power_w for tel in per_rack])  # (R, T)
        active = np.stack([tel.active_units for tel in per_rack])
        lats = np.array([r.latency_s for tel in per_rack for r in tel.responses])
        if len(lats):
            p50, p95, p99 = (float(np.percentile(lats, q)) for q in (50, 95, 99))
        else:
            p50 = p95 = p99 = 0.0
        tel = FleetTelemetry(
            time_s=per_rack[0].time_s,
            offered_rps=np.asarray(offered, float),
            assigned_rps=np.stack(assigned).T,
            active_units=active,
            power_w=power,
            queued=np.stack(queued_rows).T,
            served=sum(tel.served for tel in per_rack),
            energy_j=sum(tel.energy_j for tel in per_rack),
            p50_latency_s=p50,
            p95_latency_s=p95,
            p99_latency_s=p99,
            per_rack=per_rack,
            rack_names=list(self.rack_names),
            router=getattr(self.router, "name", type(self.router).__name__),
            backend=self.backend,
            wall_s=wall,
            drained=self._drained,
        )
        if self.chaos is not None:
            eng = self.engine
            tel.chaos_events = [e.to_record() for e in self.chaos.events]
            tel.dropped_requests = int(getattr(eng, "chaos_dropped", 0))
            tel.dropped_cost = float(getattr(eng, "chaos_dropped_cost", 0.0))
            tel.respilled_requests = int(getattr(eng, "chaos_respilled", 0))
            tel.respilled_cost = float(
                getattr(eng, "chaos_respilled_cost", 0.0)
            )
            fault_t = self.chaos.fault_t
            if math.isfinite(fault_t):
                tel.recovery = recovery_report(
                    tel,
                    fault_t,
                    dropped_requests=tel.dropped_requests,
                    dropped_cost=tel.dropped_cost,
                    respilled_requests=tel.respilled_requests,
                    respilled_cost=tel.respilled_cost,
                )
        if self.degrade is not None:
            eng = self.engine
            # host backends read the shared driver; the jax engine
            # mirrors the same attribute surface host-side after play
            src: Any = self._degrade_driver if (
                self._degrade_driver is not None) else eng
            tel.degrade_on = True
            tel.shed_cost = float(getattr(src, "shed_cost", 0.0))
            shed_by_tier = np.asarray(
                getattr(src, "shed_by_tier", np.zeros(0)), float)
            tel.shed_by_tier = {
                t.name: float(shed_by_tier[k])
                for k, t in enumerate(self.degrade.tiers)
                if k < len(shed_by_tier)
            }
            tel.shed_cost_t = np.asarray(
                getattr(src, "shed_cost_t", []), float)
            tel.expired_requests = int(getattr(eng, "degrade_expired", 0))
            tel.expired_cost = float(
                getattr(eng, "degrade_expired_cost", 0.0))
            tel.retried_cost = float(getattr(src, "retried_cost", 0.0))
            tel.retry_dropped_cost = float(
                getattr(src, "retry_dropped_cost", 0.0))
            tel.breaker_opens = int(getattr(src, "breaker_opens", 0))
            rows = getattr(src, "breaker_state_t", [])
            bt = (
                np.stack([np.asarray(r, np.int64) for r in rows]).T
                if len(rows)
                else np.zeros((self.n_racks, 0), np.int64)
            )
            tel.breaker_state_t = bt
            # derive open/half/close instants from the state matrix —
            # one shared code path for every backend (trace + summary)
            events: List[dict] = []
            ts = tel.time_s
            for r in range(bt.shape[0]):
                prev = BRK_CLOSED
                for i in range(bt.shape[1]):
                    s = int(bt[r, i])
                    if s != prev:
                        t_ev = (
                            float(ts[i]) if i < len(ts)
                            else i * self.dt_s
                        )
                        events.append({
                            "rack": self.rack_names[r],
                            "t_s": t_ev,
                            "state": s,
                            "prev": prev,
                        })
                    prev = s
            tel.breaker_events = events
        if self.obs is not None and self.obs.slo is not None:
            # evaluate() resets rule state first, so rebuilding telemetry
            # (cumulative across play_trace calls) stays idempotent
            tel.alerts = self.obs.slo.evaluate(tel)
        return tel
