"""``Fleet`` — N racks behind a geo-routed load balancer.

The paper prototypes one 60-SoC rack; public edge platforms aggregate
hundreds of such sites behind request routers. A :class:`Fleet` holds N
racks (mixed :class:`~repro.core.cluster.ClusterSpec`\\ s allowed), a
:class:`~repro.fleet.router.Router` that shards the fleet-level offered
load across racks each tick, and per-rack elastic unit governors — the
same activation policy the single-rack runtime uses, applied one level
up.

Two engines implement the same simulation:

  * ``backend="scalar"`` — one full per-unit
    :class:`~repro.runtime.ClusterRuntime` per rack (the reference:
    every unit is an object, every tick walks every rack's pool);
  * ``backend="vector"`` — rack state stacked into numpy arrays
    (activation targets, cooldown timers, and the closed-form
    binary-gating power integral computed elementwise across all racks
    at once), with per-rack fluid FIFO queues kept for exact request
    latencies.

The vector engine replicates the scalar engine's arithmetic operation
for operation, so the two produce **bitwise-identical** telemetry while
the vector engine runs an order of magnitude faster — fast enough to
sweep 100 racks x 24 simulated hours in seconds
(``benchmarks/fig16_fleet.py``). The vector engine covers the
binary-gating power model (no per-rack ``freq_governor`` /
``hedge_after_s``); configurations that need the DVFS or hedging paths
run under ``backend="scalar"``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.fleet.router import FleetView, JoinShortestQueueRouter, Router
from repro.fleet.telemetry import FleetTelemetry
from repro.runtime import (
    ClusterRuntime,
    QueueWorkload,
    Request,
    ScalePolicy,
    Telemetry,
    latency_percentiles,
)

__all__ = ["RackConfig", "Fleet", "homogeneous_fleet"]


@dataclass
class RackConfig:
    """One rack's binding into the fleet."""

    spec: ClusterSpec
    unit_rate: float  # requests/s one unit sustains
    policy: Optional[ScalePolicy] = None
    name: str = ""


def homogeneous_fleet(
    spec: ClusterSpec,
    n_racks: int,
    unit_rate: float,
    policy: Optional[ScalePolicy] = None,
) -> List[RackConfig]:
    """N identical racks (the common case for a single-platform fleet)."""
    return [
        RackConfig(spec, unit_rate, policy, name=f"{spec.name}/{i}")
        for i in range(n_racks)
    ]


class _ScalarFleetEngine:
    """Reference engine: one per-unit ClusterRuntime per rack."""

    backend = "scalar"

    def __init__(
        self,
        racks: Sequence[RackConfig],
        dt_s: float,
        idle_units_off: bool,
    ):
        self.dt_s = dt_s
        self.now = 0.0
        self.rts: List[ClusterRuntime] = []
        for i, rc in enumerate(racks):
            wl = QueueWorkload(rc.unit_rate, name=rc.name or f"rack{i}")
            self.rts.append(
                ClusterRuntime(
                    rc.spec,
                    wl,
                    policy=rc.policy,
                    window_s=dt_s,
                    dt_s=dt_s,
                    idle_units_off=idle_units_off,
                    backend="scalar",
                )
            )

    def queued_cost(self) -> np.ndarray:
        return np.array([rt.workload.pending_cost for rt in self.rts], float)

    def active_units(self) -> np.ndarray:
        return np.array([rt.active_units for rt in self.rts], np.int64)

    def tick(self, assign_rps, dt) -> Tuple[np.ndarray, np.ndarray]:
        t = self.now
        for r, rt in enumerate(self.rts):
            work = float(assign_rps[r]) * dt
            if work > 0:
                rt.submit(
                    count=work,
                    request=Request(cost=work, arrival_s=t + 0.5 * dt),
                )
        n = len(self.rts)
        queued = np.zeros(n, np.int64)
        conc = np.zeros(n, np.int64)
        for r, rt in enumerate(self.rts):
            stats = rt.tick(dt)
            queued[r] = stats.queued
            conc[r] = stats.concurrency
        self.now = t + dt
        return queued, conc

    def per_rack_telemetry(self) -> List[Telemetry]:
        return [rt.cluster_telemetry() for rt in self.rts]


class _VectorFleetEngine:
    """Stacked engine: rack state as arrays, one numpy pass per tick.

    Every floating-point expression mirrors the scalar engine's
    operation order exactly (``UnitGovernor.target_units``,
    ``UnitPool.charge``'s binary-gating branch, and the windowed rate
    estimate collapse to closed forms when ``window_s == dt_s`` and
    group size is 1), so per-rack telemetry is bitwise-identical to the
    scalar engine's. The fluid FIFO queues stay as per-rack
    :class:`QueueWorkload` objects — both engines share that code, so
    request latencies match by construction.
    """

    backend = "vector"

    def __init__(
        self,
        racks: Sequence[RackConfig],
        dt_s: float,
        idle_units_off: bool,
    ):
        for rc in racks:
            pol = rc.policy
            if pol is not None and (
                pol.freq_governor is not None or pol.hedge_after_s is not None
            ):
                raise ValueError(
                    "the vector fleet engine models binary per-unit "
                    "gating only (no freq_governor / hedge_after_s); "
                    "use Fleet(backend='scalar') for those policies"
                )
        self.dt_s = dt_s
        self.now = 0.0
        pols = [rc.policy or ScalePolicy() for rc in racks]
        units = [rc.spec.unit for rc in racks]
        self.n_units = np.array([rc.spec.n_units for rc in racks], np.int64)
        self.unit_rate = np.array([rc.unit_rate for rc in racks], float)
        self.headroom = np.array([p.headroom for p in pols], float)
        self.min_units = np.array([p.min_units for p in pols], np.int64)
        self.minq = np.maximum(1, np.minimum(self.min_units, self.n_units))
        self.cooldown = np.array([p.cooldown_s for p in pols], float)
        self.p_shared = np.array([rc.spec.p_shared for rc in racks], float)
        self.p_idle = np.array([u.p_idle for u in units], float)
        self.p_peak = np.array([u.p_peak for u in units], float)
        self.gamma = np.array([u.gamma for u in units], float)
        self.p_base = np.array(
            [u.p_off if idle_units_off else u.p_idle for u in units],
            float,
        )
        self.wls = [
            QueueWorkload(rc.unit_rate, name=rc.name or f"rack{i}")
            for i, rc in enumerate(racks)
        ]
        n = len(racks)
        self.active = self.minq.copy()
        self.last_down = np.full(n, -1e9)
        self.scale_events = np.zeros(n, np.int64)
        self.energy = np.zeros(n)
        self.unit_energy = np.zeros(n)
        self.served_acc = np.zeros(n)
        self.responses: List[list] = [[] for _ in range(n)]
        self._t_hist: List[float] = []
        self._offered_rows: List[np.ndarray] = []
        self._active_rows: List[np.ndarray] = []
        self._power_rows: List[np.ndarray] = []
        self._util_rows: List[np.ndarray] = []

    def queued_cost(self) -> np.ndarray:
        return np.array([wl.pending_cost for wl in self.wls], float)

    def active_units(self) -> np.ndarray:
        return self.active.copy()

    def tick(self, assign_rps, dt) -> Tuple[np.ndarray, np.ndarray]:
        t = self.now
        work = assign_rps * dt
        for r in np.nonzero(work > 0)[0]:
            req = Request(cost=float(work[r]), arrival_s=t + 0.5 * dt)
            self.wls[r].submit(req)
        # windowed rate estimate with window == dt: this tick's work
        rate = work / dt
        # UnitGovernor.target_units with perf_scale == 1.0, group == 1
        need = rate * self.headroom / (self.unit_rate * 1.0)
        raw = np.minimum(self.n_units, np.maximum(self.min_units, np.ceil(need)))
        tgt = np.maximum(1, raw.astype(np.int64))
        # UnitGovernor.apply_target: immediate scale-up, cooldown-gated
        # scale-down to max(min floor, target)
        active = self.active
        up = tgt > active
        keep = np.maximum(self.minq, tgt)
        in_cooldown = t - self.last_down > self.cooldown
        down = (tgt < active) & in_cooldown & (keep < active)
        new_active = np.where(up, tgt, np.where(down, keep, active))
        self.scale_events += up
        self.scale_events += down
        self.last_down = np.where(down, t, self.last_down)
        self.active = new_active
        # fluid FIFO drain per rack (QueueWorkload.step_fast — the
        # allocation-light twin of step(), identical arithmetic)
        n = len(self.wls)
        acts = new_active.tolist()
        utils_l: List[float] = []
        served_l: List[float] = []
        queued_l: List[int] = []
        conc_l: List[int] = []
        for r in range(n):
            wl = self.wls[r]
            used, util, q, c = wl.step_fast(acts[r], dt, t)
            utils_l.append(util)
            served_l.append(used)
            queued_l.append(q)
            conc_l.append(c)
            if wl._completed:
                self.responses[r].extend(wl.drain())
        utils = np.asarray(utils_l, float)
        served = np.asarray(served_l, float)
        queued = np.asarray(queued_l, np.int64)
        conc = np.asarray(conc_l, np.int64)
        # UnitPool.charge, binary-gating branch, elementwise per rack
        u = np.minimum(np.maximum(utils, 0.0), 1.0)
        af = new_active.astype(float)
        p_units = 0.0 + af * (
            self.p_idle + (self.p_peak - self.p_idle) * u**self.gamma
        )
        p_rest = (self.n_units - new_active).astype(float) * self.p_base
        total = self.p_shared + 0.0 + p_units + p_rest
        self.energy += total * dt
        self.unit_energy += p_units * dt
        self.served_acc += served
        util_agg = np.divide(af * u, af, out=np.zeros(n), where=af > 0)
        self._t_hist.append(t)
        self._offered_rows.append(rate)
        self._active_rows.append(new_active)
        self._power_rows.append(total)
        self._util_rows.append(util_agg)
        self.now = t + dt
        return queued, conc

    def per_rack_telemetry(self) -> List[Telemetry]:
        ts = np.asarray(self._t_hist, float)
        offered = np.stack(self._offered_rows)  # (ticks, racks)
        active = np.stack(self._active_rows)
        power = np.stack(self._power_rows)
        util = np.stack(self._util_rows)
        out = []
        for r in range(len(self.wls)):
            p50, p99 = latency_percentiles(self.responses[r])
            out.append(
                Telemetry(
                    time_s=ts,
                    offered_load=offered[:, r].copy(),
                    active_units=active[:, r].astype(float),
                    power_w=power[:, r].copy(),
                    utilization=util[:, r].copy(),
                    served=float(self.served_acc[r]),
                    scale_events=int(self.scale_events[r]),
                    p50_latency_s=p50,
                    p99_latency_s=p99,
                    energy_j=float(self.energy[r]),
                    unit_energy_j=float(self.unit_energy[r]),
                    responses=list(self.responses[r]),
                    workload=self.wls[r].describe(),
                )
            )
        return out


class Fleet:
    """N racks + a router, played against a fleet-level offered load.

    ``dt_s`` is fixed at construction (the per-rack rate windows are
    sized to it). ``play_trace`` advances tick by tick: route the
    tick's offered rps across racks, submit each rack's shard, advance
    every rack's governor/queue/power integral, then keep ticking until
    every queue drains.
    """

    def __init__(
        self,
        racks: Sequence[RackConfig],
        router: Optional[Router] = None,
        dt_s: float = 60.0,
        backend: str = "vector",
        idle_units_off: bool = True,
    ):
        assert racks, "need at least one rack"
        self.racks = list(racks)
        self.router = router or JoinShortestQueueRouter()
        self.dt_s = dt_s
        self.backend = backend
        if backend == "scalar":
            self.engine = _ScalarFleetEngine(self.racks, dt_s, idle_units_off)
        elif backend == "vector":
            self.engine = _VectorFleetEngine(self.racks, dt_s, idle_units_off)
        else:
            raise ValueError(
                f"unknown fleet backend {backend!r}; "
                "use 'scalar' or 'vector'"
            )
        self._capacity = np.array(
            [rc.spec.n_units * rc.unit_rate for rc in self.racks], float
        )
        self._n_units = np.array([rc.spec.n_units for rc in self.racks], np.int64)
        self._jpr = np.array(
            [
                (rc.spec.p_shared + rc.spec.n_units * rc.spec.unit.power(1.0))
                / (rc.spec.n_units * rc.unit_rate)
                for rc in self.racks
            ],
            float,
        )
        self.rack_names = [
            rc.name or f"{rc.spec.name}/{i}" for i, rc in enumerate(self.racks)
        ]
        # cumulative per-tick driver history (grows across play_trace calls,
        # in lockstep with the engines' own cumulative state)
        self._offered: List[float] = []
        self._assigned: List[np.ndarray] = []
        self._queued_rows: List[np.ndarray] = []
        self._wall_s = 0.0
        self._drained = True

    @property
    def n_racks(self) -> int:
        return len(self.racks)

    @property
    def capacity_rps(self) -> float:
        """Aggregate peak service rate of the fleet."""
        return float(self._capacity.sum())

    def view(self) -> FleetView:
        return FleetView(
            t=self.engine.now,
            dt_s=self.dt_s,
            capacity_rps=self._capacity,
            queued_cost=self.engine.queued_cost(),
            active_units=self.engine.active_units(),
            n_units=self._n_units,
            full_load_j_per_req=self._jpr,
        )

    def play_trace(
        self, trace_rps: Sequence[float], drain: bool = True
    ) -> FleetTelemetry:
        """Route and serve ``trace_rps`` tick by tick, then keep ticking
        until every rack's queue drains (bounded by a 10x-trace-length
        safety cap; if backlog still remains — a sustained-overload
        trace — the returned telemetry carries ``drained=False`` and its
        latency percentiles cover completed requests only). The
        telemetry always covers the fleet's *entire* history — calling
        ``play_trace`` again continues the same simulation (clock,
        queues, energy) and returns the cumulative roll-up, mirroring
        the engines' own cumulative state."""
        dt = self.dt_s
        trace = np.asarray(trace_rps, float)
        t0 = time.perf_counter()
        zero = np.zeros(self.n_racks)
        queued = conc = None
        for rps in trace:
            assign = np.asarray(self.router.route(float(rps), self.view()), float)
            self._offered.append(float(rps))
            self._assigned.append(assign)
            queued, conc = self.engine.tick(assign, dt)
            self._queued_rows.append(queued)
        if drain:
            for _ in range(10 * len(trace) + 100):
                self._offered.append(0.0)
                self._assigned.append(zero)
                queued, conc = self.engine.tick(zero, dt)
                self._queued_rows.append(queued)
                if int(queued.sum()) == 0 and int(conc.sum()) == 0:
                    break
        if queued is not None:
            self._drained = (
                int(queued.sum()) == 0 and int(conc.sum()) == 0
            )
        self._wall_s += time.perf_counter() - t0
        return self._build_telemetry()

    # ------------------------------------------------------------------
    def _build_telemetry(self) -> FleetTelemetry:
        offered = self._offered
        assigned = self._assigned
        queued_rows = self._queued_rows
        wall = self._wall_s
        per_rack = self.engine.per_rack_telemetry()
        power = np.stack([tel.power_w for tel in per_rack])  # (R, T)
        active = np.stack([tel.active_units for tel in per_rack])
        lats = np.array([r.latency_s for tel in per_rack for r in tel.responses])
        if len(lats):
            p50, p95, p99 = (float(np.percentile(lats, q)) for q in (50, 95, 99))
        else:
            p50 = p95 = p99 = 0.0
        return FleetTelemetry(
            time_s=per_rack[0].time_s,
            offered_rps=np.asarray(offered, float),
            assigned_rps=np.stack(assigned).T,
            active_units=active,
            power_w=power,
            queued=np.stack(queued_rows).T,
            served=sum(tel.served for tel in per_rack),
            energy_j=sum(tel.energy_j for tel in per_rack),
            p50_latency_s=p50,
            p95_latency_s=p95,
            p99_latency_s=p99,
            per_rack=per_rack,
            rack_names=list(self.rack_names),
            router=getattr(self.router, "name", type(self.router).__name__),
            backend=self.backend,
            wall_s=wall,
            drained=self._drained,
        )
