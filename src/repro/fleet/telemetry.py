"""Fleet-level telemetry roll-ups.

:class:`FleetTelemetry` aggregates per-rack
:class:`~repro.runtime.result.Telemetry` into the fleet view the paper's
claims are made at — total power tracking total offered load — and
feeds the existing energy/TCO models: :meth:`energy_report` produces a
:class:`repro.core.energy.EnergyReport` and
:meth:`monthly_electricity_usd` prices the run with the
``repro.core.tco`` constants (EIA rate x PUE).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, List, Optional

import numpy as np

from repro.core.energy import EnergyReport
from repro.core.tco import ELECTRICITY_USD_PER_KWH, PUE_EDGE
from repro.runtime.result import Telemetry

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.fleet.chaos import RecoveryReport

__all__ = ["FleetTelemetry", "empirical_proportionality"]


def empirical_proportionality(offered: np.ndarray, power_w: np.ndarray) -> float:
    """1 - mean |P/P_max - load/load_max| over a run's per-tick series —
    the trace-driven analogue of
    :func:`repro.core.energy.proportionality_index` (which scores the
    *model* curve; this scores what a run actually did)."""
    offered = np.asarray(offered, float)
    power_w = np.asarray(power_w, float)
    if len(offered) == 0 or power_w.max() <= 0 or offered.max() <= 0:
        return 0.0
    load = offered / offered.max()
    p = power_w / power_w.max()
    return float(1.0 - np.mean(np.abs(p - load)))  # reprolint: ok[RPL001] post-hoc analysis metric over finished telemetry; not part of the bitwise parity surface


@dataclass
class FleetTelemetry:
    """One fleet run: per-rack series plus fleet roll-ups."""

    time_s: np.ndarray  # (ticks,)
    offered_rps: np.ndarray  # (ticks,) fleet offered load
    assigned_rps: np.ndarray  # (racks, ticks) router shards
    active_units: np.ndarray  # (racks, ticks)
    power_w: np.ndarray  # (racks, ticks) rack power incl. shared rail
    queued: np.ndarray  # (racks, ticks) requests waiting after the tick
    served: float
    energy_j: float
    p50_latency_s: float
    p95_latency_s: float
    p99_latency_s: float
    per_rack: List[Telemetry] = field(default_factory=list)
    rack_names: List[str] = field(default_factory=list)
    router: str = ""
    backend: str = "scalar"
    wall_s: float = 0.0
    # False when the post-trace drain hit its safety cap with backlog
    # still queued (sustained overload): served < offered and the
    # latency percentiles cover completed requests only.
    drained: bool = True
    # SLO alert windows (repro.obs.slo.Alert), filled by Fleet when an
    # obs config with an slo policy is attached; empty otherwise
    alerts: List[Any] = field(default_factory=list)
    # chaos (filled by Fleet when a ChaosSchedule is wired; defaults
    # otherwise): the schedule's event records, the full-rack-kill queue
    # accounting (drop/respill per ChaosSchedule.on_kill), and the
    # post-hoc recovery metrics (repro.fleet.chaos.recovery_report)
    chaos_events: List[Dict[str, Any]] = field(default_factory=list)
    dropped_requests: int = 0
    dropped_cost: float = 0.0
    respilled_requests: int = 0
    respilled_cost: float = 0.0
    recovery: Optional["RecoveryReport"] = None
    # graceful degradation (filled by Fleet when a DegradePolicy is
    # wired; defaults otherwise). Conservation with degradation on:
    # injected = served + queued + expired + retry_dropped + dropped.
    degrade_on: bool = False
    shed_cost: float = 0.0  # total mass shed at the admission door
    shed_by_tier: Dict[str, float] = field(default_factory=dict)
    shed_cost_t: np.ndarray = field(  # (ticks,) per-tick shed mass
        default_factory=lambda: np.zeros(0))
    expired_requests: int = 0  # queued work abandoned past deadline
    expired_cost: float = 0.0
    retried_cost: float = 0.0  # shed mass re-submitted after backoff
    retry_dropped_cost: float = 0.0  # retry budget exhausted
    breaker_opens: int = 0
    breaker_state_t: np.ndarray = field(  # (racks, ticks) int state codes
        default_factory=lambda: np.zeros((0, 0), np.int64))
    breaker_events: List[Dict[str, Any]] = field(default_factory=list)

    # ----- derived ---------------------------------------------------------
    @property
    def n_racks(self) -> int:
        return int(self.power_w.shape[0])

    @property
    def ticks(self) -> int:
        return int(len(self.time_s))

    @property
    def duration_s(self) -> float:
        """Covered time: span of tick starts plus the final tick's width
        (taken from the last *actual* delta, so non-uniform tick spacing
        — e.g. stitched traces — is measured correctly)."""
        if self.ticks < 1:
            return 0.0
        if self.ticks == 1:
            return 1.0
        last_dt = self.time_s[-1] - self.time_s[-2]
        return float(self.time_s[-1] - self.time_s[0] + last_dt)

    @property
    def total_power_w(self) -> np.ndarray:
        """Fleet power per tick (sum over racks)."""
        return self.power_w.sum(axis=0)  # reprolint: ok[RPL001] roll-up over *finished* per-rack series; both engines produce identical power_w, so identical inputs give identical sums

    @property
    def mean_power_w(self) -> float:
        return float(self.total_power_w.mean()) if self.ticks else 0.0  # reprolint: ok[RPL001] roll-up-only display metric computed after the run; identical inputs in both engines

    @property
    def peak_power_w(self) -> float:
        return float(self.total_power_w.max()) if self.ticks else 0.0

    @property
    def mean_active_units(self) -> float:
        if not self.ticks:
            return 0.0
        return float(self.active_units.sum(axis=0).mean())  # reprolint: ok[RPL001] roll-up-only display metric; active_units is an integer-valued series, the sum is exact

    @property
    def throughput(self) -> float:
        return self.served / max(self.duration_s, 1e-9)

    @property
    def tpe(self) -> float:
        """Requests per joule — the paper's TpE, fleet-wide."""
        return self.served / max(self.energy_j, 1e-9)

    @property
    def energy_kwh(self) -> float:
        return self.energy_j / 3.6e6

    def proportionality(self) -> float:
        """How closely fleet power tracked fleet offered load."""
        return empirical_proportionality(self.offered_rps, self.total_power_w)

    # ----- bridges into the existing energy/TCO models ---------------------
    def energy_report(self) -> EnergyReport:
        return EnergyReport(
            joules=self.energy_j,
            avg_power_w=self.mean_power_w,
            peak_power_w=self.peak_power_w,
            items=self.served,
            tpe=self.tpe,
            proportionality=self.proportionality(),
        )

    def monthly_electricity_usd(self, pue: float = PUE_EDGE) -> float:
        """Extrapolate the run's average power to a 30-day electricity
        bill at the ``core.tco`` EIA rate, including PUE overhead."""
        monthly_kwh = self.mean_power_w * 24 * 30 / 1000.0
        return monthly_kwh * ELECTRICITY_USD_PER_KWH * pue

    def summary(self) -> Dict[str, float]:
        out = {
            "racks": self.n_racks,
            "ticks": self.ticks,
            "served": self.served,
            "energy_kwh": self.energy_kwh,
            "tpe": self.tpe,
            "mean_power_w": self.mean_power_w,
            "peak_power_w": self.peak_power_w,
            "mean_active_units": self.mean_active_units,
            "p50_latency_s": self.p50_latency_s,
            "p95_latency_s": self.p95_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "proportionality": self.proportionality(),
            "monthly_electricity_usd": self.monthly_electricity_usd(),
            "wall_s": self.wall_s,
            "drained": float(self.drained),
            "alerts": float(len(self.alerts)),
        }
        if self.chaos_events:
            out["chaos_events"] = float(len(self.chaos_events))
            out["dropped_requests"] = float(self.dropped_requests)
            out["respilled_requests"] = float(self.respilled_requests)
            rec = self.recovery
            if rec is not None:
                out["recovery_p99_blowup"] = rec.p99_blowup
                out["reconvergence_ticks"] = (
                    float(rec.reconvergence_ticks)
                    if rec.reconvergence_ticks is not None
                    else -1.0
                )
        if self.degrade_on:
            out["shed_cost"] = self.shed_cost
            out["expired_cost"] = self.expired_cost
            out["retried_cost"] = self.retried_cost
            out["retry_dropped_cost"] = self.retry_dropped_cost
            out["breaker_opens"] = float(self.breaker_opens)
            for tier, cost in self.shed_by_tier.items():
                out[f"shed_{tier}"] = cost
        return out
