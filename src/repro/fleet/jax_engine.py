"""JAX fleet engine: one jitted ``lax.scan`` per trace, ``vmap`` sweeps.

Third fleet backend (``Fleet(backend="jax")``). The whole per-tick
pipeline of the vector engine — branchless masked routing, the
``fixed`` / ``race-to-idle`` / ``schedutil`` / thermal-aware-clamp
governor passes, activation targets with cooldown, straggler hedging,
the fluid FIFO drain, ``UnitPool.charge`` power accounting, and the
stacked RC thermal Euler substeps — is a pure
``(state, traffic_t) -> (state, telemetry_t)`` function driven by
``jax.lax.scan`` and jitted once. On top, :func:`sweep` ``vmap``\\ s the
program over a stacked config axis (router choice, governor scalars,
rack-mix scalars) and shards the batch across host devices with
``pmap`` when ``--xla_force_host_platform_device_count`` exposes more
than one (see ``repro.config.set_host_device_count``).

Parity contract — **tolerance, not bitwise**. The scalar engine is the
oracle and the numpy vector engine matches it bitwise; this engine
reproduces the same arithmetic but XLA may fuse (FMA), reassociate
pairwise reductions, and schedule segment ops differently, so its
telemetry is compared against the vector engine under documented
rtol/atol bounds (``tests/test_jax_parity.py``). Float64 is mandatory:
every entry point runs inside ``jax.experimental.enable_x64`` — in
default float32 the drain recurrence loses request mass far beyond
those bounds.

Two tricks make the scan exact where it matters:

* the fluid FIFO collapses to a three-term recurrence per rack —
  pending cost ``B``, cumulative submitted cost ``A``, cumulative
  effective served ``S`` (``S`` snaps to ``A`` whenever a queue
  empties, mirroring the per-request 1e-12 forgiveness of
  ``QueueWorkload``) — and request-level completions/latencies are
  reconstructed on the host from the emitted per-tick ``(work, S,
  cap, perf)`` rows, with the same boundary semantics as the queue's
  pop rule;
* traces run in fixed-size blocks of :data:`_BLOCK` ticks with a
  per-tick ``live`` mask (dead ticks pass the carry through), so one
  compiled program serves every trace length and the post-trace drain:
  when the first fully-idle drain tick is found mid-block the block is
  re-run with the mask cut at that tick, landing the carry exactly on
  the inclusive stop tick — ``play_trace`` can then continue the same
  simulation, like the other engines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (
    TYPE_CHECKING,
    Any,
    Dict,
    List,
    NamedTuple,
    Optional,
    Sequence,
    Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.fleet.degrade import BRK_HALF, BRK_OPEN
from repro.fleet.engine_state import (
    GOV_FIXED,
    GOV_RACE,
    GOV_SCHED,
    FleetArrays,
    build_fleet_arrays,
)
from repro.runtime import Telemetry, latency_percentiles
from repro.runtime.result import Response

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.fleet.fleet import RackConfig

__all__ = ["ROUTER_KINDS", "SweepConfig", "sweep"]

#: branchless router selector values (params["router_kind"])
ROUTER_KINDS = {"round-robin": 0, "join-shortest-queue": 1, "power-aware": 2}

#: the fluid queue's per-request forgiveness (QueueWorkload pop rule)
_EPS = 1e-12

#: relative forgiveness for cumulative-axis comparisons: the carried S
#: (effective served) and the submission prefix sum A are two different
#: float summation orders of the same history, so after an overload
#: episode they drift apart by ~eps(|A|) — far above the absolute _EPS
#: once A reaches ~1e6 cost units. Completion tests along the cumulative
#: axis therefore forgive 1e-12 relative on top of the absolute floor
#: (still orders of magnitude below any real per-request cost).
_REL = 1e-12


def _cum_tol(x: Any) -> Any:
    """Forgiveness for comparisons between cumulative served/submitted
    totals (absolute floor + relative term, see ``_REL``)."""
    return _EPS + _REL * abs(x)

#: scan block size: one compiled program serves any trace length
_BLOCK = 128


class _Dims(NamedTuple):
    """Static (hashable) shape info baked into the compiled program."""

    kmax: int
    has_thermal: bool
    nt: int
    n_groups: int
    max_sub: int
    hedge_on: bool
    # emit the extra per-tick rows (opp, w_req, c_low, w_low) the host
    # needs to expand observability state after the scan; compiled as a
    # separate program so obs-off pays nothing
    emit_obs: bool = False
    # chaos mask rows are threaded through xs and the evacuation /
    # unit-cap / floor-OPP overlays run in-scan; compiled separately so
    # a chaos-free fleet runs the exact pre-chaos program
    chaos_on: bool = False
    # graceful degradation (repro.fleet.degrade lowered in-scan):
    # deadline expiry, per-rack circuit breakers, tiered admission with
    # a retry ring. All off by default so a degrade-free fleet compiles
    # to the exact pre-degrade program.
    degrade_on: bool = False
    dg_admission: bool = False
    dg_breaker_on: bool = False
    dg_use_chaos: bool = False
    dg_tiers: int = 0
    dg_attempts: int = 1
    dg_ring_slots: int = 1
    dg_lag: int = 0


# ---------------------------------------------------------------------------
# pure per-tick pipeline (everything below runs under jit)


def _route(
    params: Dict[str, Any],
    queued: Any,
    total: Any,
    dt: Any,
    cap: Any,
    alive: Optional[Any],
) -> Any:
    """All three routers, computed branchlessly and selected by
    ``params["router_kind"]`` — which is what lets a vmapped sweep give
    every config its own router. Mirrors ``repro.fleet.router``.

    ``cap`` is the (possibly chaos-degraded) per-rack capacity;
    ``alive`` is the chaos liveness mask (``None`` statically when no
    chaos is wired, keeping the compiled program unchanged)."""
    n = cap.shape[0]
    rk = params["router_kind"]
    # round-robin: uniform spread (over live racks only under chaos)
    if alive is None:
        rr = jnp.full(n, total / n)
    else:
        n_alive = jnp.sum(alive.astype(jnp.int64))  # reprolint: ok[RPL001] int64 counter, exact in any order
        rr = jnp.where(alive, total / jnp.maximum(n_alive, 1), 0.0)
    # join-shortest-queue: water-fill on expected queueing delay
    capm = jnp.maximum(cap, 1e-12)
    work = total * dt
    delay = queued / capm
    order = jnp.argsort(delay, stable=True)
    d = jnp.take(delay, order)
    c = jnp.take(capm, order)
    q = jnp.take(queued, order)
    levels = (work + jnp.cumsum(q)) / jnp.cumsum(c)  # reprolint: ok[RPL001] jax tolerance-parity: prefix cumsum; jax engine is tolerance-compared, not bitwise
    feasible = jnp.where(levels >= d, jnp.arange(n), -1)
    idx = jnp.max(feasible)
    level = jnp.where(idx < 0, levels[0], levels[jnp.maximum(idx, 0)])
    jsq = jnp.maximum(0.0, cap * level - queued) / dt
    # power-aware: pack the cheapest (J/request) racks first
    porder = params["pa_order"]
    capo = jnp.take(cap, porder)
    setpoint = capo * params["pa_util_target"]

    def greedy(tot: Any, budget: Any) -> Any:
        before = jnp.concatenate(
            [jnp.zeros(1), jnp.cumsum(budget)[:-1]]  # reprolint: ok[RPL001] jax tolerance-parity: prefix cumsum mirrors PowerAwareRouter._greedy
        )
        return jnp.clip(tot - before, 0.0, budget)

    take = greedy(total, setpoint)
    rem = total - jnp.sum(take)  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
    take = take + jnp.where(rem > 1e-12, greedy(rem, capo - take), 0.0)
    rem2 = total - jnp.sum(take)  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
    # chaos: a fully-dead fleet has zero capacity — guard the spread
    # denominator (the numerator is already zero, so the quotient is 0)
    spread = rem2 * capo / jnp.maximum(jnp.sum(capo), 1e-12)  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
    take = take + jnp.where(rem2 > 1e-12, spread, 0.0)
    pa = jnp.zeros(n).at[porder].set(take)
    assign = jnp.where(rk == 0, rr, jnp.where(rk == 1, jsq, pa))
    # every router hands out nothing when there is no offered load
    return jnp.where(total > 0.0, assign, jnp.zeros(n))


def _select_opps(
    params: Dict[str, Any], dims: _Dims, opp: Any, backlog: Any, rate: Any
) -> Any:
    """Branchless twin of ``_VectorFleetEngine._select_opps`` (which
    itself mirrors the scalar governors)."""
    gk = params["gov_kind"]
    opp = jnp.where(gk == GOV_FIXED, params["fixed_opp"], opp)
    busy = (rate > 0.0) | backlog
    opp = jnp.where(
        gk == GOV_RACE,
        jnp.where(busy, params["highest"], params["nominal"]),
        opp,
    )
    # schedutil: lowest-energy OPP x unit-count search over the OPP axis
    need = rate * params["sched_headroom"]
    pos = need > 0.0
    best = params["highest"]
    bestp = jnp.full(rate.shape[0], jnp.inf)
    for c in range(dims.kmax):
        eff = params["unit_rate"] * params["perf_tab"][:, c]
        ncnt = jnp.maximum(params["min_units"], jnp.ceil(need / eff)).astype(
            jnp.int64
        )
        util = jnp.minimum(1.0, rate / (jnp.maximum(ncnt, 1) * eff))
        power = (
            ncnt * (params["p_idle"] + params["spk_tab"][:, c] * util ** params["gamma"])
            + (params["n_units"] - ncnt) * params["p_base"]
        )
        upd = (
            (c < params["K"])
            & (ncnt <= params["n_units"])
            & pos
            & (power < bestp - 1e-12)
        )
        best = jnp.where(upd, c, best)
        bestp = jnp.where(upd, power, bestp)
    opp = jnp.where(gk == GOV_SCHED, jnp.where(pos, best, 0), opp)
    # thermal-aware ceiling clamps whatever the inner governor picked
    return jnp.where(
        params["has_ceiling"], jnp.minimum(opp, params["ceiling"]), opp
    )


def _thermal_step(
    params: Dict[str, Any],
    dims: _Dims,
    t_die: Any,
    t_pcb: Any,
    latched: Any,
    pw: Any,
    dt: Any,
    fan_fail: Optional[Any] = None,
) -> Tuple[Any, Any, Any, Any, Any, Any]:
    """Stacked RC Euler step (twin of ``_StackedThermal.step``). The
    per-rack sub-step counts are data-dependent, so a ``fori_loop``
    runs to the static worst case (``ThermalLayout.max_substeps``) with
    per-rack live masks — masked racks add exact zeros.

    ``fan_fail`` (chaos, per thermal rack) pins the fan fraction to
    exactly 0.0: zero airflow, zero fan power, and the PCB resistance
    collapses to ``r_pcb0`` exactly (``1 - (1 - rmin) * 0.0 == 1``)."""
    rack_u = params["th_rack_u"]
    rack_g = params["th_rack_g"]
    group_of_u = params["th_group_of_u"]
    hottest = jax.ops.segment_max(t_pcb, rack_g, num_segments=dims.nt)
    raw_frac = (hottest - params["th_fan_low"]) / params["th_fan_span"]
    frac = jnp.clip(raw_frac, 0.0, 1.0)
    if fan_fail is not None:
        frac = jnp.where(fan_fail, 0.0, frac)
    r_pcb = params["th_r_pcb0"] * (1.0 - (1.0 - params["th_fan_rmin"]) * frac)
    tau = jnp.minimum(
        params["th_r_die"] * params["th_c_die"], r_pcb * params["th_c_pcb"]
    )
    denom = jnp.maximum(0.25 * tau, 1e-6)
    n_sub = jnp.maximum(1, (dt / denom).astype(jnp.int64) + 1)
    hh = dt / n_sub
    h_u = jnp.take(hh, rack_u)
    h_g = jnp.take(hh, rack_g)
    r_pcb_g = jnp.take(r_pcb, rack_g)
    n_sub_u = jnp.take(n_sub, rack_u)
    n_sub_g = jnp.take(n_sub, rack_g)

    def body(s: Any, st: Tuple[Any, Any]) -> Tuple[Any, Any]:
        td, tp = st
        f = (td - jnp.take(tp, group_of_u)) / params["th_r_die_u"]
        flows = jax.ops.segment_sum(f, group_of_u, num_segments=dims.n_groups)
        d_die = h_u * (pw - f) / params["th_c_die_u"]
        out = (tp - params["th_t_amb_g"]) / r_pcb_g
        d_pcb = h_g * (flows - out) / params["th_c_pcb_g"]
        td = td + jnp.where(s < n_sub_u, d_die, 0.0)
        tp = tp + jnp.where(s < n_sub_g, d_pcb, 0.0)
        return (td, tp)

    t_die, t_pcb = jax.lax.fori_loop(0, dims.max_sub, body, (t_die, t_pcb))
    trip_u = jnp.take(params["th_trip"], rack_u)
    rel_u = jnp.take(params["th_release"], rack_u)
    new_latched = jnp.where(latched, ~(t_die <= rel_u), t_die >= trip_u)
    fan_w = params["th_fan_pmax"] * frac
    max_temp = jax.ops.segment_max(t_die, rack_u, num_segments=dims.nt)
    n_thr = jax.ops.segment_sum(
        new_latched.astype(jnp.int64), rack_u, num_segments=dims.nt
    )
    return t_die, t_pcb, new_latched, fan_w, max_temp, n_thr


def _step(
    params: Dict[str, Any], dims: _Dims, carry: Dict[str, Any], x: Dict[str, Any]
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """One fleet tick. ``x["live"]`` masks the whole tick (dead ticks
    pass the carry through unchanged); ``x["is_trace"]`` marks trace
    ticks (only those append to the hedge submission ring)."""
    dt = params["dt"]
    live = x["live"]
    t = carry["t"]
    B = carry["B"]
    A = carry["A"]
    S = carry["S"]
    fresh = x["rps"] * params["trace_scale"]
    total = fresh
    # chaos overlays (compiled out entirely when dims.chaos_on is off).
    # A full-rack kill edge evacuates the rack's pending cost *before*
    # routing — exactly the scalar/vector drivers' _chaos_step order —
    # and under on_kill="respill" the evacuated mass re-enters this
    # tick's offered total through the router like any other load.
    if dims.chaos_on:
        kill_edge = x["chaos_kill"]
        evac = jnp.where(kill_edge, B, 0.0)
        B = jnp.where(kill_edge, 0.0, B)
        E_new = carry["E"] + evac
        respill_rps = params["chaos_respill"] * jnp.sum(evac) / dt  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
        total = total + respill_rps
        cap_units = jnp.maximum(params["n_units"] - x["chaos_dead"], 0)
        # routers see the degraded fleet: killed units shrink capacity,
        # a fully-dead rack advertises exactly 0.0 and alive=False
        cap_rt = params["capacity_rps"] * (
            cap_units.astype(jnp.float64)
            / params["n_units"].astype(jnp.float64)
        )
        alive: Optional[Any] = x["chaos_dead"] < params["n_units"]
    else:
        evac = E_new = None
        respill_rps = jnp.float64(0.0)
        cap_units = params["n_units"]
        cap_rt = params["capacity_rps"]
        alive = None
    # graceful degradation control plane (repro.fleet.degrade lowered
    # in-scan; compiled out entirely when dims.degrade_on is off). The
    # per-tick order mirrors Fleet._degrade_pre exactly: deadline
    # expiry on the post-evacuation queue, breaker state machine,
    # retry-ring release + tiered admission, then routing against the
    # breaker-scaled capacity. Respill bypasses admission, like the
    # host driver.
    D_new = None
    brk_scale = None
    if dims.degrade_on:
        tick = carry["dg_tick"]
        D = carry["dg_D"]
        # deadline expiry: the lag ring W holds per-tick admitted work;
        # the slot consumed at tick i was written at tick i - L, so
        # A_lag = total submitted through tick i - L. FIFO serving
        # means the un-dispatched part of that prefix is exactly the
        # past-deadline mass — the same mass QueueWorkload.expire pops.
        if dims.dg_lag > 0:
            W = carry["dg_W"]
            A_lag = carry["dg_A_lag"]
            slotL = jnp.mod(tick, dims.dg_lag)
            A_lag = A_lag + W[slotL]
            disp_x = S + D if not dims.chaos_on else S + E_new + D
            expired = jnp.clip(A_lag - disp_x, 0.0, B)
            B = B - expired
            D_new = D + expired
        else:
            W = A_lag = None
            expired = jnp.zeros_like(B)
            D_new = D
        # per-rack circuit breakers (post-expiry queue depth, chaos-
        # degraded capacity) — branchless twin of DegradeDriver's
        # _update_breakers state machine
        brk = carry["dg_brk"]
        since = carry["dg_since"]
        last_live = carry["dg_last_live"]
        opens = carry["dg_opens"]
        if dims.dg_breaker_on:
            if dims.chaos_on and dims.dg_use_chaos:
                full_dead = x["chaos_dead"] >= params["n_units"]
            else:
                full_dead = jnp.zeros(B.shape[0], bool)
            last_live = jnp.where(full_dead, last_live, tick)
            failed = (tick - last_live) > params["dg_fail_timeout_ticks"]
            delay = B / jnp.maximum(cap_rt, 1e-12)
            trip = (delay > params["dg_open_after"]) | failed
            open_now = (brk == 0) & trip
            to_half = (brk == BRK_OPEN) & (
                tick - since >= params["dg_cooldown_ticks"]
            )
            half_trip = (brk == BRK_HALF) & trip
            to_closed = (
                (brk == BRK_HALF)
                & (delay <= params["dg_close_below"])
                & ~failed
            )
            brk = jnp.where(
                open_now | half_trip,
                BRK_OPEN,
                jnp.where(to_half, BRK_HALF, jnp.where(to_closed, 0, brk)),
            )
            since = jnp.where(open_now | half_trip | to_half, tick, since)
            opens = opens + jnp.sum(  # reprolint: ok[RPL001] int64 counter, exact in any order
                (open_now | half_trip).astype(jnp.int64)
            )
            brk_scale = jnp.where(
                brk == BRK_OPEN,
                0.0,
                jnp.where(brk == BRK_HALF, params["dg_probe"], 1.0),
            )
        else:
            brk_scale = jnp.ones(B.shape[0])
        # retry-ring release + SLO-tiered admission on fleet totals
        ring = carry["dg_ring"]
        shed_by_tier = carry["dg_shed_by_tier"]
        retried = carry["dg_retried"]
        dropped = carry["dg_retry_dropped"]
        shed_row = jnp.zeros(ring.shape[1])
        retried_d = jnp.float64(0.0)
        dropped_d = jnp.float64(0.0)
        if dims.dg_admission:
            slot = jnp.mod(tick, dims.dg_ring_slots)
            released = ring[slot]  # (tiers, attempts)
            ring = ring.at[slot].set(0.0)
            cap_total = jnp.sum(cap_rt * brk_scale)  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
            queued_total = jnp.sum(B)  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
            est_delay = queued_total / jnp.maximum(cap_total, 1e-12)
            dticks = x["dg_dticks"]  # (attempts,) int64 backoff delays
            shares = params["dg_shares"]
            budgets = params["dg_budgets"]
            # tier split of the fresh trace load: the last tier takes
            # the exact remainder (DegradePolicy share semantics)
            fresh_k = []
            acc = jnp.float64(0.0)
            for k in range(dims.dg_tiers - 1):
                f_k = shares[k] * fresh
                fresh_k.append(f_k)
                acc = acc + f_k
            fresh_k.append(fresh - acc)
            admit_total = jnp.float64(0.0)
            adm_list = []  # per-tier admitted rps, for the host-side
            # tier-split reconstruction of sub-requests (mirrors the
            # fractions DegradeDriver.pre_route hands to _tier_requests)
            for k in range(dims.dg_tiers):
                rel_mass = released[k]  # (attempts,)
                rel_rps = jnp.sum(rel_mass) / dt  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
                ok = (est_delay <= budgets[k]) & (cap_total > 1e-12)
                adm_k = jnp.where(ok, fresh_k[k] + rel_rps, 0.0)
                adm_list.append(adm_k)
                admit_total = admit_total + adm_k
                shed_fresh = jnp.where(ok, 0.0, fresh_k[k] * dt)
                shed_row = shed_row.at[k].set(
                    shed_fresh + jnp.where(ok, 0.0, jnp.sum(rel_mass))  # reprolint: ok[RPL001] jax tolerance-parity: XLA reduction order is unpinned by design here
                )
                # fresh shed enters the retry ring at attempt 0
                if dims.dg_attempts > 1:
                    s0 = jnp.mod(tick + dticks[0], dims.dg_ring_slots)
                    ring = ring.at[s0, k, 1].add(shed_fresh)
                    retried_d = retried_d + shed_fresh
                else:
                    dropped_d = dropped_d + shed_fresh
                # re-shed released mass moves to the next attempt (or
                # out of budget)
                for a in range(1, dims.dg_attempts):
                    m = jnp.where(ok, 0.0, rel_mass[a])
                    if a + 1 >= dims.dg_attempts:
                        dropped_d = dropped_d + m
                    else:
                        sa = jnp.mod(tick + dticks[a], dims.dg_ring_slots)
                        ring = ring.at[sa, k, a + 1].add(m)
                        retried_d = retried_d + m
            shed_by_tier = shed_by_tier + shed_row
            retried = retried + retried_d
            dropped = dropped + dropped_d
            total = admit_total + respill_rps
        if dims.dg_breaker_on:
            cap_rt = cap_rt * brk_scale
            brk_alive = brk != BRK_OPEN
            alive = brk_alive if alive is None else alive & brk_alive
    assign = _route(params, B, total, dt, cap_rt, alive)
    work = assign * dt
    rate = work / dt
    # frequency governors pick this tick's OPP (window_s == dt_s)
    opp = _select_opps(params, dims, carry["opp"], carry["backlog"], rate)
    # a power-capped rack *runs* at the floor point this tick while the
    # carried governor state stays untouched (force_floor_opp twin)
    if dims.chaos_on:
        opp_eff = jnp.where(x["chaos_cap"] & params["has_table"], 0, opp)
    else:
        opp_eff = opp
    perf_req = jnp.take_along_axis(
        params["perf_tab"], opp_eff[:, None], axis=1
    )[:, 0]
    perf_sz = jnp.where(params["has_table"], perf_req, 1.0)
    # UnitGovernor.target_units / apply_target with group == 1
    need = rate * params["headroom"] / (
        params["unit_rate"] * jnp.maximum(perf_sz, 1e-9)
    )
    raw = jnp.minimum(
        params["n_units"], jnp.maximum(params["min_units"], jnp.ceil(need))
    )
    tgt = jnp.maximum(1, raw.astype(jnp.int64))
    active = carry["active"]
    if dims.chaos_on:
        # killed units are force-released (no cooldown stamp, no scale
        # event — a fault is not a scaling decision) and the target is
        # capped, mirroring apply_target's unit_cap path
        tgt = jnp.minimum(tgt, cap_units)
        active = jnp.minimum(active, cap_units)
    up = tgt > active
    keep_n = jnp.maximum(params["minq"], tgt)
    in_cooldown = t - carry["last_down"] > params["cooldown"]
    down = (tgt < active) & in_cooldown & (keep_n < active)
    new_active = jnp.where(up, tgt, jnp.where(down, keep_n, active))
    scale = up.astype(jnp.int64) + down.astype(jnp.int64)
    scale_events = carry["scale_events"] + scale
    last_down = jnp.where(down, t, carry["last_down"])
    k_f = new_active.astype(jnp.float64)
    # mean perf-scale over active units; trip-latched dies dragged to
    # the floor OPP (pool.perf_scale / _perf_from_opp_counts). A fully
    # killed rack has k == 0: the pool returns the requested point's
    # perf there (the k_div guard only rewrites the k == 0 lanes)
    if dims.chaos_on:
        k_div = jnp.maximum(k_f, 1.0)
        perf_used = jnp.where(
            params["has_table"],
            jnp.where(new_active > 0, (k_f * perf_req) / k_div, perf_req),
            1.0,
        )
    else:
        perf_used = jnp.where(params["has_table"], (k_f * perf_req) / k_f, 1.0)
    if dims.has_thermal:
        ti = params["t_idx"]
        rack_u = params["th_rack_u"]
        latched = carry["latched"]
        am = params["th_local_idx"] < jnp.take(new_active, ti)[rack_u]
        lam = (am & latched).astype(jnp.int64)
        c_low_t = jax.ops.segment_sum(lam, rack_u, num_segments=dims.nt)
        c_low_f = c_low_t.astype(jnp.float64)
        k_t = jnp.take(k_f, ti)
        p0 = jnp.take(params["perf_tab"][:, 0], ti)
        pr = jnp.take(perf_req, ti)
        floor_all = (jnp.take(opp_eff, ti) == 0) & (c_low_t > 0)
        mixed = c_low_f * p0 + (k_t - c_low_f) * pr
        if dims.chaos_on:
            k_div_t = jnp.maximum(k_t, 1.0)
            perf_used = perf_used.at[ti].set(
                jnp.where(
                    k_t > 0.0,
                    jnp.where(floor_all, k_t * p0, mixed) / k_div_t,
                    pr,
                )
            )
        else:
            perf_used = perf_used.at[ti].set(
                jnp.where(floor_all, k_t * p0, mixed) / k_t
            )
    # straggler hedging: the submission ring carries (cumulative cost,
    # arrival) per trace tick; the head request is the first submission
    # not yet fully served (searchsorted past S + forgiveness)
    arrival_t = t + 0.5 * dt
    A_new = A + work
    if dims.hedge_on:
        wmask = x["is_trace"] & live
        ptr = carry["ptr"]
        A_buf = carry["A_buf"]
        arr_buf = carry["arr_buf"]
        A_buf = A_buf.at[:, ptr].set(jnp.where(wmask, A_new, A_buf[:, ptr]))
        arr_buf = arr_buf.at[:, ptr].set(
            jnp.where(wmask, arrival_t, arr_buf[:, ptr])
        )
        new_ptr = ptr + wmask.astype(jnp.int64)
        # under chaos the head search skips evacuated mass: the combined
        # dispatched axis is S + E (served + voided), mirroring the
        # scalar queue being physically cleared by evacuate()
        if dims.chaos_on:
            disp = S + E_new
        else:
            disp = S
        # deadline-expired mass leaves the queue the same way (the
        # scalar queue is physically popped by expire())
        if dims.degrade_on and dims.dg_lag > 0:
            disp = disp + D_new
        head = jax.vmap(
            lambda row, key: jnp.searchsorted(row, key, side="right")
        )(A_buf, disp + _cum_tol(disp))
        hidx = jnp.minimum(head, jnp.maximum(new_ptr - 1, 0))
        head_arrival = jnp.take_along_axis(arr_buf, hidx[:, None], axis=1)[:, 0]
        age = jnp.maximum(0.0, t - head_arrival)
        pending = (B + work) > 0.0
        h = (
            pending
            & (age > params["hedge_deadline"])
            & (new_active < cap_units)
        ).astype(jnp.int64)
        if dims.chaos_on:
            # drain-tick respill is not recorded in the submission ring
            # (is_trace gates writes); without a ring entry past the
            # dispatched axis there is no head request to age
            h = h * (head < new_ptr).astype(jnp.int64)
    else:
        h = jnp.zeros_like(new_active)
    hedged = carry["hedged"] + h
    # fluid FIFO drain (QueueWorkload.step_fast collapsed to B/A/S)
    cap = (
        jnp.maximum(new_active + h, 0).astype(jnp.float64)
        * params["unit_rate"]
        * dt
        * jnp.maximum(perf_used, 0.0)
    )
    Bw = B + work
    empty = Bw <= cap + _EPS
    used = jnp.where(empty, Bw, cap)
    B_new = jnp.where(empty, 0.0, Bw - cap)
    S_new = jnp.where(empty, S + Bw, S + cap)
    cap_safe = jnp.where(cap > 0.0, cap, 1.0)
    util = jnp.where(cap > 0.0, used / cap_safe, 0.0)
    backlog = B_new > 0.0
    served = carry["served"] + used
    # UnitPool.charge: active units at the rack's OPP (latched dies at
    # the floor), the borrowed hedge unit at the requested point, the
    # rest at the gated floor
    u = jnp.clip(util, 0.0, 1.0)
    ug = u ** params["gamma"]
    spk_req = jnp.take_along_axis(
        params["spk_tab"], opp_eff[:, None], axis=1
    )[:, 0]
    w_req = params["p_idle"] + spk_req * ug
    h_f = h.astype(jnp.float64)
    powered = new_active + h
    powered_f = powered.astype(jnp.float64)
    p_act = k_f * w_req
    fan_w = jnp.zeros(w_req.shape[0])
    if dims.has_thermal:
        w_low = params["p_idle"] + params["spk_tab"][:, 0] * ug
        w_low_t = jnp.take(w_low, ti)
        w_req_t = jnp.take(w_req, ti)
        mixed_w = c_low_f * w_low_t + (k_t - c_low_f) * w_req_t
        p_act = p_act.at[ti].set(jnp.where(floor_all, k_t * w_low_t, mixed_w))
        pw = jnp.take(params["p_base"], ti)[rack_u]
        pw = jnp.where(am, w_req_t[rack_u], pw)
        pw = jnp.where(am & latched, w_low_t[rack_u], pw)
        last_u = params["th_last_unit"]
        pw = pw.at[last_u].set(
            jnp.where(jnp.take(h, ti) > 0, w_req_t, pw[last_u])
        )
        fan_fail_t = (
            jnp.take(x["chaos_fan"], ti) if dims.chaos_on else None
        )
        t_die, t_pcb, new_latched, fan_t, temp_t, thr_t = _thermal_step(
            params, dims, carry["t_die"], carry["t_pcb"], latched, pw, dt,
            fan_fail=fan_fail_t,
        )
        fan_w = fan_w.at[ti].set(fan_t)
    p_units = jnp.where(
        params["has_table"], p_act + h_f * w_req, powered_f * w_req
    )
    p_rest = (params["n_units"] - powered).astype(jnp.float64) * params["p_base"]
    total_w = params["p_shared"] + fan_w + p_units + p_rest
    energy = carry["energy"] + total_w * dt
    unit_energy = carry["unit_energy"] + p_units * dt
    pf_safe = jnp.where(powered_f > 0.0, powered_f, 1.0)
    util_agg = jnp.where(powered_f > 0.0, powered_f * u / pf_safe, 0.0)

    def keep(new: Any, old: Any) -> Any:
        return jnp.where(live, new, old)

    new_carry: Dict[str, Any] = {
        "t": keep(t + dt, t),
        # fall back to the *pre-evacuation* carry on dead ticks (the
        # local B was rewritten by the chaos kill edge above)
        "B": keep(B_new, carry["B"]),
        "A": keep(A_new, A),
        "S": keep(S_new, S),
        "opp": keep(opp, carry["opp"]),
        "backlog": keep(backlog, carry["backlog"]),
        "active": keep(new_active, active),
        "last_down": keep(last_down, carry["last_down"]),
        "scale_events": keep(scale_events, carry["scale_events"]),
        "hedged": keep(hedged, carry["hedged"]),
        "energy": keep(energy, carry["energy"]),
        "unit_energy": keep(unit_energy, carry["unit_energy"]),
        "served": keep(served, carry["served"]),
    }
    if dims.has_thermal:
        new_carry["t_die"] = keep(t_die, carry["t_die"])
        new_carry["t_pcb"] = keep(t_pcb, carry["t_pcb"])
        new_carry["latched"] = keep(new_latched, latched)
    if dims.hedge_on:
        new_carry["A_buf"] = keep(A_buf, carry["A_buf"])
        new_carry["arr_buf"] = keep(arr_buf, carry["arr_buf"])
        new_carry["ptr"] = keep(new_ptr, carry["ptr"])
    if dims.chaos_on:
        new_carry["E"] = keep(E_new, carry["E"])
    if dims.degrade_on:
        new_carry["dg_tick"] = keep(tick + 1, tick)
        new_carry["dg_brk"] = keep(brk, carry["dg_brk"])
        new_carry["dg_since"] = keep(since, carry["dg_since"])
        new_carry["dg_last_live"] = keep(last_live, carry["dg_last_live"])
        new_carry["dg_opens"] = keep(opens, carry["dg_opens"])
        new_carry["dg_ring"] = keep(ring, carry["dg_ring"])
        new_carry["dg_shed_by_tier"] = keep(
            shed_by_tier, carry["dg_shed_by_tier"]
        )
        new_carry["dg_retried"] = keep(retried, carry["dg_retried"])
        new_carry["dg_retry_dropped"] = keep(
            dropped, carry["dg_retry_dropped"]
        )
        new_carry["dg_D"] = keep(D_new, carry["dg_D"])
        if dims.dg_lag > 0:
            # the consumed slot is overwritten with this tick's routed
            # work — it will be the lagged prefix again in L ticks
            new_carry["dg_A_lag"] = keep(A_lag, carry["dg_A_lag"])
            new_carry["dg_W"] = keep(W.at[slotL].set(work), carry["dg_W"])
    ys: Dict[str, Any] = {
        "assign": assign,
        "rate": rate,
        "work": work,
        "empty": empty,
        "used": used,
        "S": S_new,
        "cap": cap,
        "perf": perf_used,
        "active": powered,
        "power": total_w,
        "util": util_agg,
        "hedge": h,
        "scale": scale,
    }
    if dims.has_thermal:
        ys["fan"] = fan_t
        ys["temp"] = temp_t
        ys["thr"] = thr_t
    if dims.chaos_on:
        ys["evac"] = evac
    if dims.degrade_on:
        # the routed (admitted) fleet total — what the host drivers
        # append to their offered series
        ys["dg_admitted"] = total
        ys["dg_shed"] = shed_row
        if dims.dg_admission:
            # per-tier admitted rps + untiered respill rps: the host
            # side rebuilds _tier_requests-compatible split fractions
            # from these so sub-request reconstruction (responses,
            # queued counts, void/expiry counts, tier latency tags)
            # matches the host engines' tiered submissions
            ys["dg_adm"] = jnp.stack(adm_list)
            ys["dg_respill"] = respill_rps
        ys["dg_expired"] = expired
        ys["dg_brk"] = brk
        ys["dg_ring_mass"] = jnp.sum(ring)  # reprolint: ok[RPL001] jax tolerance-parity: drain-idle sentinel only, compared against exact 0
        ys["dg_retried"] = retried_d
        ys["dg_retry_dropped"] = dropped_d
    if dims.emit_obs:
        ys["opp"] = opp_eff
        ys["w_req"] = w_req
        if dims.has_thermal:
            ys["c_low"] = c_low_f
            ys["w_low"] = w_low
    return new_carry, ys


def _scan_steps(
    params: Dict[str, Any],
    carry: Dict[str, Any],
    xs: Dict[str, Any],
    dims: _Dims,
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    def f(c: Dict[str, Any], x: Dict[str, Any]) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        return _step(params, dims, c, x)

    return jax.lax.scan(f, carry, xs)


_RUN = jax.jit(_scan_steps, static_argnames=("dims",))


# ---------------------------------------------------------------------------
# static params / carry builders (shared by the engine and sweep())


def _full_load_j_per_req(racks: "Sequence[RackConfig]") -> np.ndarray:
    """Same ranking key ``Fleet`` publishes to the PowerAwareRouter."""
    return np.array(
        [
            (rc.spec.p_shared + rc.spec.n_units * rc.spec.unit.power(1.0))
            / (rc.spec.n_units * rc.unit_rate)
            for rc in racks
        ],
        float,
    )


def _base_params(
    arr: FleetArrays, dt_s: float, jpr: np.ndarray
) -> Dict[str, Any]:
    p: Dict[str, Any] = {
        "dt": float(dt_s),
        "trace_scale": 1.0,
        "router_kind": np.int64(ROUTER_KINDS["join-shortest-queue"]),
        "pa_util_target": 0.85,
        "pa_order": np.argsort(jpr, kind="stable"),
        "capacity_rps": arr.n_units.astype(float) * arr.unit_rate,
        "n_units": arr.n_units,
        "unit_rate": arr.unit_rate,
        "headroom": arr.headroom,
        "min_units": arr.min_units,
        "minq": arr.minq,
        "cooldown": arr.cooldown,
        "p_shared": arr.p_shared,
        "p_idle": arr.p_idle,
        "gamma": arr.gamma,
        "p_base": arr.p_base,
        "has_table": arr.has_table,
        "K": arr.K,
        "perf_tab": arr.perf_tab,
        "spk_tab": arr.spk_tab,
        "nominal": arr.nominal,
        "highest": arr.highest,
        "gov_kind": arr.gov_kind,
        "fixed_opp": arr.fixed_opp,
        "sched_headroom": arr.sched_headroom,
        "ceiling": arr.ceiling,
        "has_ceiling": arr.has_ceiling,
        "hedge_deadline": np.array(
            [np.inf if dl is None else float(dl) for dl in arr.hedge_deadline]
        ),
    }
    th = arr.thermal
    if th is not None:
        p.update(
            t_idx=th.t_idx,
            th_rack_u=th.rack_u,
            th_rack_g=th.rack_g,
            th_group_of_u=th.group_of_u,
            th_local_idx=th.local_idx,
            th_last_unit=th.last_unit,
            th_r_die=th.r_die,
            th_c_die=th.c_die,
            th_r_pcb0=th.r_pcb0,
            th_c_pcb=th.c_pcb,
            th_t_amb_g=th.t_amb_g,
            th_fan_low=th.fan_low,
            th_fan_span=th.fan_span,
            th_fan_rmin=th.fan_rmin,
            th_fan_pmax=th.fan_pmax,
            th_trip=th.trip,
            th_release=th.release,
            th_r_die_u=th.r_die_u,
            th_c_die_u=th.c_die_u,
            th_c_pcb_g=th.c_pcb_g,
        )
    return p


def _make_dims(
    arr: FleetArrays,
    dt_s: float,
    hedge_on: bool,
    emit_obs: bool = False,
    chaos_on: bool = False,
    degrade: Optional[Any] = None,
) -> _Dims:
    th = arr.thermal
    return _Dims(
        kmax=int(arr.Kmax),
        has_thermal=th is not None,
        nt=0 if th is None else int(len(th.t_idx)),
        n_groups=0 if th is None else th.n_groups,
        max_sub=0 if th is None else th.max_substeps(dt_s),
        hedge_on=hedge_on,
        emit_obs=emit_obs,
        chaos_on=chaos_on,
        degrade_on=degrade is not None,
        dg_admission=degrade is not None and degrade.admission_on,
        dg_breaker_on=degrade is not None and degrade.breaker_on,
        dg_use_chaos=(
            degrade is not None
            and degrade.breaker_on
            and degrade.policy.breaker.use_chaos_signal
        ),
        dg_tiers=0 if degrade is None else int(degrade.n_tiers),
        dg_attempts=(
            1 if degrade is None else int(degrade.retry.max_attempts)
        ),
        dg_ring_slots=1 if degrade is None else int(degrade.ring_slots),
        dg_lag=0 if degrade is None else int(degrade.deadline_lag),
    )


def _fresh_carry(arr: FleetArrays, hedge_on: bool, tbuf: int) -> Dict[str, Any]:
    n = arr.n_racks
    c: Dict[str, Any] = {
        "t": np.float64(0.0),
        "B": np.zeros(n),
        "A": np.zeros(n),
        "S": np.zeros(n),
        "opp": arr.opp0.copy(),
        "backlog": np.zeros(n, bool),
        "active": arr.minq.copy(),
        "last_down": np.full(n, -1e9),
        "scale_events": np.zeros(n, np.int64),
        "hedged": np.zeros(n, np.int64),
        "energy": np.zeros(n),
        "unit_energy": np.zeros(n),
        "served": np.zeros(n),
    }
    th = arr.thermal
    if th is not None:
        c["t_die"] = th.t_amb[th.rack_u].copy()
        c["t_pcb"] = th.t_amb[th.rack_g].copy()
        c["latched"] = np.zeros(th.n_flat_units, bool)
    if hedge_on:
        c["A_buf"] = np.full((n, tbuf), np.inf)
        c["arr_buf"] = np.full((n, tbuf), np.inf)
        c["ptr"] = np.int64(0)
    return c


def _host_rows(ys: Any, n: int) -> Dict[str, np.ndarray]:
    host = jax.device_get(ys)
    return {k: np.asarray(v)[:n] for k, v in host.items()}


# ---------------------------------------------------------------------------
# host-side request reconstruction (completions / latencies / queue depth)


def _expand_submissions(
    work_col: np.ndarray, split_rows: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Expand each work-carrying tick into per-tier sub-submissions,
    mirroring ``fleet._tier_requests`` exactly: slice existence is
    decided by ``frac > 0`` alone, non-last slices cost ``work * frac``
    and the last positive-fraction slice takes the exact remainder (the
    trailing column is the untiered chaos respill, tier index ``-1``
    → tier count). Returns (submission ticks, costs, tier indices)."""
    ticks: List[int] = []
    costs: List[float] = []
    tiers: List[int] = []
    for i in np.nonzero(work_col > 0.0)[0]:
        w = float(work_col[i])
        row = split_rows[i]
        idx = np.nonzero(row > 0.0)[0]
        if len(idx) == 0:
            # no split recorded for a work-carrying tick (should not
            # happen: routed work implies admitted flow) — keep the
            # mass as one untiered submission rather than drop it
            ticks.append(int(i))
            costs.append(w)
            tiers.append(len(row) - 1)
            continue
        acc = 0.0
        for k in idx[:-1]:
            c = w * float(row[k])
            ticks.append(int(i))
            costs.append(c)
            tiers.append(int(k))
            acc += c
        c = w - acc
        if c > 0.0:
            ticks.append(int(i))
            costs.append(c)
            tiers.append(int(idx[-1]))
    return (
        np.asarray(ticks, np.int64),
        np.asarray(costs),
        np.asarray(tiers, np.int64),
    )


def _completions(
    work_col: np.ndarray,
    s_col: np.ndarray,
    split_rows: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Per-rack submission ticks, cumulative-cost tails, completion
    ticks, and (when tiered) tier indices. Without ``split_rows`` one
    fluid request is reconstructed per work-carrying tick; with it,
    each tick expands into the same per-tier sub-requests the host
    engines submit via ``_tier_requests``, so response / queued / void
    *counts* match the hosts. Submission ``k`` completes at the first
    tick whose cumulative effective served ``S`` reaches its cumulative
    cost tail, minus the cumulative-axis forgiveness (``_cum_tol`` —
    the pop rule of ``QueueWorkload``, widened to relative because
    ``a`` and ``s_col`` are different float summation orders of the
    same history). A completion index of ``len(s_col)`` means "still
    queued"."""
    if split_rows is None:
        a = np.cumsum(work_col)  # reprolint: ok[RPL001] jax tolerance-parity: prefix cumsum replays the device carry's sequential adds
        sub = np.nonzero(work_col > 0.0)[0]
        a_sub = a[sub]
        tiers = None
    else:
        sub, costs, tiers = _expand_submissions(work_col, split_rows)
        a_sub = np.cumsum(costs)  # reprolint: ok[RPL001] jax tolerance-parity: prefix cumsum replays the device carry's sequential adds
    j = np.searchsorted(s_col, a_sub - _cum_tol(a_sub), side="left")
    return sub, a_sub, j, tiers


def _queued_for_rack(
    work_col: np.ndarray,
    s_col: np.ndarray,
    split_rows: Optional[np.ndarray] = None,
) -> np.ndarray:
    """End-of-tick queued request count per tick (len(queue) twin)."""
    t_all = len(work_col)
    sub, _, j, _ = _completions(work_col, s_col, split_rows)
    diff = np.zeros(t_all + 1, np.int64)
    np.add.at(diff, sub, 1)
    np.add.at(diff, np.minimum(j, t_all), -1)
    return np.cumsum(diff[:-1])  # reprolint: ok[RPL001] jax tolerance-parity: int64 prefix sum, exact in any order


def _responses_for_rack(
    ts: np.ndarray,
    dt: float,
    work_col: np.ndarray,
    s_col: np.ndarray,
    cap_col: np.ndarray,
    perf_col: np.ndarray,
    unit_rate: float,
    evac_col: Optional[np.ndarray] = None,
    split_rows: Optional[np.ndarray] = None,
    payloads: Optional[List[Optional[str]]] = None,
) -> List[Response]:
    """Rebuild the rack's :class:`Response` list from emitted rows,
    with ``QueueWorkload.step_fast``'s finish-time arithmetic. With
    ``split_rows``/``payloads`` (tiered admission active) each tick
    expands into the hosts' per-tier sub-requests and every Response
    carries its tier name as ``output`` — the same tagging the host
    engines get from ``QueueWorkload`` echoing ``Request.payload`` —
    so :func:`repro.fleet.degrade.tier_latency_percentiles` works on
    jax telemetry within the engine's documented tolerances.

    ``evac_col`` is the per-tick cost *voided* without being served:
    chaos evacuations (the whole pending queue flushed by a kill edge)
    plus deadline expiries (``QueueWorkload.expire``). The dispatched
    axis becomes ``S + cumsum(void)``, and a request whose cumulative
    tail lands inside its crossing tick's void jump emits no Response.
    Voiding happens *before* serving within a tick (kill edges and
    expiry both run pre-routing), so the in-tick order of the jump vs
    the served mass is void-first — a request past the jump at an
    expiry tick genuinely completed (unlike a kill tick, where the
    rack's unit cap is 0 and nothing serves)."""
    if evac_col is not None:
        s_col = s_col + np.cumsum(evac_col)  # reprolint: ok[RPL001] jax tolerance-parity: prefix cumsum replays the device carry's sequential adds
    sub, a_sub, j, tiers = _completions(work_col, s_col, split_rows)
    t_all = len(ts)
    done: List[Tuple[int, int, Response]] = []
    for k in range(len(sub)):
        jj = int(j[k])
        if jj >= t_all:
            continue  # never completed (undrained overload)
        s_prev = float(s_col[jj - 1]) if jj > 0 else 0.0
        void_j = float(evac_col[jj]) if evac_col is not None else 0.0
        a_k = float(a_sub[k])
        if void_j > 0.0 and a_k - _cum_tol(a_k) <= s_prev + void_j:
            continue  # voided (evacuated or expired), not served
        # the void jump consumes no serving capacity: the mass served
        # *into* this request excludes it
        s_prev += void_j
        arrival = float(ts[sub[k]]) + 0.5 * dt
        cap_j = float(cap_col[jj])
        if cap_j > 0.0:
            frac = min(a_k - s_prev, cap_j) / cap_j
        else:
            frac = 1.0
        service_s = 1.0 / (unit_rate * max(float(perf_col[jj]), 1e-9))
        finish = max(float(ts[jj]) + frac * dt, arrival + service_s)
        out = None
        if tiers is not None and payloads is not None:
            tk = int(tiers[k])
            if 0 <= tk < len(payloads):
                out = payloads[tk]
        done.append(
            (jj, k,
             Response(rid=k, arrival_s=arrival, finish_s=finish, output=out))
        )
    done.sort(key=lambda it: (it[0], it[1]))  # completion order, FIFO in-tick
    return [resp for _, _, resp in done]


class _ThermalState:
    """Host mirror of the stacked RC state (what the sanitizer reads)."""

    def __init__(self, layout: Any) -> None:
        self.layout = layout
        self.t_die = layout.t_amb[layout.rack_u].copy()
        self.t_pcb = layout.t_amb[layout.rack_g].copy()
        self.latched = np.zeros(layout.n_flat_units, bool)


# ---------------------------------------------------------------------------
# the engine


class _JaxFleetEngine:
    """Block-scanned jit engine behind ``Fleet(backend="jax")``.

    Holds all mutable simulation state on the host between ``play``
    calls (so ``play_trace`` composes cumulatively like the other
    engines) and runs each call as jitted ``lax.scan`` blocks. Routing
    happens *in-scan* — the fleet's router object is only used to pick
    the branchless router kind, so only the built-in routers (and
    built-in governors) are supported; anything else must use
    ``backend="vector"``.
    """

    backend = "jax"

    def __init__(
        self,
        racks: "Sequence[RackConfig]",
        dt_s: float,
        idle_units_off: bool,
        router: Any,
    ) -> None:
        arr = build_fleet_arrays(racks, idle_units_off)
        if arr.generic:
            kinds = sorted({type(g).__name__ for _, g in arr.generic})
            raise ValueError(
                "backend='jax' compiles the governor passes and only "
                "supports the built-in governors (fixed / race-to-idle "
                f"/ schedutil / thermal-aware); got {kinds} — use "
                "backend='vector' for generic governors"
            )
        rname = getattr(router, "name", type(router).__name__)
        if rname not in ROUTER_KINDS:
            raise ValueError(
                "backend='jax' routes in-scan and only knows "
                f"{sorted(ROUTER_KINDS)}; got router {rname!r} — use "
                "backend='vector' for custom routers"
            )
        self.arrays = arr
        self.dt_s = float(dt_s)
        self.now = 0.0
        self.n_racks = arr.n_racks
        # sanitizer-facing static surface
        self.K = arr.K
        self.has_table = arr.has_table
        self._params = _base_params(arr, dt_s, _full_load_j_per_req(racks))
        self._params["router_kind"] = np.int64(ROUTER_KINDS[rname])
        self._params["pa_util_target"] = float(
            getattr(router, "util_target", 0.85)
        )
        self._hedge_any = arr.any_hedge
        # set by Fleet._wire_obs; rows are expanded host-side after play
        self.obs: Optional[Any] = None
        # mutable per-rack state (mirrors _fresh_carry)
        n = arr.n_racks
        self._B = np.zeros(n)
        self._A = np.zeros(n)
        self._S = np.zeros(n)
        self.opp = arr.opp0.copy()
        self._backlog = np.zeros(n, bool)
        self.active = arr.minq.copy()
        self._last_down = np.full(n, -1e9)
        self.scale_events = np.zeros(n, np.int64)
        self.hedged_cnt = np.zeros(n, np.int64)
        self.energy = np.zeros(n)
        self.unit_energy = np.zeros(n)
        self.served_acc = np.zeros(n)
        self.therm: Optional[_ThermalState] = (
            _ThermalState(arr.thermal) if arr.thermal is not None else None
        )
        self._A_buf = np.full((n, 0), np.inf)
        self._arr_buf = np.full((n, 0), np.inf)
        self._ptr = 0
        # chaos surface (inert until Fleet calls set_chaos): the lowered
        # schedule, the cumulative evacuated-cost carry, and the same
        # counters the scalar/vector engines expose to _build_telemetry
        self._chaos: Optional[Any] = None
        self.chaos_on_kill = "respill"
        self._E = np.zeros(n)
        self.chaos_dead = np.zeros(n, np.int64)
        self.chaos_fan = np.zeros(n, bool)
        self.chaos_cap = np.zeros(n, bool)
        self.chaos_evac_cost = 0.0
        self.chaos_evac_by_rack = np.zeros(n)
        self.chaos_dropped = 0
        self.chaos_dropped_cost = 0.0
        self.chaos_respilled = 0
        self.chaos_respilled_cost = 0.0
        # degrade surface (inert until Fleet calls set_degrade)
        self._degrade: Optional[Any] = None
        # cumulative per-tick emitted history (for telemetry rebuilds)
        self._t_hist: List[float] = []
        self._hist: Dict[str, List[np.ndarray]] = {}

    def set_chaos(self, lowered: Any) -> None:
        """Wire a :class:`~repro.fleet.chaos.LoweredChaos` schedule.

        Called by ``Fleet.__init__``; the schedule is re-sampled into
        per-tick mask rows (``LoweredChaos.rows``) block by block at
        ``play`` time so the jitted scan stays shape-static — the same
        compiled program serves every schedule."""
        self._chaos = lowered if lowered.any_events() else None
        self.chaos_on_kill = lowered.on_kill

    def set_degrade(self, lowered: Any) -> None:
        """Wire a :class:`~repro.fleet.degrade.LoweredDegrade` plan.

        Called by ``Fleet.__init__``. The control plane runs in-scan;
        the host keeps carry mirrors plus the same cumulative counter
        attributes :class:`~repro.fleet.degrade.DegradeDriver` exposes,
        so ``Fleet._build_telemetry`` reads either source unchanged.
        The scan routes the admitted fleet total; per-tier request
        shape is recovered host-side from the emitted ``dg_adm`` /
        ``dg_respill`` rows (see :meth:`_tier_split_rows`), so
        responses carry tier payloads and sub-request counts match the
        host engines within the documented tolerances."""
        self._degrade = lowered
        n = self.n_racks
        nt = max(lowered.n_tiers, 1)
        self._dg_ring = np.zeros(
            (lowered.ring_slots, nt, lowered.retry.max_attempts))
        self._dg_brk = np.zeros(n, np.int64)
        self._dg_since = np.zeros(n, np.int64)
        self._dg_last_live = np.full(n, -1, np.int64)
        self._dg_opens = np.int64(0)
        self._dg_shed_by_tier = np.zeros(nt)
        self._dg_retried = np.float64(0.0)
        self._dg_retry_dropped = np.float64(0.0)
        self._dg_W = np.zeros((max(lowered.deadline_lag, 1), n))
        self._dg_A_lag = np.zeros(n)
        self._dg_D = np.zeros(n)
        # telemetry mirrors (recomputed from history after every play)
        self.shed_by_tier = np.zeros(nt)
        self.shed_cost = 0.0
        self.shed_cost_t = np.zeros(0)
        self.retried_cost = 0.0
        self.retry_dropped_cost = 0.0
        self.breaker_opens = 0
        self.breaker_state_t = np.zeros((0, n), np.int64)
        self.degrade_expired = 0
        self.degrade_expired_cost = 0.0
        self.degrade_expired_by_rack = np.zeros(n)

    # -- sanitizer / Fleet.view surface ---------------------------------
    def queued_cost(self) -> np.ndarray:
        return self._B.copy()

    def active_units(self) -> np.ndarray:
        return self.active.copy()

    # -------------------------------------------------------------------
    def _carry(self, hedge_on: bool) -> Dict[str, Any]:
        c: Dict[str, Any] = {
            "t": np.float64(self.now),
            "B": self._B,
            "A": self._A,
            "S": self._S,
            "opp": self.opp,
            "backlog": self._backlog,
            "active": self.active,
            "last_down": self._last_down,
            "scale_events": self.scale_events,
            "hedged": self.hedged_cnt,
            "energy": self.energy,
            "unit_energy": self.unit_energy,
            "served": self.served_acc,
        }
        if self.therm is not None:
            c["t_die"] = self.therm.t_die
            c["t_pcb"] = self.therm.t_pcb
            c["latched"] = self.therm.latched
        if hedge_on:
            c["A_buf"] = self._A_buf
            c["arr_buf"] = self._arr_buf
            c["ptr"] = np.int64(self._ptr)
        if self._chaos is not None:
            c["E"] = self._E
        if self._degrade is not None:
            c["dg_tick"] = np.int64(len(self._t_hist))
            c["dg_brk"] = self._dg_brk
            c["dg_since"] = self._dg_since
            c["dg_last_live"] = self._dg_last_live
            c["dg_opens"] = self._dg_opens
            c["dg_ring"] = self._dg_ring
            c["dg_shed_by_tier"] = self._dg_shed_by_tier
            c["dg_retried"] = self._dg_retried
            c["dg_retry_dropped"] = self._dg_retry_dropped
            c["dg_D"] = self._dg_D
            if self._degrade.deadline_lag > 0:
                c["dg_A_lag"] = self._dg_A_lag
                c["dg_W"] = self._dg_W
        return c

    def _full(self, key: str) -> np.ndarray:
        rows = self._hist.get(key)
        if not rows:
            return np.zeros((0, self.n_racks))
        return np.concatenate(rows, axis=0)

    # -------------------------------------------------------------------
    def play(
        self, trace_rps: Sequence[float], drain: bool = True
    ) -> Tuple[np.ndarray, np.ndarray, int, Optional[bool]]:
        """Run the whole trace (plus post-trace drain) in one shot.

        Returns ``(assigned_rps, queued_rows, n_drain_ticks, drained)``
        with one row per simulated tick; ``drained`` is ``None`` when
        the call simulated no ticks at all.
        """
        with enable_x64():
            return self._play(np.asarray(trace_rps, float), drain)

    def _play(
        self, trace: np.ndarray, drain: bool
    ) -> Tuple[np.ndarray, np.ndarray, int, Optional[bool]]:
        dt = self.dt_s
        t_len = len(trace)
        n = self.n_racks
        if self._hedge_any and t_len > 0:
            pad = np.full((n, t_len), np.inf)
            self._A_buf = np.concatenate([self._A_buf, pad], axis=1)
            self._arr_buf = np.concatenate([self._arr_buf, pad.copy()], axis=1)
        hedge_on = self._hedge_any and self._A_buf.shape[1] > 0
        chaos = self._chaos
        degrade = self._degrade
        dims = _make_dims(
            self.arrays, dt, hedge_on,
            emit_obs=self.obs is not None,
            chaos_on=chaos is not None,
            degrade=degrade,
        )
        params = self._params
        if chaos is not None or degrade is not None:
            params = dict(params)
        if chaos is not None:
            params["chaos_respill"] = np.float64(
                1.0 if self.chaos_on_kill == "respill" else 0.0
            )
        if degrade is not None:
            params["dg_shares"] = degrade.shares
            params["dg_budgets"] = degrade.budgets
            brk_cfg = degrade.policy.breaker
            if brk_cfg is not None:
                params["dg_open_after"] = np.float64(brk_cfg.open_after_s)
                params["dg_close_below"] = np.float64(brk_cfg.close_below_s)
                params["dg_probe"] = np.float64(brk_cfg.probe_fraction)
                params["dg_cooldown_ticks"] = np.int64(
                    degrade.cooldown_ticks)
                params["dg_fail_timeout_ticks"] = np.int64(
                    degrade.fail_timeout_ticks)

        def chaos_xs(t0: float) -> Dict[str, np.ndarray]:
            """Per-tick mask rows for one block starting at ``t0``.
            Live ticks are a prefix of every block, so tick ``i`` runs
            at exactly ``t0 + i*dt`` — rows beyond the live prefix are
            masked out by the scan's carry-through."""
            assert chaos is not None
            rows = chaos.rows(t0, _BLOCK, dt)
            return {
                "chaos_dead": rows["dead"],
                "chaos_fan": rows["fan_fail"],
                "chaos_cap": rows["power_cap"],
                "chaos_kill": rows["kill_edge"],
            }

        dg_xs_on = degrade is not None and degrade.admission_on

        def degrade_xs(tick0: int) -> Dict[str, np.ndarray]:
            """Retry-delay rows for one block starting at global tick
            ``tick0`` — resamplable like ``chaos_xs`` (row k depends
            only on the absolute tick index, so the drain rewind can
            reuse the block verbatim)."""
            assert degrade is not None
            return {"dg_dticks": degrade.retry_rows(tick0, _BLOCK)}

        carry = self._carry(hedge_on)
        cur_t = self.now
        tick_base = len(self._t_hist)
        zeros = np.zeros(_BLOCK)
        falses = np.zeros(_BLOCK, bool)
        kept: List[Dict[str, np.ndarray]] = []
        pos = 0
        while pos < t_len:
            blk = min(_BLOCK, t_len - pos)
            rps = np.zeros(_BLOCK)
            rps[:blk] = trace[pos : pos + blk]
            live = np.zeros(_BLOCK, bool)
            live[:blk] = True
            xs = {"rps": rps, "live": live, "is_trace": live}
            if chaos is not None:
                xs.update(chaos_xs(cur_t))
            if dg_xs_on:
                xs.update(degrade_xs(tick_base + pos))
            carry, ys = _RUN(params, carry, xs, dims=dims)
            kept.append(_host_rows(ys, blk))
            pos += blk
            cur_t += blk * dt

        def ring_idle(rows: Dict[str, np.ndarray]) -> np.ndarray:
            """Per-tick 'retry ring is empty' mask (all-true without
            degrade) — a drain tick only starts idle when no shed mass
            is still waiting for its backoff slot."""
            if degrade is None:
                return np.ones(len(rows["empty"]), bool)
            return np.asarray(rows["dg_ring_mass"]) <= 0.0

        if kept:
            all_empty = bool(
                kept[-1]["empty"][-1].all() and ring_idle(kept[-1])[-1]
            )
        else:
            all_empty = bool(np.all(self._B <= 0.0)) and (
                degrade is None or float(self._dg_ring.sum()) <= 0.0  # reprolint: ok[RPL001] zero-test only: sum()<=0 iff all nonnegative ring slots are 0, order-free
            )
        drained: Optional[bool]
        if drain:
            # keep ticking until the first tick that starts fully idle
            # (inclusive) — the same stop tick Fleet.play_trace's
            # queued/concurrency break lands on — bounded by the same
            # 10x-trace safety cap
            cap_ticks = 10 * t_len + 100
            done = 0
            found = False
            while done < cap_ticks and not found:
                blk = min(_BLOCK, cap_ticks - done)
                live = np.zeros(_BLOCK, bool)
                live[:blk] = True
                xs = {"rps": zeros, "live": live, "is_trace": falses}
                if chaos is not None:
                    # the rewind re-runs the same block with a shorter
                    # live prefix, so the rows must be reused verbatim
                    xs_chaos = chaos_xs(cur_t)
                    xs.update(xs_chaos)
                if dg_xs_on:
                    xs_dg = degrade_xs(tick_base + t_len + done)
                    xs.update(xs_dg)
                carry0 = carry
                carry, ys = _RUN(params, carry0, xs, dims=dims)
                rows = _host_rows(ys, blk)
                allm = rows["empty"].all(axis=1) & ring_idle(rows)
                start_idle = np.concatenate(([all_empty], allm[:-1]))
                idle = np.nonzero(start_idle)[0]
                if len(idle):
                    stop = int(idle[0])
                    live2 = np.zeros(_BLOCK, bool)
                    live2[: stop + 1] = True
                    xs2 = {"rps": zeros, "live": live2, "is_trace": falses}
                    if chaos is not None:
                        xs2.update(xs_chaos)
                    if dg_xs_on:
                        xs2.update(xs_dg)
                    carry, _ = _RUN(params, carry0, xs2, dims=dims)
                    kept.append({k: v[: stop + 1] for k, v in rows.items()})
                    found = True
                else:
                    kept.append(rows)
                    all_empty = bool(allm[-1])
                    done += blk
                    cur_t += blk * dt
            drained = found
        elif t_len == 0:
            drained = None
        else:
            last = kept[-1]
            drained = bool(
                last["empty"][-1].all()
                and not (last["used"][-1] > 0.0).any()
                and ring_idle(last)[-1]
            )
        # pull the final carry back into host state
        fin = jax.device_get(carry)
        self.now = float(fin["t"])
        self._B = np.asarray(fin["B"])
        self._A = np.asarray(fin["A"])
        self._S = np.asarray(fin["S"])
        self.opp = np.asarray(fin["opp"])
        self._backlog = np.asarray(fin["backlog"])
        self.active = np.asarray(fin["active"])
        self._last_down = np.asarray(fin["last_down"])
        self.scale_events = np.asarray(fin["scale_events"])
        self.hedged_cnt = np.asarray(fin["hedged"])
        self.energy = np.asarray(fin["energy"])
        self.unit_energy = np.asarray(fin["unit_energy"])
        self.served_acc = np.asarray(fin["served"])
        if self.therm is not None:
            self.therm.t_die = np.asarray(fin["t_die"])
            self.therm.t_pcb = np.asarray(fin["t_pcb"])
            self.therm.latched = np.asarray(fin["latched"])
        if hedge_on:
            self._A_buf = np.asarray(fin["A_buf"])
            self._arr_buf = np.asarray(fin["arr_buf"])
            self._ptr = int(fin["ptr"])
        if chaos is not None:
            self._E = np.asarray(fin["E"])
        if degrade is not None:
            self._dg_brk = np.asarray(fin["dg_brk"])
            self._dg_since = np.asarray(fin["dg_since"])
            self._dg_last_live = np.asarray(fin["dg_last_live"])
            self._dg_opens = np.int64(fin["dg_opens"])
            self._dg_ring = np.asarray(fin["dg_ring"])
            self._dg_shed_by_tier = np.asarray(fin["dg_shed_by_tier"])
            self._dg_retried = np.float64(fin["dg_retried"])
            self._dg_retry_dropped = np.float64(fin["dg_retry_dropped"])
            self._dg_D = np.asarray(fin["dg_D"])
            if degrade.deadline_lag > 0:
                self._dg_A_lag = np.asarray(fin["dg_A_lag"])
                self._dg_W = np.asarray(fin["dg_W"])
        # append this call's rows to the cumulative history
        if kept:
            rows_all = {k: np.concatenate([r[k] for r in kept]) for k in kept[0]}
            n_rows = int(rows_all["empty"].shape[0])
        else:
            rows_all = {}
            n_rows = 0
        t0 = self.now - n_rows * dt
        if n_rows:
            self._t_hist.extend((t0 + np.arange(n_rows) * dt).tolist())
            for k, v in rows_all.items():
                self._hist.setdefault(k, []).append(v)
        # queue depths come from the *full* history (cumulative S/A);
        # under chaos the dispatched axis is S + cumsum(evac) — a kill
        # edge drains the queue count to zero the same tick, exactly
        # like QueueWorkload.evacuate clearing the scalar queue
        work_all = self._full("work")
        s_all = self._full("S")
        # the dispatched axis adds every kind of voided mass: chaos
        # evacuations and deadline expiries both clear queued cost
        # without serving it (a kill edge zeroes B before expiry runs,
        # so the two are never nonzero on the same (tick, rack))
        evac_all = (
            self._full("evac")
            if chaos is not None and "evac" in self._hist
            else None
        )
        exp_all = (
            self._full("dg_expired")
            if degrade is not None and "dg_expired" in self._hist
            else None
        )
        void_all = None
        if evac_all is not None or exp_all is not None:
            void_all = np.zeros_like(work_all)
            if evac_all is not None:
                void_all = void_all + evac_all
            if exp_all is not None:
                void_all = void_all + exp_all
            s_all = s_all + np.cumsum(void_all, axis=0)  # reprolint: ok[RPL001] jax tolerance-parity: prefix cumsum replays the device carry's sequential adds
        split_rows = self._tier_split_rows()
        if evac_all is not None:
            self._update_chaos_counters(work_all, s_all, evac_all, split_rows)
        if degrade is not None:
            self._update_degrade_counters(work_all, s_all, exp_all, split_rows)
        queued_rows = np.zeros((n_rows, n), np.int64)
        for r in range(n):
            q = _queued_for_rack(work_all[:, r], s_all[:, r], split_rows)
            if n_rows:
                queued_rows[:, r] = q[-n_rows:]
        assigned = (
            rows_all["assign"] if n_rows else np.zeros((0, n))
        )
        if chaos is not None and n_rows:
            # host mirrors of the mask state (Fleet.view / telemetry):
            # the last applied masks are the ones sampled at the final
            # tick's *start*, same as the scalar/vector drivers
            d_fin, f_fin, c_fin = chaos.masks_at(self.now - dt)
            self.chaos_dead = d_fin
            self.chaos_fan = f_fin
            self.chaos_cap = c_fin
        return assigned, queued_rows, n_rows - t_len, drained

    def _update_chaos_counters(
        self,
        work_all: np.ndarray,
        s_eff_all: np.ndarray,
        evac_all: np.ndarray,
        split_rows: Optional[np.ndarray] = None,
    ) -> None:
        """Recompute the cumulative drop/respill accounting from the
        full emitted history (idempotent across ``play`` calls).

        Costs are the evacuated mass itself; request counts come from
        the same host reconstruction that builds Response lists — a
        submission whose crossing tick carries an evacuation was voided
        by the kill, and ``on_kill`` decides which bucket it lands in.
        ``s_eff_all`` must already include the evacuation cumsum.
        ``split_rows`` (tiered admission) expands ticks into the hosts'
        per-tier sub-requests so voided *counts* match."""
        self.chaos_evac_by_rack = evac_all.sum(axis=0)  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
        self.chaos_evac_cost = float(self.chaos_evac_by_rack.sum())  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
        t_all = evac_all.shape[0]
        n_voided = 0
        for r in range(self.n_racks):
            ecol = evac_all[:, r]
            if not ecol.any():
                continue
            _, _, j, _ = _completions(work_all[:, r], s_eff_all[:, r],
                                      split_rows)
            jv = np.clip(j, 0, t_all - 1)
            n_voided += int(np.count_nonzero((j < t_all) & (ecol[jv] > 0.0)))
        if self.chaos_on_kill == "respill":
            self.chaos_respilled = n_voided
            self.chaos_respilled_cost = self.chaos_evac_cost
            self.chaos_dropped = 0
            self.chaos_dropped_cost = 0.0
        else:
            self.chaos_dropped = n_voided
            self.chaos_dropped_cost = self.chaos_evac_cost
            self.chaos_respilled = 0
            self.chaos_respilled_cost = 0.0

    def _update_degrade_counters(
        self,
        work_all: np.ndarray,
        s_eff_all: np.ndarray,
        exp_all: Optional[np.ndarray],
        split_rows: Optional[np.ndarray] = None,
    ) -> None:
        """Recompute the cumulative degradation accounting from the
        full emitted history (idempotent across ``play`` calls), under
        the same attribute names :class:`DegradeDriver` exposes.

        Expired request *counts* come from the host reconstruction: a
        submission whose crossing tick carries an expiry, with its
        cumulative tail inside that tick's voided jump, was abandoned
        past deadline rather than served. ``s_eff_all`` must already
        include every void cumsum (evacuations + expiries)."""
        if "dg_shed" in self._hist:
            shed = np.concatenate(self._hist["dg_shed"], axis=0)
            self.shed_by_tier = shed.sum(axis=0)  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
            self.shed_cost_t = shed.sum(axis=1)  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
            self.shed_cost = float(self.shed_by_tier.sum())  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
        if "dg_retried" in self._hist:
            self.retried_cost = float(
                np.sum(np.concatenate(self._hist["dg_retried"]))  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
            )
            self.retry_dropped_cost = float(
                np.sum(np.concatenate(self._hist["dg_retry_dropped"]))  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
            )
        if "dg_brk" in self._hist:
            brk = np.concatenate(self._hist["dg_brk"], axis=0)
            self.breaker_state_t = brk.astype(np.int64)
            prev = np.vstack(
                [np.zeros((1, brk.shape[1]), np.int64), brk[:-1]]
            )
            self.breaker_opens = int(
                ((brk == BRK_OPEN) & (prev != BRK_OPEN)).sum()  # reprolint: ok[RPL001] bool edge count, exact in any order
            )
        if exp_all is None:
            return
        self.degrade_expired_by_rack = exp_all.sum(axis=0)  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
        self.degrade_expired_cost = float(self.degrade_expired_by_rack.sum())  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished host rows
        t_all = exp_all.shape[0]
        n_expired = 0
        for r in range(self.n_racks):
            ecol = exp_all[:, r]
            if not ecol.any():
                continue
            s_col = s_eff_all[:, r]
            _, a_sub, j, _ = _completions(work_all[:, r], s_col, split_rows)
            for k in range(len(a_sub)):
                jj = int(j[k])
                if jj >= t_all or ecol[jj] <= 0.0:
                    continue
                s_prev = float(s_col[jj - 1]) if jj > 0 else 0.0
                a_k = float(a_sub[k])
                if a_k - _cum_tol(a_k) <= s_prev + float(ecol[jj]):
                    n_expired += 1
        self.degrade_expired = n_expired

    def _tier_split_rows(self) -> Optional[np.ndarray]:
        """Per-tick tier fractions of the routed total, shape
        ``(T, n_tiers + 1)`` (last column = untiered chaos respill) —
        the host-side mirror of the ``frac`` vector
        :meth:`DegradeDriver.pre_route` hands to ``_tier_requests``:
        ``frac[k] = admitted_k / total``, ``frac[-1] = respill / total``.
        ``None`` when tiered admission is off (reconstruction then
        keeps its one-request-per-tick fluid shape)."""
        if self._degrade is None or "dg_adm" not in self._hist:
            return None
        adm = self._full("dg_adm")  # (T, n_tiers)
        respill = self._full("dg_respill")  # (T,)
        total = self._full("dg_admitted")  # (T,)
        rows = np.zeros((adm.shape[0], adm.shape[1] + 1))
        flow = total > 0.0
        rows[flow, :-1] = adm[flow] / total[flow, None]
        rows[flow, -1] = respill[flow] / total[flow]
        return rows

    def _tier_payloads(self) -> List[Optional[str]]:
        """Tier payload names + trailing ``None`` for the untiered
        respill column — same list ``Fleet`` hands the host engines."""
        return [t.name for t in self._degrade.tiers] + [None]

    # -------------------------------------------------------------------
    def per_rack_telemetry(self) -> List[Telemetry]:
        ts = np.asarray(self._t_hist, float)
        work = self._full("work")
        s_rows = self._full("S")
        cap = self._full("cap")
        perf = self._full("perf")
        rate = self._full("rate")
        active = self._full("active")
        power = self._full("power")
        util = self._full("util")
        empty = np.zeros(0)
        th = self.arrays.thermal
        if th is not None and "temp" in self._hist:
            fan: Optional[np.ndarray] = np.concatenate(self._hist["fan"])
            temp: Optional[np.ndarray] = np.concatenate(self._hist["temp"])
            thr: Optional[np.ndarray] = np.concatenate(self._hist["thr"])
            col_of = {int(r): j for j, r in enumerate(th.t_idx)}
        else:
            fan = temp = thr = None
            col_of = {}
        evac = (
            self._full("evac")
            if self._chaos is not None and "evac" in self._hist
            else None
        )
        # deadline-expired mass voids requests the same way (see
        # _responses_for_rack's evac_col contract)
        if self._degrade is not None and "dg_expired" in self._hist:
            exp = self._full("dg_expired")
            evac = exp if evac is None else evac + exp
        split_rows = self._tier_split_rows()
        payloads = self._tier_payloads() if split_rows is not None else None
        arr = self.arrays
        out: List[Telemetry] = []
        for r in range(self.n_racks):
            responses = _responses_for_rack(
                ts,
                self.dt_s,
                work[:, r],
                s_rows[:, r],
                cap[:, r],
                perf[:, r],
                float(arr.unit_rate[r]),
                evac_col=None if evac is None else evac[:, r],
                split_rows=split_rows,
                payloads=payloads,
            )
            p50, p99 = latency_percentiles(responses)
            j = col_of.get(r)
            if j is None or temp is None or thr is None or fan is None:
                temp_r = thr_r = fan_r = empty
            else:
                temp_r = temp[:, j].copy()
                thr_r = thr[:, j].astype(float)
                fan_r = fan[:, j].copy()
            out.append(
                Telemetry(
                    time_s=ts,
                    offered_load=rate[:, r].copy(),
                    active_units=active[:, r].astype(float),
                    power_w=power[:, r].copy(),
                    utilization=util[:, r].copy(),
                    served=float(self.served_acc[r]),
                    hedged=int(self.hedged_cnt[r]),
                    scale_events=int(self.scale_events[r]),
                    p50_latency_s=p50,
                    p99_latency_s=p99,
                    energy_j=float(self.energy[r]),
                    unit_energy_j=float(self.unit_energy[r]),
                    responses=responses,
                    workload={
                        "name": arr.names[r],
                        "kind": "fluid",
                        "unit_rate": float(arr.unit_rate[r]),
                    },
                    max_temp_c=temp_r,
                    throttled_units=thr_r,
                    fan_power_w=fan_r,
                )
            )
        return out


# ---------------------------------------------------------------------------
# batched config sweeps


@dataclass
class SweepConfig:
    """One point of a batched fig15-style policy sweep.

    Scalars multiply the corresponding per-rack base arrays (so a
    heterogeneous fleet keeps its shape); ``hedge_after_s`` of ``None``
    keeps each rack's own policy deadline, ``float("inf")`` disables
    hedging for the config, any finite value overrides every rack. The
    power-aware router runs at its default ``util_target`` (0.85).
    """

    router: str = "join-shortest-queue"
    headroom_scale: float = 1.0
    sched_headroom_scale: float = 1.0
    hedge_after_s: Optional[float] = None
    unit_rate_scale: float = 1.0
    trace_scale: float = 1.0
    name: str = ""


def sweep(
    racks: "Sequence[RackConfig]",
    configs: Sequence[SweepConfig],
    trace_rps: Sequence[float],
    dt_s: float = 60.0,
    idle_units_off: bool = True,
    drain_ticks: Optional[int] = None,
) -> List[Dict[str, Any]]:
    """Run every config over the trace as **one** batched XLA program.

    The whole scan is ``vmap``-ed over the config axis and dispatched
    in chunks; with more than one host device (see
    ``repro.config.set_host_device_count``) each chunk is additionally
    ``pmap``-sharded across devices. Every config runs the full trace
    plus ``drain_ticks`` idle ticks (default ``len(trace) + 100``);
    per-config results are trimmed at each config's own drain point, so
    summaries match a per-config ``Fleet(backend="jax").play_trace``
    within jit-determinism (a config that fails to drain inside the
    window reports ``drained=False``).

    Returns one summary dict per config (same keys across configs).
    """
    trace = np.asarray(trace_rps, float)
    assert len(configs) > 0, "need at least one sweep config"
    assert len(trace) > 0, "need a non-empty trace"
    with enable_x64():
        return _sweep(racks, list(configs), trace, dt_s, idle_units_off,
                      drain_ticks)


def _sweep(
    racks: "Sequence[RackConfig]",
    configs: List[SweepConfig],
    trace: np.ndarray,
    dt_s: float,
    idle_units_off: bool,
    drain_ticks: Optional[int],
) -> List[Dict[str, Any]]:
    arr = build_fleet_arrays(racks, idle_units_off)
    if arr.generic:
        raise ValueError(
            "sweep() only supports the built-in governors; use the "
            "vector engine for generic governors"
        )
    for cfg in configs:
        if cfg.router not in ROUTER_KINDS:
            raise ValueError(
                f"unknown sweep router {cfg.router!r}; "
                f"choose from {sorted(ROUTER_KINDS)}"
            )
    n = arr.n_racks
    t_len = len(trace)
    n_drain = t_len + 100 if drain_ticks is None else int(drain_ticks)
    total_ticks = t_len + n_drain
    n_cfg = len(configs)
    base = _base_params(arr, dt_s, _full_load_j_per_req(racks))
    base_dl = np.asarray(base["hedge_deadline"], float)
    hedge_dls = np.stack(
        [
            base_dl
            if cfg.hedge_after_s is None
            else np.full(n, float(cfg.hedge_after_s))
            for cfg in configs
        ]
    )
    hedge_on = bool(np.isfinite(hedge_dls).any())
    dims = _make_dims(arr, dt_s, hedge_on)
    params = dict(base)
    params["router_kind"] = np.array(
        [ROUTER_KINDS[cfg.router] for cfg in configs], np.int64
    )
    params["trace_scale"] = np.array(
        [float(cfg.trace_scale) for cfg in configs]
    )
    params["unit_rate"] = np.stack(
        [arr.unit_rate * cfg.unit_rate_scale for cfg in configs]
    )
    params["capacity_rps"] = np.stack(
        [
            arr.n_units.astype(float) * arr.unit_rate * cfg.unit_rate_scale
            for cfg in configs
        ]
    )
    params["headroom"] = np.stack(
        [arr.headroom * cfg.headroom_scale for cfg in configs]
    )
    params["sched_headroom"] = np.stack(
        [arr.sched_headroom * cfg.sched_headroom_scale for cfg in configs]
    )
    params["hedge_deadline"] = hedge_dls
    batched = {
        "router_kind",
        "trace_scale",
        "unit_rate",
        "capacity_rps",
        "headroom",
        "sched_headroom",
        "hedge_deadline",
    }
    axes = {k: (0 if k in batched else None) for k in params}
    carry = _fresh_carry(arr, hedge_on, t_len)
    rps = np.zeros(total_ticks)
    rps[:t_len] = trace
    live = np.ones(total_ticks, bool)
    is_trace = np.zeros(total_ticks, bool)
    is_trace[:t_len] = True
    xs = {"rps": rps, "live": live, "is_trace": is_trace}

    ndev = jax.local_device_count()
    if ndev > 1:
        per = max(1, min(4, -(-n_cfg // ndev)))
        step_sz = ndev * per
    else:
        per = 0
        step_sz = min(8, n_cfg)
    cache_key = (
        dims,
        t_len,
        total_ticks,
        ndev,
        per,
        step_sz,
        tuple(sorted(params)),
        tuple(sorted(carry)),
    )
    mapped = _MAPPED.get(cache_key)
    if mapped is None:

        def run(
            p: Dict[str, Any], c: Dict[str, Any], x: Dict[str, Any]
        ) -> Dict[str, Any]:
            _, ys = _scan_steps(p, c, x, dims)
            return _device_summary(ys, t_len, p["dt"], p["unit_rate"])

        inner = jax.vmap(run, in_axes=(axes, None, None))
        if ndev > 1:
            mapped = jax.pmap(inner, in_axes=(axes, None, None))
        else:
            mapped = jax.jit(inner)
        _MAPPED[cache_key] = mapped
    rows: List[Dict[str, np.ndarray]] = []
    i = 0
    while i < n_cfg:
        sel = list(range(i, min(i + step_sz, n_cfg)))
        n_sel = len(sel)
        sel = sel + [sel[-1]] * (step_sz - n_sel)
        pc = {
            k: (np.asarray(params[k])[sel] if k in batched else params[k])
            for k in params
        }
        if ndev > 1:
            pc = {
                k: (
                    v.reshape((ndev, per) + v.shape[1:])
                    if k in batched
                    else v
                )
                for k, v in pc.items()
            }
        host = jax.device_get(mapped(pc, carry, xs))
        host = {
            k: np.asarray(v).reshape((step_sz,) + np.asarray(v).shape[2:])[
                :n_sel
            ]
            for k, v in host.items()
        }
        rows.append(host)
        i += n_sel
    out: List[Dict[str, Any]] = []
    ci = 0
    for part in rows:
        for k in range(len(part["ticks"])):
            out.append(_format_row(configs[ci], ci, arr, part, k))
            ci += 1
    return out


#: compiled sweep programs keyed by (dims, shapes, device layout): a
#: repeated sweep() over the same fleet/trace shape reuses the XLA
#: executable instead of re-tracing a fresh closure
_MAPPED: Dict[Tuple[Any, ...], Any] = {}


def _pctl(flat: Any, n_ok: Any, q: float) -> Any:
    """``np.percentile(lat, q)`` (linear interpolation) on a sorted
    device vector padded with ``+inf`` past ``n_ok`` valid entries."""
    pos = (q / 100.0) * jnp.maximum(n_ok - 1, 0)
    lo = jnp.floor(pos).astype(jnp.int64)
    hi = jnp.ceil(pos).astype(jnp.int64)
    w = pos - lo.astype(jnp.float64)
    v = flat[lo] * (1.0 - w) + flat[hi] * w
    return jnp.where(n_ok > 0, v, 0.0)


def _device_summary(
    ys: Dict[str, Any], t_len: int, dt: Any, unit_rate: Any
) -> Dict[str, Any]:
    """Reduce one config's emitted rows to summary scalars **on the
    device**. Shipping the raw ``(ticks, racks)`` histories to the host
    and rebuilding Response objects costs ~10x the scan itself, so the
    sweep's host traffic is a dozen scalars per config: the per-config
    trim mask, roll-ups, and the latency reconstruction (the
    ``QueueWorkload`` completion/finish arithmetic of
    :func:`_responses_for_rack`, vectorized over all submissions) all
    run inside the compiled program."""
    total = ys["empty"].shape[0]
    allm = jnp.all(ys["empty"], axis=1)
    start_idle = jnp.concatenate([jnp.zeros(1, bool), allm[:-1]])
    drain_idle = start_idle[t_len:]
    drained = jnp.any(drain_idle)
    first = jnp.argmax(drain_idle)
    n_kept = jnp.where(drained, t_len + first + 1, total)
    tick = jnp.arange(total)
    tmask = tick < n_kept
    col = tmask[:, None]
    nk = n_kept.astype(jnp.float64)
    power_t = jnp.sum(jnp.where(col, ys["power"], 0.0), axis=1)  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished device rows
    energy_j = jnp.sum(power_t) * dt  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished device rows
    served = jnp.sum(jnp.where(col, ys["used"], 0.0))  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished device rows
    active_t = jnp.sum(jnp.where(col, ys["active"], 0), axis=1)  # reprolint: ok[RPL001] jax tolerance-parity: integer unit counts, exact in any order
    hedged = jnp.sum(jnp.where(col, ys["hedge"], 0))  # reprolint: ok[RPL001] jax tolerance-parity: int64 counters, exact in any order
    scale = jnp.sum(jnp.where(col, ys["scale"], 0))  # reprolint: ok[RPL001] jax tolerance-parity: int64 counters, exact in any order
    # latency reconstruction: one fluid request per work-carrying tick,
    # completion at the first tick whose cumulative served covers its
    # cumulative cost tail (minus the cumulative-axis forgiveness)
    work = jnp.where(col, ys["work"], 0.0)
    a = jnp.cumsum(work, axis=0)  # reprolint: ok[RPL001] jax tolerance-parity: prefix cumsum replays the device carry's sequential adds
    s_col = ys["S"]
    j = jax.vmap(
        lambda scol, keys: jnp.searchsorted(scol, keys, side="left"),
        in_axes=(1, 1),
        out_axes=1,
    )(s_col, a - _cum_tol(a))
    ok = (work > 0.0) & (j < n_kept)
    jc = jnp.clip(j, 0, total - 1)
    cap_j = jnp.take_along_axis(ys["cap"], jc, axis=0)
    perf_j = jnp.take_along_axis(ys["perf"], jc, axis=0)
    s_prev = jnp.where(
        jc > 0,
        jnp.take_along_axis(s_col, jnp.maximum(jc - 1, 0), axis=0),
        0.0,
    )
    safe_cap = jnp.where(cap_j > 0.0, cap_j, 1.0)
    frac = jnp.where(
        cap_j > 0.0, jnp.minimum(a - s_prev, cap_j) / safe_cap, 1.0
    )
    arrival = (tick.astype(jnp.float64) * dt + 0.5 * dt)[:, None]
    service = 1.0 / (unit_rate[None, :] * jnp.maximum(perf_j, 1e-9))
    finish = jnp.maximum(
        jc.astype(jnp.float64) * dt + frac * dt, arrival + service
    )
    lat = jnp.where(ok, finish - arrival, jnp.inf)
    flat = jnp.sort(lat.ravel())
    n_ok = jnp.sum(ok)  # reprolint: ok[RPL001] jax tolerance-parity: bool counter, exact in any order
    return {
        "ticks": n_kept,
        "drained": drained,
        "served": served,
        "energy_j": energy_j,
        "mean_power_w": jnp.sum(power_t) / nk,  # reprolint: ok[RPL001] jax tolerance-parity: post-hoc roll-up of finished device rows
        "peak_power_w": jnp.max(jnp.where(tmask, power_t, -jnp.inf)),
        "mean_active_units": jnp.sum(active_t).astype(jnp.float64) / nk,  # reprolint: ok[RPL001] jax tolerance-parity: integer unit counts, exact in any order
        "hedged": hedged,
        "scale_events": scale,
        "p50_latency_s": _pctl(flat, n_ok, 50.0),
        "p95_latency_s": _pctl(flat, n_ok, 95.0),
        "p99_latency_s": _pctl(flat, n_ok, 99.0),
    }


def _format_row(
    cfg: SweepConfig,
    ci: int,
    arr: FleetArrays,
    part: Dict[str, np.ndarray],
    k: int,
) -> Dict[str, Any]:
    energy_j = float(part["energy_j"][k])
    served = float(part["served"][k])
    return {
        "name": cfg.name or f"cfg{ci}",
        "router": cfg.router,
        "racks": arr.n_racks,
        "ticks": int(part["ticks"][k]),
        "served": served,
        "energy_j": energy_j,
        "energy_kwh": energy_j / 3.6e6,
        "tpe": served / max(energy_j, 1e-9),
        "mean_power_w": float(part["mean_power_w"][k]),
        "peak_power_w": float(part["peak_power_w"][k]),
        "mean_active_units": float(part["mean_active_units"][k]),
        "p50_latency_s": float(part["p50_latency_s"][k]),
        "p95_latency_s": float(part["p95_latency_s"][k]),
        "p99_latency_s": float(part["p99_latency_s"][k]),
        "hedged": int(part["hedged"][k]),
        "scale_events": int(part["scale_events"][k]),
        "drained": bool(part["drained"][k]),
    }
