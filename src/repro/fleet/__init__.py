"""``repro.fleet`` — fleet-scale simulation: N racks behind a router.

The paper measures one 60-SoC rack; this layer aggregates many such
racks behind a geo-routed load balancer so the energy-proportionality
claims can be evaluated at the scale public edge platforms run at
("millions of users"):

  * :class:`~repro.fleet.fleet.Fleet` — N racks (mixed
    :class:`~repro.core.cluster.ClusterSpec`\\ s allowed), one offered
    load, tick-by-tick routing + per-rack elastic unit governors; three
    engines behind ``backend="scalar" | "vector" | "jax"`` — the first
    two bitwise-identical, the jitted jax engine tolerance-matched;
  * :mod:`~repro.fleet.jax_engine` — ``jax.lax.scan`` engine plus the
    batched :func:`~repro.fleet.jax_engine.sweep` entry point that
    ``vmap``\\ s whole fig15-style config grids into one XLA program;
  * :mod:`~repro.fleet.router` — round-robin, join-shortest-queue
    (water-fill), and power-aware (efficiency-packed) request routers;
  * :mod:`~repro.fleet.chaos` — correlated fault injection (rack/unit
    kills, shared-fan-rail failure, rack power caps) with recovery
    metrics and seeded random schedules for the CI chaos gate;
  * :mod:`~repro.fleet.degrade` — graceful-degradation control plane:
    SLO-tiered admission, deadline load shedding, per-rack circuit
    breakers, and deterministic seeded retry, wired identically
    through all three engines;
  * :mod:`~repro.fleet.traces` — diurnal, flash-crowd, and replayed
    arrival traces, scalable to a target user population;
  * :class:`~repro.fleet.telemetry.FleetTelemetry` — fleet roll-ups
    feeding the existing energy/TCO models.

Typical use::

    from repro.core.cluster import soc_cluster
    from repro.fleet import (Fleet, PowerAwareRouter, diurnal_trace,
                             homogeneous_fleet, scale_to_users)

    racks = homogeneous_fleet(soc_cluster(), n_racks=100, unit_rate=30.0)
    fleet = Fleet(racks, router=PowerAwareRouter(), dt_s=60.0)
    trace = scale_to_users(diurnal_trace(peak_rps=1.0, hours=24),
                           users=3e6, rps_per_user=0.05)
    tel = fleet.play_trace(trace)
    print(tel.summary())
"""
from typing import Any

from repro.fleet.chaos import (
    ChaosEvent,
    ChaosMonitor,
    ChaosSchedule,
    RecoveryReport,
    chaos_seed,
    hedging_delta,
    recovery_report,
    recovery_window_p99,
)
from repro.fleet.degrade import (
    BreakerConfig,
    DegradePolicy,
    TierSpec,
    default_tiers,
    tier_latency_percentiles,
)
from repro.fleet.fleet import Fleet, RackConfig, homogeneous_fleet
from repro.fleet.router import (
    ROUTERS,
    FleetView,
    JoinShortestQueueRouter,
    PowerAwareRouter,
    RoundRobinRouter,
    Router,
)
from repro.fleet.telemetry import FleetTelemetry, empirical_proportionality
from repro.fleet.traces import (
    diurnal_trace,
    flash_crowd_trace,
    replay_trace,
    save_trace,
    scale_to_users,
)

def __getattr__(name: str) -> Any:
    # lazy: the jax sweep surface pulls in jax, which the scalar/vector
    # backends (and tier-1) must not depend on
    if name in ("SweepConfig", "sweep"):
        from repro.fleet import jax_engine

        return getattr(jax_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Fleet",
    "RackConfig",
    "homogeneous_fleet",
    "ChaosEvent",
    "ChaosSchedule",
    "ChaosMonitor",
    "RecoveryReport",
    "chaos_seed",
    "TierSpec",
    "BreakerConfig",
    "DegradePolicy",
    "default_tiers",
    "tier_latency_percentiles",
    "hedging_delta",
    "recovery_report",
    "recovery_window_p99",
    "SweepConfig",
    "sweep",
    "Router",
    "FleetView",
    "RoundRobinRouter",
    "JoinShortestQueueRouter",
    "PowerAwareRouter",
    "ROUTERS",
    "FleetTelemetry",
    "empirical_proportionality",
    "diurnal_trace",
    "flash_crowd_trace",
    "replay_trace",
    "save_trace",
    "scale_to_users",
]
