"""Shared rack-stacking for the array-based fleet engines.

Both the numpy vector engine (``backend="vector"``) and the jax engine
(``backend="jax"``) simulate the fleet as *stacked per-rack arrays*:
activation policies, OPP perf/power tables, governor classifications,
and the flattened per-die RC thermal layout. This module is the single
place that stacking happens — :func:`build_fleet_arrays` turns a rack
list into a :class:`FleetArrays` bundle, and :func:`build_thermal_layout`
flattens every thermal-modelled rack's unit/group topology into a
:class:`ThermalLayout` — so the two engines cannot drift apart in how
they read a :class:`~repro.fleet.fleet.RackConfig`.

Array *construction* here is parity-critical: the vector engine adopts
these arrays verbatim and its telemetry is compared bitwise against the
scalar engine, so values must be produced by exactly the arithmetic the
scalar runtime uses (same expressions, same order). The jax engine
consumes the same arrays but is held to tolerance-based parity (XLA
float semantics differ; see ``repro/fleet/jax_engine.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import UnitSpec
from repro.power.governor import (
    FixedFreqGovernor,
    RaceToIdleGovernor,
    SchedutilGovernor,
    ThermalAwareGovernor,
)
from repro.power.opp import OPPTable
from repro.power.thermal import ThermalModel
from repro.runtime import ScalePolicy

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.fleet.fleet import RackConfig

__all__ = [
    "GOV_NONE",
    "GOV_FIXED",
    "GOV_RACE",
    "GOV_SCHED",
    "GOV_GENERIC",
    "ThermalLayout",
    "FleetArrays",
    "build_thermal_layout",
    "build_fleet_arrays",
]

# governor kinds the stacked selection passes understand; anything else
# falls back (vector engine) to a per-rack select() call with a real
# FreqContext, or is rejected outright (jax engine).
GOV_NONE, GOV_FIXED, GOV_RACE, GOV_SCHED, GOV_GENERIC = range(5)


@dataclass
class ThermalLayout:
    """Static layout of every thermal-modelled rack, flattened.

    Per-die/per-PCB-group topology and RC parameters of all thermal
    racks concatenated in ``t_idx`` order. The mutable state
    (``t_die``/``t_pcb``/``latched``) lives with the engines; this is
    the shared read-only part both build from.
    """

    t_idx: np.ndarray  # fleet rack indices carrying a thermal model
    # per-thermal-rack RC parameters
    r_die: np.ndarray
    c_die: np.ndarray
    r_pcb0: np.ndarray
    c_pcb: np.ndarray
    t_amb: np.ndarray
    fan_low: np.ndarray
    fan_span: np.ndarray
    fan_rmin: np.ndarray
    fan_pmax: np.ndarray
    trip: np.ndarray
    release: np.ndarray
    # flat unit/group layout (racks concatenated in t_idx order)
    n_flat_units: int
    unit_starts: np.ndarray
    group_starts: np.ndarray
    rack_u: np.ndarray
    rack_g: np.ndarray
    local_idx: np.ndarray
    group_of_u: np.ndarray
    last_unit: np.ndarray
    # per-unit/per-group broadcasts of the per-rack constants
    r_die_u: np.ndarray
    c_die_u: np.ndarray
    c_pcb_g: np.ndarray
    t_amb_g: np.ndarray
    # thermal ceilings for governors: constant per rack, computed with
    # the same scalar helper the pool caches
    max_sustainable: List[int] = field(default_factory=list)

    @property
    def n_groups(self) -> int:
        return int(len(self.rack_g))

    def max_substeps(self, dt: float) -> int:
        """Static upper bound on the Euler sub-step count of any rack.

        The per-tick count depends on the fan-modulated ``r_pcb`` in
        ``[r_pcb0 * fan_rmin, r_pcb0]``; the smallest reachable time
        constant (fan flat out) gives the largest count, so a
        ``lax.fori_loop`` over this bound with per-rack live masks
        covers every tick (the jax engine needs a trace-time constant).
        """
        r_pcb_min = self.r_pcb0 * self.fan_rmin
        tau_min = np.minimum(self.r_die * self.c_die, r_pcb_min * self.c_pcb)
        denom = np.maximum(0.25 * tau_min, 1e-6)
        n_sub = np.maximum(1, (dt / denom).astype(np.int64) + 1)
        return int(n_sub.max())


def build_thermal_layout(
    racks: "Sequence[RackConfig]", t_idx: Sequence[int]
) -> ThermalLayout:
    """Flatten the thermal racks' topology + RC parameters (in ``t_idx``
    order), exactly as the stacked vector engine has always laid them
    out — the arithmetic below is byte-for-byte the former
    ``_StackedThermal.__init__``."""
    idx = np.asarray(t_idx, np.int64)
    nt = len(t_idx)
    specs = [racks[r].spec for r in t_idx]
    prms = [racks[r].thermal for r in t_idx]
    assert all(p is not None for p in prms)
    r_die = np.array([p.r_die_c_per_w for p in prms if p is not None])
    c_die = np.array([p.c_die_j_per_c for p in prms if p is not None])
    r_pcb0 = np.array([p.r_pcb_c_per_w for p in prms if p is not None])
    c_pcb = np.array([p.c_pcb_j_per_c for p in prms if p is not None])
    t_amb = np.array([p.t_ambient_c for p in prms if p is not None])
    fan_low = np.array([p.fan_t_low_c for p in prms if p is not None])
    fan_span = np.array(
        [max(p.fan_t_high_c - p.fan_t_low_c, 1e-9) for p in prms if p is not None]
    )
    fan_rmin = np.array([p.fan_r_scale_min for p in prms if p is not None])
    fan_pmax = np.array([p.fan_p_max_w for p in prms if p is not None])
    trip = np.array([p.t_trip_c for p in prms if p is not None])
    release = np.array([p.t_release_c for p in prms if p is not None])
    unit_starts: List[int] = []
    group_starts: List[int] = []  # group segment starts, flat pcb
    rack_u: List[int] = []
    rack_g: List[int] = []
    local_idx: List[int] = []
    group_of_u: List[int] = []
    last_unit = np.zeros(nt, np.int64)
    u0 = g0 = 0
    for j, spec in enumerate(specs):
        unit_starts.append(u0)
        group_starts.append(g0)
        groups = spec.groups()
        for _ in groups:
            rack_g.append(j)
        for u in range(spec.n_units):
            rack_u.append(j)
            local_idx.append(u)
            group_of_u.append(g0 + u // spec.group_size)
        last_unit[j] = u0 + spec.n_units - 1
        u0 += spec.n_units
        g0 += len(groups)
    rack_u_a = np.asarray(rack_u, np.int64)
    rack_g_a = np.asarray(rack_g, np.int64)
    max_sustainable: List[int] = []
    for r in t_idx:
        tm = ThermalModel(racks[r].spec, racks[r].thermal)
        max_sustainable.append(
            tm.max_sustainable_index(racks[r].spec.unit, racks[r].opp_table)
        )
    return ThermalLayout(
        t_idx=idx,
        r_die=r_die,
        c_die=c_die,
        r_pcb0=r_pcb0,
        c_pcb=c_pcb,
        t_amb=t_amb,
        fan_low=fan_low,
        fan_span=fan_span,
        fan_rmin=fan_rmin,
        fan_pmax=fan_pmax,
        trip=trip,
        release=release,
        n_flat_units=u0,
        unit_starts=np.asarray(unit_starts, np.int64),
        group_starts=np.asarray(group_starts, np.int64),
        rack_u=rack_u_a,
        rack_g=rack_g_a,
        local_idx=np.asarray(local_idx, np.int64),
        group_of_u=np.asarray(group_of_u, np.int64),
        last_unit=last_unit,
        r_die_u=r_die[rack_u_a],
        c_die_u=c_die[rack_u_a],
        c_pcb_g=c_pcb[rack_g_a],
        t_amb_g=t_amb[rack_g_a],
        max_sustainable=max_sustainable,
    )


@dataclass
class FleetArrays:
    """Every static per-rack array the stacked engines consume."""

    n_racks: int
    # activation policy + power model, stacked per rack
    n_units: np.ndarray
    unit_rate: np.ndarray
    headroom: np.ndarray
    min_units: np.ndarray
    minq: np.ndarray
    cooldown: np.ndarray
    p_shared: np.ndarray
    p_idle: np.ndarray
    p_peak: np.ndarray
    gamma: np.ndarray
    span: np.ndarray
    p_base: np.ndarray
    # frequency axis: stacked OPP tables + governor classification
    has_table: np.ndarray
    K: np.ndarray
    Kmax: int
    perf_tab: np.ndarray  # (racks, Kmax)
    spk_tab: np.ndarray  # (racks, Kmax) span * power_scale
    opp0: np.ndarray  # initial (nominal) OPP per rack
    nominal: np.ndarray
    highest: np.ndarray
    gov_kind: np.ndarray
    fixed_opp: np.ndarray
    sched_headroom: np.ndarray
    ceiling: np.ndarray  # thermal-aware clamp
    has_ceiling: np.ndarray
    generic: List[Tuple[int, object]]
    # per-rack objects the (generic) scalar fallbacks need
    tables: List[Optional[OPPTable]]
    unit_specs: List[UnitSpec]
    max_sust: List[Optional[int]]
    # hedging config (None = off), per rack
    hedge_deadline: List[Optional[float]]
    names: List[str]
    # thermal stacking (None when no rack carries a thermal model)
    t_idx: np.ndarray
    thermal: Optional[ThermalLayout]

    @property
    def any_hedge(self) -> bool:
        return any(dl is not None for dl in self.hedge_deadline)

    @property
    def capacity_rps(self) -> np.ndarray:
        """Per-rack peak service rate (``n_units * unit_rate``) — the
        denominator of every queue-delay estimate (routers, breakers,
        the jax degradation lowering)."""
        return self.n_units.astype(float) * self.unit_rate


def build_fleet_arrays(
    racks: "Sequence[RackConfig]", idle_units_off: bool
) -> FleetArrays:
    """Stack a rack list into :class:`FleetArrays`.

    The arithmetic is lifted verbatim from the vector engine's former
    constructor — the vector engine adopts these arrays as-is, so the
    refactor is bitwise-neutral by construction.
    """
    for rc in racks:
        if rc.thermal is not None and rc.opp_table is None:
            raise AssertionError(
                "thermal throttling needs an opp_table to throttle within"
            )
    pols = [rc.policy or ScalePolicy() for rc in racks]
    units = [rc.spec.unit for rc in racks]
    n_units = np.array([rc.spec.n_units for rc in racks], np.int64)
    min_units = np.array([p.min_units for p in pols], np.int64)
    p_idle = np.array([u.p_idle for u in units], float)
    p_peak = np.array([u.p_peak for u in units], float)
    span = p_peak - p_idle
    n = len(racks)
    # --- frequency axis: stacked OPP tables + governor classification
    has_table = np.array([rc.opp_table is not None for rc in racks], bool)
    K = np.array(
        [len(rc.opp_table) if rc.opp_table is not None else 1 for rc in racks],
        np.int64,
    )
    Kmax = int(K.max())
    # (racks, opps) perf and span*power_scale tables; rows of racks
    # without a table carry the nominal point, columns past a short
    # table replicate its top point (masked out of every search)
    perf_tab = np.ones((n, Kmax), float)
    spk_tab = np.repeat(span[:, None], Kmax, axis=1)
    opp0 = np.zeros(n, np.int64)
    for r, rc in enumerate(racks):
        tb = rc.opp_table
        if tb is None:
            continue
        for c in range(Kmax):
            p = tb[min(c, len(tb) - 1)]
            perf_tab[r, c] = p.perf_scale
            spk_tab[r, c] = span[r] * p.power_scale
        opp0[r] = tb.nominal
    nominal = opp0.copy()
    highest = K - 1
    # thermal stacking (before classification: ceilings come from it)
    t_idx = [r for r, rc in enumerate(racks) if rc.thermal is not None]
    thermal = build_thermal_layout(racks, t_idx) if t_idx else None
    max_sust: List[Optional[int]] = [None] * n
    if thermal is not None:
        for j, r in enumerate(t_idx):
            max_sust[r] = thermal.max_sustainable[j]
    # classify each rack's governor for the stacked selection passes
    gov_kind = np.full(n, GOV_NONE, np.int64)
    fixed_opp = np.zeros(n, np.int64)
    sched_headroom = np.zeros(n, float)
    ceiling = highest.copy()  # thermal-aware clamp
    has_ceiling = np.zeros(n, bool)
    generic: List[Tuple[int, object]] = []
    for r, (rc, pol) in enumerate(zip(racks, pols)):
        gov = pol.freq_governor
        tb = rc.opp_table
        if tb is None or gov is None:
            continue  # frequency axis off / pinned at nominal
        inner = gov
        if type(gov) is ThermalAwareGovernor:
            inner = gov.inner
            if max_sust[r] is not None:
                ceiling[r] = max_sust[r]  # type: ignore[assignment]
                has_ceiling[r] = True
        if type(inner) is FixedFreqGovernor:
            gov_kind[r] = GOV_FIXED
            fixed_opp[r] = (
                tb.highest if inner.index is None else tb.clamp(inner.index)
            )
        elif type(inner) is RaceToIdleGovernor:
            gov_kind[r] = GOV_RACE
        elif type(inner) is SchedutilGovernor:
            gov_kind[r] = GOV_SCHED
            sched_headroom[r] = (
                inner.headroom if inner.headroom is not None else pol.headroom
            )
        else:
            gov_kind[r] = GOV_GENERIC
            generic.append((r, gov))
    return FleetArrays(
        n_racks=n,
        n_units=n_units,
        unit_rate=np.array([rc.unit_rate for rc in racks], float),
        headroom=np.array([p.headroom for p in pols], float),
        min_units=min_units,
        minq=np.maximum(1, np.minimum(min_units, n_units)),
        cooldown=np.array([p.cooldown_s for p in pols], float),
        p_shared=np.array([rc.spec.p_shared for rc in racks], float),
        p_idle=p_idle,
        p_peak=p_peak,
        gamma=np.array([u.gamma for u in units], float),
        span=span,
        p_base=np.array(
            [u.p_off if idle_units_off else u.p_idle for u in units],
            float,
        ),
        has_table=has_table,
        K=K,
        Kmax=Kmax,
        perf_tab=perf_tab,
        spk_tab=spk_tab,
        opp0=opp0,
        nominal=nominal,
        highest=highest,
        gov_kind=gov_kind,
        fixed_opp=fixed_opp,
        sched_headroom=sched_headroom,
        ceiling=ceiling,
        has_ceiling=has_ceiling,
        generic=generic,
        tables=[rc.opp_table for rc in racks],
        unit_specs=units,
        max_sust=max_sust,
        hedge_deadline=[p.hedge_after_s for p in pols],
        names=[rc.name or f"rack{i}" for i, rc in enumerate(racks)],
        t_idx=np.asarray(t_idx, np.int64),
        thermal=thermal,
    )
