"""Request routers: shard one fleet-level offered load across racks.

A router sees a :class:`FleetView` — per-rack state arrays published by
the engine at the start of every tick — and returns the per-rack
requests/s assignment. All routers are pure array computations, so the
same router instance drives both fleet backends and (given identical
views) produces bitwise-identical assignments, which is what makes the
scalar and vector fleet engines comparable end to end.

  * :class:`RoundRobinRouter` — uniform spread (the fluid limit of
    per-request round-robin); ignores rack state entirely;
  * :class:`JoinShortestQueueRouter` — water-filling on expected
    queueing delay: load goes to the racks whose (backlog + new work) /
    capacity is lowest until delays equalize — the geo load balancer's
    JSQ policy in fluid form;
  * :class:`PowerAwareRouter` — packs load onto the most
    energy-efficient racks first (full-load J/request ranking, filled
    to a utilization setpoint, spilling only when the efficient racks
    saturate) — routing-level energy proportionality on heterogeneous
    fleets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, runtime_checkable

import numpy as np

__all__ = [
    "FleetView",
    "Router",
    "RoundRobinRouter",
    "JoinShortestQueueRouter",
    "PowerAwareRouter",
    "ROUTERS",
]


@dataclass
class FleetView:
    """Per-rack state a router may consult (arrays of length n_racks).

    Under chaos the fleet publishes a *degraded* view: killed units
    shrink ``capacity_rps`` and a fully dead rack carries capacity 0.0
    and ``alive=False``. JSQ and the power-aware router exclude dead
    racks through the zeroed capacity alone (their assignments are
    capacity-scaled); round-robin consults ``alive`` directly. ``None``
    means no chaos is wired — bitwise-identical to the pre-chaos view.
    """

    t: float
    dt_s: float
    capacity_rps: np.ndarray  # peak service rate (n_units x unit_rate)
    queued_cost: np.ndarray  # request-equivalents waiting per rack
    active_units: np.ndarray
    n_units: np.ndarray
    full_load_j_per_req: np.ndarray  # rack energy cost per request at peak
    alive: Optional[np.ndarray] = None  # chaos: False = rack fully dead

    @property
    def n_racks(self) -> int:
        return len(self.capacity_rps)

    def scaled(self, capacity_scale: np.ndarray) -> "FleetView":
        """This view with per-rack capacity multipliers applied — how
        the degradation layer's circuit breakers reshape routing. An
        open breaker (scale 0.0) zeroes the rack's advertised capacity
        and clears ``alive`` (so capacity-oblivious round-robin also
        stops sending); a half-open breaker advertises its probe
        fraction, which capacity-aware routers honor proportionally.
        ``scale == 1`` everywhere returns the arrays unchanged
        bitwise (multiplying by 1.0 is an IEEE identity)."""
        scale = np.asarray(capacity_scale, float)
        alive = self.alive
        if (scale == 0.0).any():  # reprolint: ok[RPL005] exact-zero sentinel, not a float tie: an open breaker's scale is the literal 0.0 on every backend, never a computed near-zero
            base = alive if alive is not None else np.ones(self.n_racks, bool)
            alive = base & (scale > 0.0)
        return FleetView(
            t=self.t,
            dt_s=self.dt_s,
            capacity_rps=self.capacity_rps * scale,
            queued_cost=self.queued_cost,
            active_units=self.active_units,
            n_units=self.n_units,
            full_load_j_per_req=self.full_load_j_per_req,
            alive=alive,
        )


@runtime_checkable
class Router(Protocol):
    """Structural protocol: one per-rack rps assignment per tick."""

    def route(self, total_rps: float, view: FleetView) -> np.ndarray: ...


class RoundRobinRouter:
    """Uniform spread: every rack gets ``total / n_racks`` requests/s.

    The fluid equivalent of cycling request-by-request through the rack
    list. Capacity-oblivious — on a heterogeneous fleet it overloads
    the small racks while big ones idle, which is exactly the baseline
    the smarter routers are measured against.
    """

    name = "round-robin"

    def route(self, total_rps: float, view: FleetView) -> np.ndarray:
        alive = view.alive
        if alive is None:
            return np.full(view.n_racks, total_rps / view.n_racks)
        # chaos degradation: spread only over live racks (a dead rack's
        # queue was evacuated; sending it more work would strand it).
        # All racks dead = nowhere to route — the load is lost.
        n_alive = int(np.count_nonzero(alive))
        if n_alive == 0:
            return np.zeros(view.n_racks)
        return np.where(alive, total_rps / n_alive, 0.0)


class JoinShortestQueueRouter:
    """Water-fill on expected queueing delay.

    Each rack's delay metric is ``queued_cost / capacity`` seconds of
    backlog; this tick's work is poured onto the racks with the lowest
    metric until delays equalize at a common water level ``L``::

        assign_r = max(0, capacity_r * L - queued_r) / dt

    with ``L`` chosen so the assignments sum to the offered work. Racks
    whose backlog already exceeds the level receive nothing this tick.
    """

    name = "join-shortest-queue"

    def route(self, total_rps: float, view: FleetView) -> np.ndarray:
        cap = np.maximum(view.capacity_rps, 1e-12)
        if total_rps <= 0.0:
            return np.zeros(view.n_racks)
        work = total_rps * view.dt_s
        delay = view.queued_cost / cap
        order = np.argsort(delay, kind="stable")
        d = delay[order]
        c = cap[order]
        q = view.queued_cost[order]
        # level over the k cheapest racks; feasible while L_k >= d_k
        levels = (work + np.cumsum(q)) / np.cumsum(c)  # reprolint: ok[RPL001] cumsum is prefix-ordered; the one Router instance feeds both engines the same views, so routing is deterministic by construction
        feasible = np.nonzero(levels >= d)[0]
        level = levels[feasible[-1]] if len(feasible) else levels[0]
        assign = np.maximum(0.0, view.capacity_rps * level - view.queued_cost)
        return assign / view.dt_s


class PowerAwareRouter:
    """Pack load onto the cheapest racks (J/request at full load) first.

    Racks are ranked by ``full_load_j_per_req``; each is filled to
    ``util_target`` of its capacity before the next rank gets traffic.
    If the setpoint pool saturates, a second pass fills the same
    ranking to full capacity; any residual overload is spread
    capacity-proportionally. On a heterogeneous fleet this keeps the
    inefficient racks at their idle floor whenever the efficient ones
    can carry the load.
    """

    name = "power-aware"

    def __init__(self, util_target: float = 0.85) -> None:
        assert 0.0 < util_target <= 1.0
        self.util_target = util_target

    @staticmethod
    def _greedy(total: float, budget: np.ndarray) -> np.ndarray:
        """Fill ``budget`` slots in order until ``total`` is exhausted."""
        before = np.concatenate(([0.0], np.cumsum(budget)[:-1]))  # reprolint: ok[RPL001] cumsum is prefix-ordered; the one Router instance feeds both engines the same views, so routing is deterministic by construction
        return np.clip(total - before, 0.0, budget)

    def route(self, total_rps: float, view: FleetView) -> np.ndarray:
        if total_rps <= 0.0:
            return np.zeros(view.n_racks)
        order = np.argsort(view.full_load_j_per_req, kind="stable")
        cap = view.capacity_rps[order]
        if float(cap.sum()) <= 0.0:  # reprolint: ok[RPL001] zero-test only: capacities are non-negative, sum()==0 iff all are 0
            # chaos: every rack dead — nowhere to route
            return np.zeros(view.n_racks)
        setpoint = cap * self.util_target
        take = self._greedy(total_rps, setpoint)
        rem = total_rps - float(take.sum())  # reprolint: ok[RPL001] router runs once per tick on identical views in both engines; its output is replayed, not recomputed, so any reduction order is parity-safe
        if rem > 1e-12:
            take = take + self._greedy(rem, cap - take)
            rem = total_rps - float(take.sum())  # reprolint: ok[RPL001] same shared-router argument as above
        if rem > 1e-12:
            # fleet-wide overload: spread the excess by capacity
            take = take + rem * cap / float(cap.sum())  # reprolint: ok[RPL001] same shared-router argument as above
        assign = np.zeros(view.n_racks)
        assign[order] = take
        return assign


ROUTERS = {
    "round-robin": RoundRobinRouter,
    "join-shortest-queue": JoinShortestQueueRouter,
    "power-aware": PowerAwareRouter,
}
