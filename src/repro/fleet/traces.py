"""Fleet-scale arrival traces: diurnal, flash-crowd, and replay files.

A fleet trace is a 1-D array of offered load (requests/s) per tick for
the *whole* fleet; the :class:`~repro.fleet.router.Router` shards it
across racks each tick. Three sources:

  * :func:`diurnal_trace` (re-exported from ``core.scheduler``) — the
    paper's Fig 5 day/night swing (25x peak/trough);
  * :func:`flash_crowd_trace` — a baseline with a sudden multiplicative
    spike (the "breaking-news" case public edge platforms provision
    for);
  * :func:`replay_trace` — arrival rates replayed from a file, one
    requests/s value per line (``#`` comments and a trailing CSV column
    layout ``t,rps`` are accepted), so measured traces from production
    load balancers can drive the simulation.

:func:`scale_to_users` rescales any trace so its peak corresponds to a
target user population — this is how the fig16 sweep turns a unit-less
diurnal shape into "millions of users" of offered load.
"""
from __future__ import annotations

import os
from typing import Sequence, Union

import numpy as np

from repro.core.scheduler import diurnal_trace

__all__ = [
    "diurnal_trace",
    "flash_crowd_trace",
    "replay_trace",
    "save_trace",
    "scale_to_users",
]


def flash_crowd_trace(
    base_rps: float,
    spike_mult: float = 8.0,
    hours: float = 2.0,
    dt_s: float = 60.0,
    spike_start_h: float = 0.75,
    spike_ramp_h: float = 0.05,
    spike_hold_h: float = 0.35,
    noise: float = 0.03,
    seed: int = 0,
) -> np.ndarray:
    """A steady baseline with one flash crowd: load ramps linearly to
    ``spike_mult`` x baseline over ``spike_ramp_h``, holds, and ramps
    back down. The shape stresses routers (queue imbalance) and
    governors (wake storms) far more than a smooth diurnal."""
    rng = np.random.default_rng(seed)
    n = int(hours * 3600 / dt_s)
    t_h = np.arange(n) * dt_s / 3600.0
    up0, up1 = spike_start_h, spike_start_h + spike_ramp_h
    dn0 = up1 + spike_hold_h
    dn1 = dn0 + spike_ramp_h
    ramp_up = np.clip((t_h - up0) / max(up1 - up0, 1e-9), 0.0, 1.0)
    ramp_dn = np.clip((t_h - dn0) / max(dn1 - dn0, 1e-9), 0.0, 1.0)
    mult = 1.0 + (spike_mult - 1.0) * (ramp_up - ramp_dn)
    load = base_rps * mult * (1.0 + noise * rng.standard_normal(n))
    return np.clip(load, 0.0, None)


def replay_trace(path: Union[str, os.PathLike], scale: float = 1.0) -> np.ndarray:
    """Load an arrival-rate trace from a text file: one requests/s value
    per line (blank lines and ``#`` comments skipped). Lines with
    commas are treated as CSV and the *last* column is used, so both
    bare dumps and ``timestamp,rps`` exports replay unchanged."""
    values = []
    with open(path) as fh:
        for line in fh:
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            values.append(float(line.split(",")[-1]))
    if not values:
        raise ValueError(f"replay trace {path!r} contains no samples")
    return np.asarray(values, float) * scale


def save_trace(path: Union[str, os.PathLike], trace: Sequence[float]) -> None:
    """Write a trace in the :func:`replay_trace` format."""
    with open(path, "w") as fh:
        fh.write("# requests/s, one tick per line\n")
        for v in np.asarray(trace, float):
            fh.write(f"{v:.6f}\n")


def scale_to_users(
    trace: Sequence[float],
    users: float,
    rps_per_user: float = 0.02,
) -> np.ndarray:
    """Rescale ``trace`` so its peak equals ``users * rps_per_user``
    (every user contributing ``rps_per_user`` requests/s at the daily
    peak — the ROADMAP's "millions of users" knob)."""
    tr = np.asarray(trace, float)
    peak = float(tr.max())
    if peak <= 0.0:
        raise ValueError("trace has no positive samples to scale")
    return tr * (users * rps_per_user / peak)
