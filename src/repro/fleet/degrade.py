"""Graceful-degradation control plane for the fleet (ROADMAP: SLO-tiered
admission control when the pool saturates).

The chaos layer (PR 9) injects faults; this module is the
self-protection layer that rides them out. Four composable mechanisms,
each declarative and seeded like :class:`~repro.fleet.chaos.ChaosSchedule`:

* **SLO-tiered admission control** — offered load is split into tiers
  (:class:`TierSpec`: gold/silver/bulk by default) with per-tier
  deadline budgets. When the fleet's estimated queueing delay
  (queued cost over breaker-scaled live capacity) exceeds a tier's
  budget, that tier is *shed at the door*: counted, scheduled for
  retry, never silently dropped. Conservation becomes
  ``injected = served + queued + shed + dropped + respilled``.
* **Deadline-aware load shedding** — queued work older than
  ``queue_deadline_s`` is abandoned inside the fluid drain
  (:meth:`repro.runtime.workload.QueueWorkload.expire`) instead of
  being served uselessly, reclaiming capacity during flash crowds.
* **Per-rack circuit breakers** — a rack trips open on queue delay or
  on the chaos liveness signal (router stops sending), half-opens
  after a cooldown with ``probe_fraction`` traffic, and closes on
  recovery. All transitions run on the *sim clock* in whole ticks
  (integer tick arithmetic, so every engine agrees on transition
  instants by construction).
* **Deterministic retry** — shed mass is re-submitted through the
  router after exponential backoff with seeded jitter. The backoff
  math is :class:`repro.distributed.fault.RetryPolicy` (the single
  copy in the repo); the retry budget (``max_attempts``) makes retry
  storms impossible by construction, and the bounded ring buffer the
  mass waits in makes that visible in the types.

Parity contract: the scalar and vector engines are driven by **one**
:class:`DegradeDriver` instance per run — admission, breaker, and
retry decisions are literally the same Python objects, so the two
engines stay bitwise-identical (the same trick ``router.py`` uses).
Deadline expiry runs inside the shared ``QueueWorkload`` deque, again
one code path. The jax engine lowers the same policy to branchless
per-tick rows inside its ``lax.scan`` (`repro.fleet.jax_engine`) and
rides the documented tolerance budgets; decision thresholds compared
against float queue state can flip a tick under XLA float semantics,
which is the same quantized-decision caveat the governor lowering
carries. The jax engine also emits per-tick per-tier admitted rows
(``dg_adm`` / ``dg_respill``) and rebuilds the hosts'
``_tier_requests`` sub-request split host-side — slice existence is
a ``frac > 0`` predicate on both sides, never cost rounding dust —
so response/queued/void *counts* match the hosts exactly and
tier-tagged latencies (:func:`tier_latency_percentiles`) agree
within the tolerance budgets on all three backends.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.fault import RetryPolicy

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.fleet.router import FleetView
    from repro.fleet.telemetry import FleetTelemetry

__all__ = [
    "TierSpec",
    "BreakerConfig",
    "DegradePolicy",
    "LoweredDegrade",
    "DegradeDriver",
    "tier_latency_percentiles",
    "BRK_CLOSED",
    "BRK_OPEN",
    "BRK_HALF",
]

# breaker states (int codes shared with the jax lowering's carry)
BRK_CLOSED, BRK_OPEN, BRK_HALF = 0, 1, 2

#: floor for capacity denominators in delay estimates (all racks dead /
#: all breakers open -> delay saturates instead of dividing by zero)
_CAP_EPS = 1e-12


@dataclass(frozen=True)
class TierSpec:
    """One admission tier: its share of offered load and its budget.

    ``share`` is the tier's fraction of every tick's fresh offered rps
    (shares must sum to 1); ``deadline_budget_s`` is the estimated
    queueing delay above which the tier is shed at the door. Gold gets
    a generous budget, bulk a tight one — under saturation the bulk
    tier sheds first and gold keeps its latency."""

    name: str
    share: float
    deadline_budget_s: float

    def __post_init__(self) -> None:
        if not 0.0 <= self.share <= 1.0:
            raise ValueError(f"tier {self.name!r}: share must be in [0, 1]")
        if self.deadline_budget_s <= 0.0:
            raise ValueError(
                f"tier {self.name!r}: deadline budget must be positive")


def default_tiers() -> List[TierSpec]:
    """The gold/silver/bulk split used when a policy gives none."""
    return [
        TierSpec("gold", 0.2, 600.0),
        TierSpec("silver", 0.3, 300.0),
        TierSpec("bulk", 0.5, 120.0),
    ]


@dataclass(frozen=True)
class BreakerConfig:
    """Per-rack circuit breaker thresholds (sim-clock seconds).

    A rack opens when its queue delay (queued cost / chaos-degraded
    capacity) exceeds ``open_after_s`` or — with ``use_chaos_signal``
    — when it has been fully dead for more than ``fail_timeout_s``
    (the :class:`~repro.fleet.chaos.ChaosMonitor` liveness timeout, in
    whole ticks so every engine agrees). After ``cooldown_s`` it
    half-opens and receives ``probe_fraction`` of its normal routing
    share; it closes once delay recovers below ``close_below_s``."""

    open_after_s: float = 600.0
    close_below_s: float = 120.0
    cooldown_s: float = 600.0
    probe_fraction: float = 0.1
    use_chaos_signal: bool = True
    fail_timeout_s: float = 120.0

    def __post_init__(self) -> None:
        if self.open_after_s <= self.close_below_s:
            raise ValueError("breaker must open above where it closes")
        if not 0.0 < self.probe_fraction <= 1.0:
            raise ValueError("probe_fraction must be in (0, 1]")
        if self.cooldown_s <= 0.0:
            raise ValueError("cooldown_s must be positive")


@dataclass(frozen=True)
class DegradePolicy:
    """A declarative, seeded degradation plan for one fleet run.

    Any mechanism can be disabled: an empty ``tiers`` list turns off
    admission control, ``queue_deadline_s=None`` turns off deadline
    shedding, ``breaker=None`` turns off the circuit breakers, and a
    ``retry`` budget of one attempt turns shed mass straight into
    ``retry_dropped`` (no re-submission). ``seed`` feeds the retry
    jitter only — everything else is deterministic already."""

    tiers: Tuple[TierSpec, ...] = field(
        default_factory=lambda: tuple(default_tiers()))
    queue_deadline_s: Optional[float] = None
    breaker: Optional[BreakerConfig] = None
    retry: RetryPolicy = field(
        default_factory=lambda: RetryPolicy(
            max_attempts=3, backoff_s=120.0, jitter=0.5))
    seed: int = 0

    def __post_init__(self) -> None:
        if self.tiers:
            total = 0.0
            for t in self.tiers:
                total += t.share
            if abs(total - 1.0) > 1e-9:
                raise ValueError(
                    f"tier shares must sum to 1, got {total!r}")
        if self.queue_deadline_s is not None and self.queue_deadline_s <= 0:
            raise ValueError("queue_deadline_s must be positive")

    @property
    def retry_policy(self) -> RetryPolicy:
        """The retry policy with this plan's seed folded in (a policy
        constructed with an explicit seed keeps it)."""
        if self.retry.seed == 0 and self.seed != 0:
            return RetryPolicy(
                max_attempts=self.retry.max_attempts,
                backoff_s=self.retry.backoff_s,
                jitter=self.retry.jitter,
                seed=self.seed,
            )
        return self.retry

    def lower(self, n_units: Sequence[int], dt_s: float) -> "LoweredDegrade":
        """Bind the policy to a fleet shape + tick width: precompute
        the integer tick constants every engine shares (deadline lag,
        breaker cooldown/fail-timeout ticks, retry ring size)."""
        return LoweredDegrade(self, np.asarray(n_units, np.int64), dt_s)


def _ceil_ticks(seconds: float, dt_s: float) -> int:
    """Whole-tick count covering ``seconds`` (with an epsilon so an
    exact multiple of ``dt`` does not round up an extra tick)."""
    return max(1, int(math.ceil(seconds / dt_s - 1e-9)))


class LoweredDegrade:
    """A :class:`DegradePolicy` bound to a fleet: static tick constants
    plus the per-tick retry-delay rows both the host driver and the
    jax ``lax.scan`` consume (one backoff computation, two engines)."""

    def __init__(
        self, policy: DegradePolicy, n_units: np.ndarray, dt_s: float
    ) -> None:
        self.policy = policy
        self.n_units = np.asarray(n_units, np.int64)
        self.dt_s = float(dt_s)
        self.n_racks = len(self.n_units)
        self.tiers = list(policy.tiers)
        self.n_tiers = len(self.tiers)
        self.shares = np.asarray([t.share for t in self.tiers], float)
        self.budgets = np.asarray(
            [t.deadline_budget_s for t in self.tiers], float)
        self.retry = policy.retry_policy
        # deadline lag in ticks: a request injected at tick j (arrival
        # j*dt + dt/2) expires at the first tick start i*dt with
        # i*dt - arrival >= deadline, i.e. i - j >= ceil(deadline/dt + 1/2)
        self.deadline_lag = (
            _ceil_ticks(policy.queue_deadline_s + 0.5 * dt_s, dt_s)
            if policy.queue_deadline_s is not None
            else 0
        )
        # retry ring size: the longest possible backoff (jitter maxed)
        # in ticks, plus the release slot itself
        self.max_dticks = _ceil_ticks(
            max(self.retry.max_delay_s, dt_s), dt_s)
        self.ring_slots = self.max_dticks + 2
        brk = policy.breaker
        self.cooldown_ticks = (
            _ceil_ticks(brk.cooldown_s, dt_s) if brk is not None else 0)
        self.fail_timeout_ticks = (
            _ceil_ticks(brk.fail_timeout_s, dt_s) if brk is not None else 0)

    @property
    def admission_on(self) -> bool:
        return self.n_tiers > 0

    @property
    def breaker_on(self) -> bool:
        return self.policy.breaker is not None

    def retry_dticks(self, tick: int) -> np.ndarray:
        """Backoff delays in whole ticks for mass shed at global tick
        ``tick``, one entry per failed attempt index — the seeded
        jitter draw keyed by the tick, through the one
        :class:`RetryPolicy` implementation."""
        u = self.retry.jitter_u(tick)
        out = np.empty(self.retry.max_attempts, np.int64)
        for a in range(self.retry.max_attempts):
            out[a] = _ceil_ticks(
                max(self.retry.delay_s(a, u), self.dt_s), self.dt_s)
        return out

    def retry_rows(self, tick0: int, n_ticks: int) -> np.ndarray:
        """``(n_ticks, max_attempts)`` int64 retry-delay rows for the
        jax lowering (block-resamplable: row ``k`` depends only on the
        absolute tick index ``tick0 + k``)."""
        rows = np.empty((n_ticks, self.retry.max_attempts), np.int64)
        for k in range(n_ticks):
            rows[k] = self.retry_dticks(tick0 + k)
        return rows


class DegradeDriver:
    """The host-side control loop: admission + breakers + retry.

    One instance drives **both** the scalar and the vector engine in a
    run (the fleet constructs it per :meth:`play_trace`), so their
    degradation decisions are bitwise-identical by construction. All
    state advances in :meth:`pre_route`, called once per tick *after*
    chaos masks/deadline expiry and *before* routing.
    """

    def __init__(self, lowered: LoweredDegrade) -> None:
        self.lowered = lowered
        lw = lowered
        self.dt_s = lw.dt_s
        # retry ring: mass waiting to re-enter, by (slot, tier, attempt)
        self.ring = np.zeros(
            (lw.ring_slots, max(lw.n_tiers, 1), lw.retry.max_attempts))
        # breaker state/since/last-live, all in whole ticks
        n = lw.n_racks
        self.breaker_state = np.zeros(n, np.int64)
        self._since = np.zeros(n, np.int64)
        self._last_live = np.full(n, -1, np.int64)
        # cumulative counters (the telemetry reads these at run end)
        self.shed_by_tier = np.zeros(max(lw.n_tiers, 1))
        self.retried_cost = 0.0
        self.retry_dropped_cost = 0.0
        self.breaker_opens = 0
        # per-tick series (telemetry + shed_storm SLO rule)
        self.shed_cost_t: List[float] = []
        self.breaker_state_t: List[np.ndarray] = []

    # -- derived -------------------------------------------------------
    @property
    def shed_cost(self) -> float:
        total = 0.0
        for v in self.shed_by_tier:
            total += float(v)
        return total

    def ring_mass(self) -> float:
        """Mass still waiting for a retry slot (drain runs until 0)."""
        total = 0.0
        for v in self.ring.ravel():
            total += float(v)
        return total

    def breaker_scale(self) -> np.ndarray:
        """Per-rack routing multiplier for the current breaker state."""
        lw = self.lowered
        if not lw.breaker_on:
            return np.ones(lw.n_racks)
        brk = lw.policy.breaker
        assert brk is not None
        scale = np.ones(lw.n_racks)
        scale[self.breaker_state == BRK_OPEN] = 0.0
        scale[self.breaker_state == BRK_HALF] = brk.probe_fraction
        return scale

    # -- per-tick control ---------------------------------------------
    def _update_breakers(
        self,
        tick: int,
        queued_cost: np.ndarray,
        cap_rps: np.ndarray,
        dead: Optional[np.ndarray],
    ) -> None:
        lw = self.lowered
        brk = lw.policy.breaker
        assert brk is not None
        n = lw.n_racks
        full_dead = np.zeros(n, bool)
        if dead is not None:
            full_dead = np.asarray(dead, np.int64) >= lw.n_units
        self._last_live[~full_dead] = tick
        failed = np.zeros(n, bool)
        if brk.use_chaos_signal:
            failed = (tick - self._last_live) > lw.fail_timeout_ticks
        delay = queued_cost / np.maximum(cap_rps, _CAP_EPS)
        trip = (delay > brk.open_after_s) | failed
        for r in range(n):
            st = int(self.breaker_state[r])
            if st == BRK_CLOSED:
                if trip[r]:
                    self.breaker_state[r] = BRK_OPEN
                    self._since[r] = tick
                    self.breaker_opens += 1
            elif st == BRK_OPEN:
                if tick - self._since[r] >= lw.cooldown_ticks:
                    self.breaker_state[r] = BRK_HALF
                    self._since[r] = tick
            else:  # half-open
                if trip[r]:
                    self.breaker_state[r] = BRK_OPEN
                    self._since[r] = tick
                    self.breaker_opens += 1
                elif delay[r] <= brk.close_below_s and not failed[r]:
                    self.breaker_state[r] = BRK_CLOSED

    def pre_route(
        self,
        tick: int,
        rps: float,
        respill_rps: float,
        queued_cost: np.ndarray,
        cap_rps: np.ndarray,
        dead: Optional[np.ndarray],
    ) -> Tuple[float, Optional[np.ndarray]]:
        """Advance one tick of the control plane.

        ``queued_cost``/``cap_rps`` are the post-expiry backlog and the
        chaos-degraded (not breaker-scaled) per-rack capacities;
        ``dead`` the chaos down-unit counts (None without chaos).
        Returns ``(total_rps, tier_frac)``: the admitted fleet load to
        route this tick and the tier fractions of it (length
        ``n_tiers + 1``, last entry = untiered respill; ``None`` when
        admission is off or nothing flows)."""
        lw = self.lowered
        if lw.breaker_on:
            self._update_breakers(tick, queued_cost, cap_rps, dead)
        self.breaker_state_t.append(self.breaker_state.copy())
        scale = self.breaker_scale()
        if not lw.admission_on:
            self.shed_cost_t.append(0.0)
            return rps + respill_rps, None
        # fresh per-tier offered rps (last tier takes the exact
        # remainder so the split conserves the trace bitwise)
        fresh = np.empty(lw.n_tiers)
        acc = 0.0
        for k in range(lw.n_tiers - 1):
            fresh[k] = lw.shares[k] * rps
            acc += fresh[k]
        fresh[lw.n_tiers - 1] = rps - acc
        # release this tick's retry slot (mass -> rps)
        slot = tick % lw.ring_slots
        released = self.ring[slot].copy()
        self.ring[slot] = 0.0
        # estimated fleet queueing delay on breaker-scaled capacity
        cap_total = 0.0
        for r in range(lw.n_racks):
            cap_total += float(cap_rps[r] * scale[r])
        queued_total = 0.0
        for r in range(lw.n_racks):
            queued_total += float(queued_cost[r])
        est_delay = queued_total / max(cap_total, _CAP_EPS)
        # the jitter draw is lazy: a tick that sheds nothing never
        # touches the rng (the no-shed fast path stays cheap)
        dticks: Optional[np.ndarray] = None
        admitted = np.zeros(lw.n_tiers)
        shed_now = 0.0
        for k in range(lw.n_tiers):
            rel_rps = 0.0
            for a in range(lw.retry.max_attempts):
                rel_rps += float(released[k, a]) / self.dt_s
            if est_delay <= lw.budgets[k] and cap_total > _CAP_EPS:
                admitted[k] = fresh[k] + rel_rps
                continue
            if dticks is None:
                dticks = lw.retry_dticks(tick)
            # shed at the door: fresh mass at attempt 0, released mass
            # at its own attempt; schedule retries within the budget
            shed_mass = fresh[k] * self.dt_s
            self.shed_by_tier[k] += shed_mass
            shed_now += shed_mass
            self._schedule(tick, k, 0, shed_mass, dticks)
            for a in range(lw.retry.max_attempts):
                mass = float(released[k, a])
                if mass > 0.0:
                    self.shed_by_tier[k] += mass
                    shed_now += mass
                    self._schedule(tick, k, a, mass, dticks)
        self.shed_cost_t.append(shed_now)
        total = 0.0
        for k in range(lw.n_tiers):
            total += float(admitted[k])
        total += respill_rps
        if total <= 0.0:
            return 0.0, None
        frac = np.empty(lw.n_tiers + 1)
        for k in range(lw.n_tiers):
            frac[k] = admitted[k] / total
        frac[lw.n_tiers] = respill_rps / total
        return total, frac

    def _schedule(
        self,
        tick: int,
        tier: int,
        attempt: int,
        mass: float,
        dticks: np.ndarray,
    ) -> None:
        """Queue shed ``mass`` (whose submission attempt ``attempt``
        just failed) for its next attempt, or drop it when the retry
        budget is spent — the budget is what makes retry storms
        impossible by construction."""
        lw = self.lowered
        if mass <= 0.0:
            return
        if attempt + 1 >= lw.retry.max_attempts:
            self.retry_dropped_cost += mass
            return
        slot = (tick + int(dticks[attempt])) % lw.ring_slots
        self.ring[slot, tier, attempt + 1] += mass
        self.retried_cost += mass


def tier_latency_percentiles(
    tel: "FleetTelemetry", tier: str, qs: Sequence[float] = (50.0, 99.0)
) -> Dict[float, float]:
    """Latency percentiles over one tier's completions. Scalar/vector
    backends tag each sub-request's payload with its tier name; the
    jax backend rebuilds the same tier-tagged sub-requests host-side
    and agrees within its documented tolerances (see module
    docstring). Returns ``{q: percentile_s}``; zeros when the tier
    completed nothing."""
    lats: List[float] = []
    for rack_tel in tel.per_rack:
        for resp in rack_tel.responses:
            if resp.output == tier:
                lats.append(float(resp.latency_s))
    if not lats:
        return {float(q): 0.0 for q in qs}
    arr = np.asarray(lats, float)
    return {float(q): float(np.percentile(arr, q)) for q in qs}
