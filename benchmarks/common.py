"""Shared benchmark utilities. Every benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = the figure/table-specific metric).

With ``benchmarks/run.py --json PATH`` a machine-readable record of the
same run is collected here: per-suite wall times, every emitted CSV row,
and the numeric metrics registered via :func:`emit_metric` (these feed
the CI perf-regression gate, ``benchmarks/perf_gate.py``).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax

# Active JSON sink (None unless run.py was invoked with --json).
_json: Optional[Dict[str, Any]] = None
_suite: Optional[str] = None


def start_json_recording() -> Dict[str, Any]:
    """Begin collecting rows/metrics; returns the record run.py dumps."""
    global _json
    _json = {"schema": 1, "suites": {}, "metrics": {}}
    return _json


def begin_suite(name: str) -> None:
    global _suite
    _suite = name
    if _json is not None:
        _json["suites"].setdefault(
            name, {"wall_s": None, "rows": [], "metrics": {}})


def end_suite(name: str, wall_s: float, ok: bool,
              peak_rss_kb: Optional[int] = None) -> None:
    global _suite
    if _json is not None and name in _json["suites"]:
        _json["suites"][name]["wall_s"] = round(wall_s, 4)
        _json["suites"][name]["ok"] = ok
        if peak_rss_kb is not None:
            # ru_maxrss is a process-wide high-water mark (KiB on
            # Linux), monotone across suites: a suite whose value
            # equals its predecessor's did not push the peak further.
            _json["suites"][name]["peak_rss_kb"] = int(peak_rss_kb)
    _suite = None


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    if _json is not None and _suite is not None:
        _json["suites"][_suite]["rows"].append(
            {"name": name, "us_per_call": us_per_call, "derived": derived})


def emit_metric(name: str, value: float, unit: str = "") -> None:
    """Emit a *numeric* metric: printed as a CSV row and, under
    ``--json``, recorded under both the suite and the top-level
    ``metrics`` map the perf gate compares against the baseline."""
    value = float(value)
    emit(name, 0.0, f"{value:.6g}{' ' + unit if unit else ''}")
    if _json is not None:
        _json["metrics"][name] = value
        if _suite is not None:
            _json["suites"][_suite]["metrics"][name] = value


def header(title: str) -> None:
    print(f"# --- {title} ---")
