"""Shared benchmark utilities. Every benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = the figure/table-specific metric).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def header(title: str) -> None:
    print(f"# --- {title} ---")
