"""Table 5 — throughput per monthly-TCO dollar (TpC), per workload."""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.tco import (edge_server_nogpu_tco, edge_server_tco,
                            soc_cluster_tco)
from repro.workloads.dlserving import point
from repro.workloads.transcoding import VIDEOS, a40_live, intel_live, \
    soc_cluster_live

# Paper Table 5 reference (live streaming TpC, streams/$).
PAPER_LIVE_SOC = {"V1": 0.748, "V2": 0.863, "V3": 0.230, "V4": 0.519,
                  "V5": 0.173, "V6": 0.058}
PAPER_LIVE_A40 = {"V1": 0.420, "V2": 0.210, "V3": 0.102, "V4": 0.181,
                  "V5": 0.114, "V6": 0.034}


def run() -> None:
    header("table5: live streaming TpC (streams per monthly $)")
    soc_tco = soc_cluster_tco()
    gpu_tco = edge_server_tco()
    nogpu_tco = edge_server_nogpu_tco()
    ratios = []
    for v in VIDEOS:
        soc = soc_cluster_live(v)
        a40 = a40_live(v)
        intel = intel_live(v)
        tpc_soc = soc_tco.throughput_per_cost(soc.streams)
        tpc_a40 = gpu_tco.throughput_per_cost(a40.streams)
        tpc_intel = nogpu_tco.throughput_per_cost(intel.streams)
        ratios.append(tpc_soc / tpc_a40)
        emit(f"table5/live_{v.vid}", 0.0,
             f"soc={tpc_soc:.3f}(paper {PAPER_LIVE_SOC[v.vid]})"
             f";a40={tpc_a40:.3f}(paper {PAPER_LIVE_A40[v.vid]})"
             f";intel_nogpu={tpc_intel:.3f}")
    import numpy as np
    emit("table5/live_soc_vs_a40_geomean", 0.0,
         f"{np.exp(np.mean(np.log(ratios))):.2f}x;paper=2.23x")

    header("table5: DL serving TpC (samples/s per monthly $)")
    for model, prec, plat, tco in [
        ("resnet-50", "fp32", "soc-gpu", soc_tco),
        ("resnet-50", "fp32", "intel-cpu", nogpu_tco),
        ("resnet-50", "fp32", "a40", gpu_tco),
        ("resnet-152", "int8", "soc-dsp", soc_tco),
    ]:
        p = point(model, prec, plat)
        emit(f"table5/dl_{model}_{prec}_{plat}", 0.0,
             f"tpc={tco.throughput_per_cost(p.throughput):.3f}")
    # paper's conclusion: GPUs win DL TpC despite losing TpE
    r50_soc = point("resnet-50", "fp32", "soc-gpu")
    r50_a40 = point("resnet-50", "fp32", "a40")
    emit("table5/dl_gpu_wins_tpc", 0.0,
         f"a40_tpc_gt_soc="
         f"{gpu_tco.throughput_per_cost(r50_a40.throughput) > soc_tco.throughput_per_cost(r50_soc.throughput)}"
         f";paper=True")


if __name__ == "__main__":
    run()
