"""Fig 15 (extension) — the DVFS energy/latency Pareto and thermal
throttling on the SoC Cluster.

The paper's energy-proportionality story (§5.2) is one-dimensional:
*how many* SoCs are powered. Real SD865s add a second axis — *how fast*
each runs — and a 2U thermal envelope that punishes ignoring it. This
benchmark sweeps the ``repro.power`` frequency governors over the
calibrated :func:`~repro.power.opp.sd865_opp_table`:

  1. **Low-load energy** (≤30 % load): the ``schedutil`` governor
     (lowest-energy OPP × unit-count pair meeting demand with headroom)
     must beat binary per-unit gating on energy at equal p95 latency —
     wide-and-slow beats narrow-and-fast once f·V² savings outweigh the
     extra idle floors.
  2. **Sustained peak load**: with the RC thermal network attached, the
     ``fixed``-max governor trips the 95 °C latch and its throughput
     sags; the ``thermal-aware`` governor holds the sustainable OPP and
     stays flat (and above the throttler's steady state).
  3. **Pareto sweep**: every governor × load point, as
     (energy, p95-latency) pairs.
  4. **Proportionality**: the frequency-resolved load→power curve must
     not be less proportional than the binary one.

Asserts (acceptance criteria) are enforced inline, like fig14.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from benchmarks.common import emit, header
from repro.core.cluster import soc_cluster
from repro.core.energy import dvfs_proportionality_index, proportionality_index
from repro.power import (FixedFreqGovernor, FreqGovernor, RaceToIdleGovernor,
                         SchedutilGovernor, ThermalAwareGovernor,
                         ThermalParams, sd865_opp_table)
from repro.runtime import ClusterRuntime, QueueWorkload, ScalePolicy

UNIT_RATE = 10.0          # req/s one SoC sustains at the nominal OPP
DT_S = 1.0
WARMUP_TICKS = 30         # governor window + wake ramp settle time


def _run_load(governor: Optional[FreqGovernor], load_frac: float,
              ticks: int = 300, with_table: bool = True
              ) -> Tuple[float, float]:
    """Steady offered load at ``load_frac`` of peak; returns
    (energy_j, p95_latency_s) over the post-warmup window."""
    spec = soc_cluster()
    rt = ClusterRuntime(
        spec, QueueWorkload(unit_rate=UNIT_RATE),
        policy=ScalePolicy(cooldown_s=30.0, freq_governor=governor),
        opp_table=sd865_opp_table() if with_table else None, dt_s=DT_S)
    trace = np.full(ticks, load_frac * UNIT_RATE * spec.n_units)
    tel = rt.play_trace(trace, dt_s=DT_S)
    lats = [r.latency_s for r in tel.responses
            if r.arrival_s >= WARMUP_TICKS * DT_S]
    p95 = float(np.percentile(lats, 95)) if lats else 0.0
    # steady-state energy: skip the cold-start ramp so governors are
    # compared on their operating point, not their warmup
    energy = float(np.sum(tel.power_w[WARMUP_TICKS:]) * DT_S)
    return energy, p95


def _run_sustained(governor: FreqGovernor, ticks: int = 900
                   ) -> Tuple[np.ndarray, ClusterRuntime]:
    """Backlog-saturated run at full activation with the thermal model:
    per-tick work_done isolates the frequency axis."""
    spec = soc_cluster()
    rt = ClusterRuntime(
        spec, QueueWorkload(unit_rate=UNIT_RATE),
        policy=ScalePolicy(min_units=spec.n_units, cooldown_s=1e9,
                           freq_governor=governor),
        opp_table=sd865_opp_table(), thermal=ThermalParams(), dt_s=DT_S)
    offered = 2.0 * UNIT_RATE * spec.n_units       # 2x oversubscribed
    work = np.empty(ticks)
    for i in range(ticks):
        rt.submit(cost=offered * DT_S, count=offered * DT_S)
        work[i] = rt.tick().work_done
    return work, rt


def run() -> None:
    header("fig15: DVFS governors — energy/latency Pareto and thermal "
           "throttling (60x SD865)")
    spec = soc_cluster()
    table = sd865_opp_table()

    # --- 1. schedutil vs binary gating at light load ----------------------
    e_bin, p95_bin = _run_load(None, 0.30, with_table=False)
    e_sched, p95_sched = _run_load(SchedutilGovernor(), 0.30)
    emit("fig15/low_load_30pct", 0.0,
         f"binary_j={e_bin:.0f};schedutil_j={e_sched:.0f};"
         f"saving={1 - e_sched / e_bin:.0%};"
         f"p95_binary_s={p95_bin:.2f};p95_schedutil_s={p95_sched:.2f}")
    assert e_sched < e_bin, \
        "schedutil must beat binary gating on energy at <=30% load"
    assert abs(p95_sched - p95_bin) <= 0.15 * max(p95_bin, 1e-9), \
        "schedutil's energy win must come at equal p95 latency"

    # --- 2. sustained peak load: throttling sag vs thermal headroom -------
    w_fixed, rt_fixed = _run_sustained(FixedFreqGovernor())
    w_aware, rt_aware = _run_sustained(ThermalAwareGovernor())
    n = len(w_fixed)
    win = n // 6
    sag_fixed = float(w_fixed[-win:].mean() / w_fixed[:win].mean())
    sag_aware = float(w_aware[-win:].mean() / w_aware[:win].mean())
    emit("fig15/sustained_throttling", 0.0,
         f"fixed_late_over_early={sag_fixed:.2f};"
         f"aware_late_over_early={sag_aware:.2f};"
         f"fixed_peak_c={max(rt_fixed.pool.max_temp_hist):.0f};"
         f"aware_peak_c={max(rt_aware.pool.max_temp_hist):.0f};"
         f"fixed_throttled_units={max(rt_fixed.pool.throttled_hist)};"
         f"aware_throttled_units={max(rt_aware.pool.throttled_hist)}")
    # (a) the throttling model bites the fixed-max governor...
    assert sag_fixed < 0.9, "fixed-max must sag under sustained peak load"
    assert max(rt_fixed.pool.throttled_hist) > 0
    # ...but not the thermal-aware one (flat, never trips, and its
    # steady state beats the throttler's)
    assert sag_aware > 0.95, "thermal-aware throughput must stay flat"
    assert max(rt_aware.pool.throttled_hist) == 0
    assert float(w_aware[-win:].mean()) > float(w_fixed[-win:].mean()), \
        "sustained: thermal-aware steady state must beat the throttler"

    # --- 3. the governor Pareto ------------------------------------------
    governors = [
        ("binary", None),
        ("fixed-max", FixedFreqGovernor()),
        ("race-to-idle", RaceToIdleGovernor()),
        ("schedutil", SchedutilGovernor()),
        ("thermal-aware-schedutil", ThermalAwareGovernor(
            SchedutilGovernor())),
    ]
    for load in (0.1, 0.3, 0.6):
        for name, gov in governors:
            e, p95 = _run_load(gov, load, with_table=gov is not None)
            emit(f"fig15/pareto/{name}@{load:.0%}", 0.0,
                 f"energy_j={e:.0f};p95_s={p95:.2f}")

    # --- 4. frequency-resolved proportionality ---------------------------
    pi_bin = proportionality_index(spec)
    pi_dvfs = dvfs_proportionality_index(spec, table)
    emit("fig15/proportionality", 0.0,
         f"binary={pi_bin:.3f};freq_resolved={pi_dvfs:.3f}")
    assert pi_dvfs >= pi_bin - 1e-9, \
        "the frequency-resolved power curve must not be less proportional"


if __name__ == "__main__":
    run()
