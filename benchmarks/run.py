"""Benchmark driver: one section per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (e.g. fig6,table4)")
    ap.add_argument("--fast", action="store_true",
                    help="skip host-executed model measurements")
    ap.add_argument("--list", action="store_true",
                    help="print registered suite names and exit")
    args = ap.parse_args()

    from benchmarks import (bench_kernels, bench_step, fig6_transcoding,
                            fig7_proportionality, fig8_hw_codec,
                            fig11_dl_serving, fig12_dl_proportionality,
                            fig13_collaborative, fig14_mixed_tenancy,
                            fig15_dvfs_pareto, roofline_table,
                            table2_microbench, table3_network_bound,
                            table4_tco, table5_tpc)

    suites = {
        "table2": table2_microbench.run,
        "table3": table3_network_bound.run,
        "fig6": fig6_transcoding.run,
        "fig7": fig7_proportionality.run,
        "fig8": fig8_hw_codec.run,
        "fig11": (lambda: fig11_dl_serving.run(measure=not args.fast)),
        "fig12": fig12_dl_proportionality.run,
        "fig13": (lambda: fig13_collaborative.run(
            executable=not args.fast)),
        "fig14": fig14_mixed_tenancy.run,
        "fig15": fig15_dvfs_pareto.run,
        "table4": table4_tco.run,
        "table5": table5_tpc.run,
        "kernels": bench_kernels.run,
        "steps": bench_step.run,
        "roofline": roofline_table.run,
    }
    if args.list:
        for name in suites:
            print(name)
        return
    selected = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name}/FAILED,0.0,{e!r}")
    if failures:
        sys.exit(f"benchmark suites failed: {[f[0] for f in failures]}")


if __name__ == "__main__":
    main()
