"""Benchmark driver: one section per paper table/figure + framework
benches. Prints ``name,us_per_call,derived`` CSV; ``--json PATH``
additionally writes a machine-readable record (per-suite wall times,
emitted rows, numeric metrics) that ``benchmarks/perf_gate.py`` compares
against the committed ``benchmarks/BENCH_baseline.json`` in CI."""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback
from typing import Optional

try:
    import resource
except ImportError:  # pragma: no cover - non-POSIX platforms
    resource = None  # type: ignore[assignment]


def _peak_rss_kb() -> Optional[int]:
    """Process peak RSS in KiB (Linux ``ru_maxrss`` units); a monotone
    high-water mark, so per-suite values attribute *growth*, not
    isolated usage. None where ``resource`` is unavailable."""
    if resource is None:
        return None
    return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (e.g. fig6,table4)")
    ap.add_argument("--backend", default=None,
                    help="fleet engine for the fleet-driving suites "
                         "(scalar|vector|jax; default: each suite's own)")
    ap.add_argument("--fast", action="store_true",
                    help="skip host-executed model measurements")
    ap.add_argument("--list", action="store_true",
                    help="print registered suite names and exit")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results to PATH")
    args = ap.parse_args()

    from benchmarks import (bench_kernels, bench_pool, bench_step, common,
                            fig6_transcoding, fig7_proportionality,
                            fig8_hw_codec, fig11_dl_serving,
                            fig12_dl_proportionality, fig13_collaborative,
                            fig14_mixed_tenancy, fig15_dvfs_pareto,
                            fig16_fleet, roofline_table, table2_microbench,
                            table3_network_bound, table4_tco, table5_tpc)

    suites = {
        "table2": table2_microbench.run,
        "table3": table3_network_bound.run,
        "fig6": fig6_transcoding.run,
        "fig7": fig7_proportionality.run,
        "fig8": fig8_hw_codec.run,
        "fig11": (lambda: fig11_dl_serving.run(measure=not args.fast)),
        "fig12": fig12_dl_proportionality.run,
        "fig13": (lambda: fig13_collaborative.run(
            executable=not args.fast)),
        "fig14": fig14_mixed_tenancy.run,
        "fig15": fig15_dvfs_pareto.run,
        "fig16": (lambda: fig16_fleet.run(perf=not args.fast,
                                          backend=args.backend)),
        "table4": table4_tco.run,
        "table5": table5_tpc.run,
        "kernels": bench_kernels.run,
        "steps": bench_step.run,
        "pool": bench_pool.run,
        "roofline": roofline_table.run,
    }
    if args.list:
        for name in suites:
            print(name)
        return
    selected = (args.only.split(",") if args.only else list(suites))
    unknown = [name for name in selected if name not in suites]
    if unknown:
        sys.exit(f"unknown suite(s): {', '.join(unknown)}\n"
                 f"valid suites: {', '.join(suites)}")
    backends = ("scalar", "vector", "jax")
    if args.backend is not None and args.backend not in backends:
        sys.exit(f"unknown backend: {args.backend}\n"
                 f"valid backends: {', '.join(backends)}")
    record = common.start_json_recording() if args.json else None
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        common.begin_suite(name)
        t0 = time.perf_counter()
        ok = True
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001
            ok = False
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name}/FAILED,0.0,{e!r}")
        finally:
            common.end_suite(name, time.perf_counter() - t0, ok,
                             peak_rss_kb=_peak_rss_kb())
    if record is not None:
        record["meta"] = {
            "python": platform.python_version(),
            "machine": platform.machine(),
            "suites_run": selected,
            "peak_rss_kb": _peak_rss_kb(),
        }
        with open(args.json, "w") as fh:
            json.dump(record, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}")
    if failures:
        sys.exit(f"benchmark suites failed: {[f[0] for f in failures]}")


if __name__ == "__main__":
    main()
