"""Kernel micro-benchmarks on this host (reference path, jitted) +
interpret-mode correctness deltas. On the TPU target the pallas path
replaces the reference implementations via kernels.ops.set_mode('tpu')."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, time_fn
from repro.kernels import ref


def run() -> None:
    header("kernels: host reference-path timings")
    rng = np.random.default_rng(0)

    b, s, hq, hkv, d = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us = time_fn(f, q, k, v, iters=3)
    flops = 4 * b * hq * s * s * d
    emit("kern/attention_1k", us, f"gflops_s={flops/(us*1e-6)/1e9:.1f}")

    qd = jnp.asarray(rng.standard_normal((8, hq, d)), jnp.bfloat16)
    kd = jnp.asarray(rng.standard_normal((8, 4096, hkv, d)), jnp.bfloat16)
    vd = jnp.asarray(rng.standard_normal((8, 4096, hkv, d)), jnp.bfloat16)
    length = jnp.full((8,), 4096, jnp.int32)
    fd = jax.jit(lambda q, k, v, l: ref.decode_attention_ref(q, k, v, l))
    us = time_fn(fd, qd, kd, vd, length, iters=3)
    emit("kern/decode_attention_4k", us,
         f"gb_s={(kd.nbytes+vd.nbytes)/(us*1e-6)/1e9:.1f}")

    m, kk, n = 512, 1024, 512
    x = jnp.asarray(rng.standard_normal((m, kk)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((kk, n)), jnp.float32)
    xq, sx = ref.quantize_int8(x, axis=1)
    wq, sw = ref.quantize_int8(w, axis=0)
    fi = jax.jit(ref.int8_matmul_ref)
    us = time_fn(fi, xq, sx, wq, sw, iters=3)
    emit("kern/int8_matmul_512", us,
         f"gops_s={2*m*kk*n/(us*1e-6)/1e9:.1f}")

    bs, ss, hh, pp, nn = 1, 2048, 4, 64, 128
    xs = jnp.asarray(rng.standard_normal((bs, ss, hh, pp)), jnp.float32)
    dts = jnp.asarray(rng.uniform(0.01, 0.2, (bs, ss, hh)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, (hh,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((bs, ss, nn)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((bs, ss, nn)), jnp.float32)
    D = jnp.ones((hh,), jnp.float32)
    fs = jax.jit(lambda *a: ref.ssd_chunked(*a, chunk=128))
    us = time_fn(fs, xs, dts, A, B, C, D, iters=3)
    emit("kern/ssd_chunked_2k", us, f"tokens_s={bs*ss/(us*1e-6):.0f}")

    xr = jnp.asarray(rng.standard_normal((4096, 1024)), jnp.bfloat16)
    wr = jnp.ones((1024,), jnp.float32)
    fr = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    us = time_fn(fr, xr, wr, iters=5)
    emit("kern/rmsnorm_4kx1k", us,
         f"gb_s={2*xr.nbytes/(us*1e-6)/1e9:.1f}")


if __name__ == "__main__":
    run()
