"""Fig 6 — transcoding energy efficiency (TpE): live streaming (streams/W)
and archive (frames/J), SoC CPU vs Intel CPU vs A40."""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.workloads.transcoding import (ARCHIVE_FPJ, VIDEOS, a40_live,
                                         intel_live, soc_cluster_live)


def run() -> None:
    header("fig6a: live streaming TpE (streams/W)")
    ratios_intel, ratios_a40 = [], []
    for v in VIDEOS:
        soc = soc_cluster_live(v)
        intel = intel_live(v)
        a40 = a40_live(v)
        r_i = soc.streams_per_watt / intel.streams_per_watt
        r_a = soc.streams_per_watt / a40.streams_per_watt
        ratios_intel.append(r_i)
        ratios_a40.append(r_a)
        emit(f"fig6a/{v.vid}", 0.0,
             f"soc={soc.streams_per_watt:.3f};intel="
             f"{intel.streams_per_watt:.3f};a40={a40.streams_per_watt:.3f}"
             f";soc_vs_intel={r_i:.2f}x;soc_vs_a40={r_a:.2f}x")
    emit("fig6a/soc_vs_intel_range", 0.0,
         f"{min(ratios_intel):.2f}-{max(ratios_intel):.2f}x"
         f";paper=2.58-3.21x")
    emit("fig6a/soc_vs_a40_range", 0.0,
         f"{min(ratios_a40):.2f}-{max(ratios_a40):.2f}x;paper=1.83-4.53x")

    header("fig6b: archive transcoding TpE (frames/J)")
    for v in VIDEOS:
        soc, intel, a40 = ARCHIVE_FPJ[v.vid]
        winner = max([("soc", soc), ("intel", intel), ("a40", a40)],
                     key=lambda t: t[1])[0]
        emit(f"fig6b/{v.vid}", 0.0,
             f"soc={soc};intel={intel};a40={a40};winner={winner}")
    emit("fig6b/a40_loses_on_low_entropy", 0.0,
         f"V2={ARCHIVE_FPJ['V2'][0] > ARCHIVE_FPJ['V2'][2]};"
         f"V4={ARCHIVE_FPJ['V4'][0] > ARCHIVE_FPJ['V4'][2]};paper=True")


if __name__ == "__main__":
    run()
