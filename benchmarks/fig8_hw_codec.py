"""Fig 8 — SoC hardware codec vs SoC CPU: throughput and energy
efficiency of live transcoding."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header
from repro.workloads.transcoding import VIDEOS, soc_cluster_live


def run() -> None:
    header("fig8: hardware codec vs SoC CPU")
    low_entropy_gains, high_entropy_gains = [], []
    for v in VIDEOS:
        cpu = soc_cluster_live(v, hw_codec=False)
        hw = soc_cluster_live(v, hw_codec=True)
        thr_gain = hw.streams / cpu.streams
        tpe_gain = hw.streams_per_watt / cpu.streams_per_watt
        (low_entropy_gains if v.entropy < 1.0
         else high_entropy_gains).append(tpe_gain)
        emit(f"fig8/{v.vid}", 0.0,
             f"streams_cpu={cpu.streams:.0f};streams_hw={hw.streams:.0f};"
             f"thr_gain={thr_gain:.2f}x;tpe_gain={tpe_gain:.2f}x")
    emit("fig8/throughput_gain_range", 0.0, "paper=1.07-3.0x")
    emit("fig8/tpe_gain_low_entropy", 0.0,
         f"geomean={np.exp(np.mean(np.log(low_entropy_gains))):.2f}x"
         f";paper~2.5x")
    emit("fig8/tpe_gain_high_entropy", 0.0,
         f"geomean={np.exp(np.mean(np.log(high_entropy_gains))):.2f}x"
         f";paper=4.7-5.5x")


if __name__ == "__main__":
    run()
