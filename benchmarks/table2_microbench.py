"""Table 2 — CPU micro-benchmarks: per-core vs whole-server scores.

Executable part: a Geekbench-style compute probe (fp32 matmul + int sort +
text-ish hashing) measured on this host gives the per-core anchor; the
whole-server aggregation model (cores x per-core x parallel efficiency)
reproduces the paper's Table 2 server-level ratios.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, time_fn

# Paper Table 2: per-core and whole-server CPU scores.
PAPER = {
    "soc-cluster": {"per_core": 911, "server": 194100, "units": 60 * 8},
    "edge-xeon": {"per_core": 840, "server": 15450, "units": 80},
    "graviton2": {"per_core": 762, "server": 36091, "units": 64},
    "graviton3": {"per_core": 1121, "server": 51379, "units": 64},
}


def host_probe() -> float:
    """A per-core compute probe (us)."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((512, 512)),
                    jnp.float32)
    f = jax.jit(lambda a: (a @ a).sum())
    return time_fn(f, x, iters=5)


def run() -> None:
    header("table2: CPU micro-benchmarks (Geekbench-5 analog)")
    us = host_probe()
    emit("table2/host_probe_matmul512", us,
         f"gflops={2*512**3/ (us*1e-6) /1e9:.1f}")
    soc = PAPER["soc-cluster"]
    for name, row in PAPER.items():
        # aggregation: server ~= per_core * units * eff
        eff = row["server"] / (row["per_core"] * row["units"])
        emit(f"table2/{name}", 0.0,
             f"per_core={row['per_core']};server={row['server']};"
             f"parallel_eff={eff:.2f}")
    emit("table2/soc_vs_xeon_server", 0.0,
         f"ratio={soc['server']/PAPER['edge-xeon']['server']:.1f}x"
         f";paper=12.6x")
    emit("table2/soc_vs_graviton3_server", 0.0,
         f"ratio={soc['server']/PAPER['graviton3']['server']:.1f}x"
         f";paper=3.8x(cpu_score)")


if __name__ == "__main__":
    run()
