"""Table 3 (right half) — network-bound analysis of live transcoding on the
SoC Cluster: per-PCB (1 Gbps) and per-server (20 Gbps) utilization."""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.workloads.transcoding import VIDEOS, network_usage

# Paper's published utilizations for validation.
PAPER_PCB_UTIL = {"V1": 0.534, "V2": 0.043, "V3": 0.673, "V4": 0.081,
                  "V5": 1.008, "V6": 0.985}
PAPER_SERVER_UTIL = {"V1": 0.320, "V2": 0.025, "V3": 0.403, "V4": 0.048,
                     "V5": 0.605, "V6": 0.591}


def run() -> None:
    header("table3: network bound analysis")
    only_v5_over = True
    for v in VIDEOS:
        u = network_usage(v, hw_codec=True)
        emit(f"table3/{v.vid}_pcb", 0.0,
             f"util={u['pcb_util']:.3f};paper={PAPER_PCB_UTIL[v.vid]:.3f}")
        emit(f"table3/{v.vid}_server", 0.0,
             f"util={u['server_util']:.3f};"
             f"paper={PAPER_SERVER_UTIL[v.vid]:.3f}")
        if u["pcb_util"] > 1.0 and v.vid != "V5":
            only_v5_over = False
    emit("table3/only_V5_exceeds_pcb", 0.0, f"holds={only_v5_over}")


if __name__ == "__main__":
    run()
