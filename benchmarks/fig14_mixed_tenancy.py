"""Fig 14 (extension) — mixed tenancy on one SoC Cluster.

The paper's deployed clusters are multi-tenant (§2: cloud gaming, video
transcoding, DL inference share the 60 SoCs). This benchmark colocates
three tenants — live transcoding (Table 3), DL serving (Fig 11/12), and
a fluid LM-serving proxy — on one ``soc_cluster()`` under *anti-phase*
diurnal traces, and compares per-tenant throughput-per-energy against
three dedicated single-tenant clusters.

Consistency checks enforced here (acceptance criteria):
  * sum of per-tenant active units <= 60 on every tick;
  * cluster ``energy_j`` equals the single pool-level power integral
    (shared power charged once);
  * colocated total energy <= the sum of the three dedicated runs.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import emit, header
from repro.core.cluster import soc_cluster
from repro.core.scheduler import diurnal_trace
from repro.runtime import (ClusterRuntime, DLServingWorkload,
                           MultiTenantRuntime, QueueWorkload, ScalePolicy,
                           Tenant, TranscodingWorkload, Workload)
from repro.workloads.transcoding import VIDEOS

DT_S = 60.0
HOURS = 24


def _workloads() -> Dict[str, Workload]:
    """Fresh workload instances (queues are stateful, one per run)."""
    return {
        # V2 720p30: 16 hw streams per SoC (Table 3)
        "transcoding": TranscodingWorkload(VIDEOS[1], hw_codec=True),
        # resnet-50 fp32 on the SoC GPU: ~30.8 samples/s per SoC (Table 7)
        "dl-serving": DLServingWorkload.from_point("resnet-50", "fp32",
                                                   "soc-gpu"),
        # fluid LM-decode proxy: ~8 tok/s per SD865 for a ~2B model
        "lm-serving": QueueWorkload(unit_rate=8.0, name="lm-serving",
                                    kind="lm-serving"),
    }


def _policy() -> ScalePolicy:
    return ScalePolicy(cooldown_s=120.0, min_units=2,
                       hedge_after_s=4 * DT_S)


def _traces(wls: Dict[str, Workload], n_units: int
            ) -> Dict[str, np.ndarray]:
    """Anti-phase diurnal traces: each tenant alone peaks at ~45% of the
    full cluster's rate, with peaks spread 8 h apart so the pool is
    contended only around the crossovers."""
    traces = {}
    n = int(HOURS * 3600 / DT_S)
    for i, (name, wl) in enumerate(wls.items()):
        tr = diurnal_trace(peak_rps=wl.unit_rate * n_units * 0.45,
                           hours=HOURS, dt_s=DT_S, seed=i)
        traces[name] = np.roll(tr, i * n // 3)
    return traces


def run() -> None:
    header("fig14: mixed tenancy — 3 tenants colocated on 60 SoCs "
           "(anti-phase diurnal)")
    spec = soc_cluster()
    wls = _workloads()
    traces = _traces(wls, spec.n_units)
    runtime = MultiTenantRuntime(
        spec, [Tenant(name, wl, policy=_policy())
               for name, wl in wls.items()],
        dt_s=DT_S)
    tel = runtime.play_traces(traces, dt_s=DT_S)
    per = tel.per_tenant

    # --- consistency checks -------------------------------------------------
    stacked = np.vstack([per[m].active_units for m in wls])
    assert np.all(stacked.sum(axis=0) <= spec.n_units), \
        "per-tenant active units exceed the pool on some tick"
    assert np.array_equal(stacked.sum(axis=0), tel.active_units), \
        "per-tenant active units disagree with the pool roll-up"
    integral = float(np.sum(tel.power_w) * DT_S)
    assert abs(tel.energy_j - integral) <= 1e-6 * max(1.0, integral), \
        "cluster energy is not the single pool-level power integral"

    # --- dedicated-cluster baseline (one full soc_cluster per tenant) ------
    dedicated = {}
    for name, wl in _workloads().items():
        rt = ClusterRuntime(soc_cluster(), wl, policy=_policy())
        dedicated[name] = rt.play_trace(traces[name], dt_s=DT_S)
    ded_energy = sum(d.energy_j for d in dedicated.values())
    assert tel.energy_j <= ded_energy, \
        "colocation must not cost more than dedicated clusters"

    # like-for-like per-tenant TPE: attributed unit energy plus a share
    # of the cluster's shared/idle overhead proportional to units used
    # (dedicated_tpe includes a full cluster's overhead, so the bare
    # attributed number would overstate the colocation advantage)
    overhead_j = tel.energy_j - sum(per[m].energy_j for m in wls)
    units_integral = {m: float(np.sum(per[m].active_units)) for m in wls}
    total_units = sum(units_integral.values()) or 1.0
    for name in wls:
        p = per[name]
        share_j = p.energy_j + overhead_j * units_integral[name] \
            / total_units
        emit(f"fig14/{name}", 0.0,
             f"served={p.served:.0f};mean_active={p.mean_active:.1f};"
             f"tpe={p.served / max(share_j, 1e-9):.3f};"
             f"dedicated_tpe={dedicated[name].tpe:.3f};"
             f"unit_tpe={p.served / max(p.energy_j, 1e-9):.3f};"
             f"hedged={p.hedged};p99_s={p.p99_latency_s:.1f}")
    emit("fig14/cluster", 0.0,
         f"energy_kwh={tel.energy_j / 3.6e6:.2f};"
         f"dedicated_kwh={ded_energy / 3.6e6:.2f};"
         f"colocation_saving={1 - tel.energy_j / ded_energy:.0%};"
         f"mean_active={tel.mean_active:.1f}/{spec.n_units};"
         f"tpe={tel.tpe:.3f}")


if __name__ == "__main__":
    run()
