"""Fig 13 — SoC-collaborative DL inference: tensor parallelism with and
without compute/communication pipelining, plus the TPU ring-overlap
mapping, plus a real multi-device compute-scaling measurement."""
from __future__ import annotations

import subprocess
import sys
import os

import numpy as np

from benchmarks.common import emit, header
from repro.core.collaborative import (PAPER_FIG13, RESNET50_PROFILE, SOC_TCP,
                                      TPU_ICI, latency_breakdown)
from repro.core.cluster import soc_cluster
from repro.runtime import ClusterRuntime, QueueWorkload, ScalePolicy


def _runtime_section() -> None:
    header("fig13: collaborative serving through ClusterRuntime")
    # n SoCs collaborate per request (tensor parallel); a request takes
    # total_ms on its group, so each *unit* contributes (1000/total)/n
    # req/s. group_units=n makes the runtime activate whole collaboration
    # groups only — no SoC is stranded in a partial group.
    spec = soc_cluster()
    for n in (1, 2, 5):
        pipe = latency_breakdown(RESNET50_PROFILE, n, SOC_TCP,
                                 pipelined=True)
        unit_rate = 1000.0 / pipe["total_ms"] / n
        workload = QueueWorkload(unit_rate=unit_rate,
                                 name=f"collab-resnet50/n{n}",
                                 kind="collaborative")
        runtime = ClusterRuntime(spec, workload,
                                 policy=ScalePolicy(cooldown_s=30.0,
                                                    min_units=n),
                                 group_units=n)
        trace = np.full(300, 0.3 * unit_rate * spec.n_units)
        tel = runtime.play_trace(trace, dt_s=1.0)
        emit(f"fig13/runtime_n{n}", 0.0,
             f"tpe={tel.tpe:.3f};mean_active={tel.mean_active:.1f}"
             f"/{spec.n_units};p99_s={tel.p99_latency_s:.2f}")


def run(executable: bool = True) -> None:
    header("fig13: collaborative inference latency breakdown (model)")
    for n in range(1, 6):
        base = latency_breakdown(RESNET50_PROFILE, n, SOC_TCP)
        pipe = latency_breakdown(RESNET50_PROFILE, n, SOC_TCP,
                                 pipelined=True)
        ring = latency_breakdown(RESNET50_PROFILE, n, TPU_ICI,
                                 ring_overlap=True)
        emit(f"fig13/n{n}", 0.0,
             f"base_total={base['total_ms']:.1f}ms"
             f";base_comm_share={base['comm_share']:.3f}"
             f";pipelined_total={pipe['total_ms']:.1f}ms"
             f";pipelined_comm_share={pipe['comm_share']:.3f}"
             f";tpu_ring_total={ring['total_ms']:.2f}ms")
    emit("fig13/paper_reference", 0.0,
         f"comm_share@5={PAPER_FIG13['comm_share_at_5']}"
         f";pipelined={PAPER_FIG13['comm_share_at_5_pipelined']}"
         f";speedup@5={PAPER_FIG13['total_speedup_at_5']}")

    _runtime_section()

    if executable:
        header("fig13: executable TP compute scaling (fake devices)")
        code = """
import jax, jax.numpy as jnp, numpy as np, time
from repro.core.collaborative import make_tp_block
from repro.launch.mesh import make_mesh
import sys
n = int(sys.argv[1])
mesh = make_mesh((n,), ("model",))
rng = np.random.default_rng(0)
m, d, f = 64, 512, 2048
x = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
w1 = jnp.asarray(rng.standard_normal((d, f)), jnp.float32) * 0.05
w2 = jnp.asarray(rng.standard_normal((f, d)), jnp.float32) * 0.05
for overlap in (False, True):
    fn = make_tp_block(mesh, d, f, overlap=overlap)
    out = fn(x, w1, w2); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(10):
        out = fn(x, w1, w2)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / 10
    print(f"RESULT n={n} overlap={overlap} us={dt*1e6:.0f}")
"""
        env = dict(os.environ)
        here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.path.join(here, "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        for n in (1, 2, 4):
            env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
            try:
                r = subprocess.run([sys.executable, "-c", code, str(n)],
                                   env=env, capture_output=True, text=True,
                                   timeout=300)
                for line in r.stdout.splitlines():
                    if line.startswith("RESULT"):
                        parts = dict(kv.split("=") for kv in
                                     line.split()[1:])
                        emit(f"fig13/exec_n{parts['n']}_overlap_"
                             f"{parts['overlap']}", float(parts["us"]),
                             "tp_block_fwd")
            except subprocess.TimeoutExpired:  # pragma: no cover
                emit(f"fig13/exec_n{n}", 0.0, "timeout")


if __name__ == "__main__":
    run()
