"""CI perf-regression gate.

Compares the metrics of a fresh ``benchmarks/run.py --json`` record
against the committed baseline and **fails the build** (exit 1) when a
gated metric regressed by more than ``--max-regression`` (default 2x —
wide enough to absorb runner-to-runner variance, tight enough to catch
an accidentally de-vectorized hot path).

The baseline's ``gate`` list names the metrics under contract (the
vectorized-pool and fleet-engine tick throughputs, including the DVFS
fleet configuration); everything else in the record is informational.
A metric listed in the baseline's optional ``gate_limits`` map uses
that per-metric factor instead of ``--max-regression`` — e.g. the
observability overhead ratio ``obs/fleet_probe_overhead_ratio`` is
gated at ~1.05x against a 1.0 baseline, enforcing the "probes on
costs <= 5%" contract far tighter than the 2x throughput allowance.
When ``GITHUB_STEP_SUMMARY`` is set (any GitHub Actions job), the
metric-by-metric comparison is also appended there as a Markdown table,
so the verdicts are readable from the job page without opening logs.
Regenerate the baseline with::

    PYTHONPATH=src:. python benchmarks/run.py --json \\
        benchmarks/BENCH_baseline.json --only pool
    # then re-add the "gate" list to the file

Usage::

    python benchmarks/perf_gate.py BENCH_pr.json \\
        [--baseline benchmarks/BENCH_baseline.json] [--max-regression 2.0]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Tuple

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "BENCH_baseline.json")

# (metric, baseline, current, verdict) — baseline/current None when the
# metric is missing from that record
_Row = Tuple[str, Optional[float], Optional[float], str]


def _write_summary(rows: List[_Row], max_regression: float,
                   failed: bool) -> None:
    """Append the comparison as a Markdown table to the GitHub Actions
    job summary, when running inside one."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        "### Perf gate — " + ("FAILED" if failed else "passed"),
        "",
        f"Allowed regression: {max_regression:.1f}x vs committed baseline "
        "(per-metric overrides: baseline `gate_limits`).",
        "",
        "| metric | baseline | current | ratio | verdict |",
        "| --- | ---: | ---: | ---: | --- |",
    ]
    for name, base, cur, verdict in rows:
        mark = {"ok": "✅", "REGRESSED": "❌", "MISSING": "⚠️"}[verdict]
        base_s = f"{base:,.1f}" if base is not None else "—"
        cur_s = f"{cur:,.1f}" if cur is not None else "—"
        ratio_s = f"{cur / base:.2f}x" \
            if cur is not None and base is not None and base > 0 else "—"
        lines.append(f"| `{name}` | {base_s} | {cur_s} | {ratio_s} | "
                     f"{mark} {verdict} |")
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="--json record of the run under test")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when current < baseline / this factor")
    args = ap.parse_args()

    with open(args.current) as fh:
        current = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    gate = baseline.get("gate")
    if not gate:
        gate = sorted(m for m in baseline.get("metrics", {})
                      if "ticks_per_s" in m)
    if not gate:
        sys.exit(f"baseline {args.baseline} has no gated metrics")
    limits = baseline.get("gate_limits", {})

    failures = []
    rows: List[_Row] = []
    print(f"{'metric':44s} {'baseline':>12s} {'current':>12s} "
          f"{'ratio':>7s}  verdict")
    for name in gate:
        base = baseline.get("metrics", {}).get(name)
        cur = current.get("metrics", {}).get(name)
        if base is None:
            failures.append(
                f"{name}: gated but missing from baseline "
                f"{args.baseline} (stale gate list? regenerate the "
                "baseline and restore the gate/note fields)")
            cur_s = "---" if cur is None else f"{cur:.1f}"
            print(f"{name:44s} {'---':>12s} {cur_s:>12s} {'---':>7s}  "
                  "MISSING")
            rows.append((name, None, cur, "MISSING"))
            continue
        if cur is None:
            failures.append(f"{name}: missing from current run "
                            "(did the suite that emits it run?)")
            print(f"{name:44s} {base:12.1f} {'---':>12s} {'---':>7s}  "
                  "MISSING")
            rows.append((name, base, None, "MISSING"))
            continue
        ratio = cur / base if base > 0 else float("inf")
        limit = float(limits.get(name, args.max_regression))
        ok = cur * limit >= base
        print(f"{name:44s} {base:12.1f} {cur:12.1f} {ratio:7.2f}  "
              f"{'ok' if ok else 'REGRESSED'}")
        rows.append((name, base, cur, "ok" if ok else "REGRESSED"))
        if not ok:
            failures.append(
                f"{name}: {cur:.1f} vs baseline {base:.1f} "
                f"({base / max(cur, 1e-9):.2f}x slower; "
                f"allowed {limit:.2f}x)")
    _write_summary(rows, args.max_regression, bool(failures))
    if failures:
        sys.exit("perf gate FAILED:\n  " + "\n  ".join(failures))
    print(f"perf gate passed ({len(gate)} metrics, "
          f"max allowed regression {args.max_regression:.1f}x)")


if __name__ == "__main__":
    main()
