"""pool: steady-state tick throughput of the simulation backends.

The numbers this suite registers via ``emit_metric`` are the CI
perf-regression gate's inputs (``benchmarks/perf_gate.py`` compares
them against the committed ``benchmarks/BENCH_baseline.json`` and fails
the build on a >2x slowdown of the vectorized paths):

  * ``pool/{scalar,vector}_ticks_per_s`` — a single 60-SoC rack under
    the full DVFS + thermal stack (schedutil governor, RC network,
    trip latches), ticked at steady 50% load;
  * ``fleet/{scalar,vector}_rack_ticks_per_s`` — rack-ticks/s of the
    fleet engines (binary gating, join-shortest-queue router) at
    steady 50% load;
  * ``fleet_dvfs/{scalar,vector}_rack_ticks_per_s`` — the same fleet
    measurement with the full frequency axis on every rack (schedutil
    governor over the SD865 OPP table plus the stacked RC thermal
    network), i.e. the paper-relevant energy-proportionality
    configuration running on the array path;
  * ``fleet_chaos/vector_rack_ticks_per_s`` — the binary-gating fleet
    measurement with an *active* chaos schedule (randomized kills, fan
    failures, and power caps cycling through the measured window, plus
    the per-tick mask application and respill routing in the driver
    loop) — chaos masking must not knock the vector engine off its
    fast path;
  * ``fleet_degrade/vector_rack_ticks_per_s`` — the binary-gating fleet
    measurement with the full graceful-degradation control plane active
    (tiered admission, deadline expiry, breakers, retry ring, per-tier
    request splitting) at 90% load — the control plane's per-tick cost
    is gated against the baseline so it stays a thin shim over the
    vector fast path;
  * ``obs/fleet_probe_overhead_ratio`` (plus the probes-on rate
    ``obs/fleet_probes_on_rack_ticks_per_s``) — probes-enabled over
    probes-disabled vector fleet tick rate, both arms interleaved per
    rep so machine drift cancels; the ratio is gated at >= 0.95 via the
    baseline's per-metric ``gate_limits`` entry, enforcing the
    observability overhead contract (probes on costs <= 5%);
  * ``fleet_jax/vector_sweep_scenarios_per_s`` — scenarios/s of the
    jax engine's batched :func:`repro.fleet.sweep` (32 fig15-style
    configs x 50 racks, warm compile cache), the vmap/pmap path the
    fig16 speedup criterion rides on. Skipped (not emitted) when jax
    is unavailable — CI installs jax in the perf-gate job, so a
    missing metric there means the sweep path broke, and the gate
    reports it as MISSING.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, emit_metric, header
from repro.core.cluster import soc_cluster
from repro.fleet import (Fleet, JoinShortestQueueRouter, diurnal_trace,
                         homogeneous_fleet)
from repro.power import SchedutilGovernor, ThermalParams, sd865_opp_table
from repro.runtime import ClusterRuntime, QueueWorkload, ScalePolicy

UNIT_RATE = 10.0


def _rack_ticks_per_s(backend: str, ticks: int = 300, reps: int = 3,
                      warmup: int = 50) -> float:
    """Best-of-``reps`` steady-state ticks/s of one DVFS+thermal rack."""
    best = 0.0
    for _ in range(reps):
        spec = soc_cluster()
        rt = ClusterRuntime(
            spec, QueueWorkload(unit_rate=UNIT_RATE),
            policy=ScalePolicy(freq_governor=SchedutilGovernor()),
            opp_table=sd865_opp_table(), thermal=ThermalParams(),
            dt_s=1.0, backend=backend)
        offered = 0.5 * UNIT_RATE * spec.n_units
        for _ in range(warmup):
            rt.submit(cost=offered, count=offered)
            rt.tick()
        t0 = time.perf_counter()
        for _ in range(ticks):
            rt.submit(cost=offered, count=offered)
            rt.tick()
        best = max(best, ticks / (time.perf_counter() - t0))
    return best


def _fleet_rack_ticks_per_s(backend: str, n_racks: int, ticks: int,
                            reps: int = 3, warmup: int = 10,
                            dvfs: bool = False) -> float:
    """Best-of-``reps`` steady-state rack-ticks/s of a fleet engine;
    ``dvfs=True`` attaches the full frequency axis (schedutil + SD865
    table + RC thermal network) to every rack."""
    best = 0.0
    for _ in range(reps):
        policy, kwargs = None, {}
        if dvfs:
            policy = ScalePolicy(freq_governor=SchedutilGovernor())
            kwargs = dict(opp_table=sd865_opp_table(),
                          thermal=ThermalParams())
        fleet = Fleet(
            homogeneous_fleet(soc_cluster(), n_racks, unit_rate=30.0,
                              policy=policy, **kwargs),
            router=JoinShortestQueueRouter(), dt_s=60.0, backend=backend)
        total = 0.5 * fleet.capacity_rps
        for _ in range(warmup):
            assign = fleet.router.route(total, fleet.view())
            fleet.engine.tick(np.asarray(assign, float), fleet.dt_s)
        t0 = time.perf_counter()
        for _ in range(ticks):
            assign = fleet.router.route(total, fleet.view())
            fleet.engine.tick(np.asarray(assign, float), fleet.dt_s)
        best = max(best, n_racks * ticks / (time.perf_counter() - t0))
    return best


def _fleet_chaos_rack_ticks_per_s(n_racks: int = 100, ticks: int = 400,
                                  reps: int = 3, warmup: int = 10
                                  ) -> float:
    """Best-of-``reps`` rack-ticks/s of the vector fleet engine with an
    active chaos schedule — same shape as the plain fleet metric, but
    every tick also applies the lowered fault masks and routes any
    respilled backlog (the driver loop ``Fleet.play_trace`` runs). The
    schedule is seeded, with enough events that kills/fan-rail
    failures/power caps keep toggling inside the measured window."""
    from repro.fleet import ChaosSchedule

    best = 0.0
    dt = 60.0
    horizon = (warmup + ticks) * dt
    for _ in range(reps):
        fleet = Fleet(
            homogeneous_fleet(soc_cluster(), n_racks, unit_rate=30.0),
            router=JoinShortestQueueRouter(), dt_s=dt, backend="vector",
            chaos=ChaosSchedule.random(n_racks, horizon, seed=5,
                                       n_events=12))
        total = 0.5 * fleet.capacity_rps
        for _ in range(warmup):
            t = total + fleet._chaos_step()
            assign = fleet.router.route(t, fleet.view())
            fleet.engine.tick(np.asarray(assign, float), dt)
        t0 = time.perf_counter()
        for _ in range(ticks):
            t = total + fleet._chaos_step()
            assign = fleet.router.route(t, fleet.view())
            fleet.engine.tick(np.asarray(assign, float), dt)
        best = max(best, n_racks * ticks / (time.perf_counter() - t0))
    return best


def _fleet_degrade_rack_ticks_per_s(n_racks: int = 100, ticks: int = 400,
                                    reps: int = 3, warmup: int = 10
                                    ) -> float:
    """Best-of-``reps`` rack-ticks/s of the vector fleet engine with the
    full graceful-degradation control plane active — every tick runs
    deadline expiry, the breaker state machine, retry-ring release,
    tiered admission (``DegradeDriver.pre_route``), and the three-way
    tier split of each rack's submission (``_tier_requests``). Offered
    load sits at 90% of capacity with tight deadline budgets so the
    admission/retry paths do real work inside the measured window."""
    from repro.distributed.fault import RetryPolicy
    from repro.fleet import BreakerConfig, DegradePolicy, TierSpec

    best = 0.0
    dt = 60.0
    for _ in range(reps):
        policy = DegradePolicy(
            tiers=(TierSpec("gold", 0.2, 600.0),
                   TierSpec("silver", 0.3, 300.0),
                   TierSpec("bulk", 0.5, 120.0)),
            queue_deadline_s=600.0,
            breaker=BreakerConfig(open_after_s=300.0, close_below_s=120.0,
                                  cooldown_s=600.0, probe_fraction=0.25,
                                  fail_timeout_s=120.0),
            retry=RetryPolicy(max_attempts=3, backoff_s=120.0, jitter=0.5),
            seed=5)
        fleet = Fleet(
            homogeneous_fleet(soc_cluster(), n_racks, unit_rate=30.0),
            router=JoinShortestQueueRouter(), dt_s=dt, backend="vector",
            degrade=policy)
        rps = 0.9 * fleet.capacity_rps
        for _ in range(warmup):
            total, split, view = fleet._degrade_pre(rps, 0.0)
            assign = np.asarray(fleet.router.route(total, view), float)
            fleet.engine.tick(assign, dt, tier_split=split)
        t0 = time.perf_counter()
        for _ in range(ticks):
            total, split, view = fleet._degrade_pre(rps, 0.0)
            assign = np.asarray(fleet.router.route(total, view), float)
            fleet.engine.tick(assign, dt, tier_split=split)
        best = max(best, n_racks * ticks / (time.perf_counter() - t0))
    return best


def _fleet_obs_overhead(n_racks: int = 100, ticks: int = 400,
                        reps: int = 5, warmup: int = 10
                        ) -> "tuple[float, float]":
    """Probes-on rack-ticks/s and on/off tick-rate ratio of the vector
    fleet engine (same shape as ``fleet/vector_rack_ticks_per_s``).
    Returns ``(on, ratio)``. Each rep runs the off and on arms
    back-to-back and the ratio is taken *within* the rep, so slow
    machine drift cancels pairwise; the gate uses the *median* rep's
    ratio — a genuine probe-path regression depresses every rep, while
    a noisy-neighbor window only poisons the reps it overlaps."""
    from repro.obs import FleetObs, MemorySink, ProbeRegistry

    best_on = 0.0
    ratios = []
    for _ in range(reps):
        rates = {}
        for probes_on in (False, True):
            obs = (FleetObs(probes=ProbeRegistry([MemorySink()]))
                   if probes_on else None)
            fleet = Fleet(
                homogeneous_fleet(soc_cluster(), n_racks, unit_rate=30.0),
                router=JoinShortestQueueRouter(), dt_s=60.0,
                backend="vector", obs=obs)
            total = 0.5 * fleet.capacity_rps
            for _ in range(warmup):
                assign = fleet.router.route(total, fleet.view())
                fleet.engine.tick(np.asarray(assign, float), fleet.dt_s)
            t0 = time.perf_counter()
            for _ in range(ticks):
                assign = fleet.router.route(total, fleet.view())
                fleet.engine.tick(np.asarray(assign, float), fleet.dt_s)
            rates[probes_on] = n_racks * ticks / (time.perf_counter() - t0)
        best_on = max(best_on, rates[True])
        ratios.append(rates[True] / rates[False])
    ratios.sort()
    return best_on, ratios[len(ratios) // 2]


def _jax_sweep_scenarios_per_s(n_cfg: int = 32, n_racks: int = 50,
                               reps: int = 2) -> float:
    """Best-of-``reps`` scenarios/s of the batched jax ``sweep`` over a
    24 h diurnal trace (binary-gating racks, the fig16 sweep shape).
    The first call pays XLA compilation; it warms the compile cache and
    is excluded from timing, so the metric tracks the steady-state
    batched-dispatch rate CI actually depends on."""
    from repro.fleet import SweepConfig, sweep

    racks = homogeneous_fleet(soc_cluster(), n_racks, unit_rate=30.0,
                              policy=ScalePolicy(cooldown_s=300.0))
    capacity = sum(rc.spec.n_units * rc.unit_rate for rc in racks)
    trace = 0.5 * capacity * diurnal_trace(peak_rps=1.0, hours=24,
                                           dt_s=300.0, seed=11)
    routers = ("round-robin", "join-shortest-queue", "power-aware")
    configs = [
        SweepConfig(router=routers[i % 3],
                    headroom_scale=0.9 + 0.05 * (i % 8),
                    trace_scale=0.8 + 0.05 * (i % 6),
                    name=f"cfg{i}")
        for i in range(n_cfg)
    ]
    sweep(racks, configs, trace, dt_s=300.0)  # compile warm-up
    best = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        rows = sweep(racks, configs, trace, dt_s=300.0)
        assert len(rows) == n_cfg
        best = max(best, n_cfg / (time.perf_counter() - t0))
    return best


def run() -> None:
    header("pool: steady-state tick throughput (scalar vs vector)")
    scalar = _rack_ticks_per_s("scalar")
    vector = _rack_ticks_per_s("vector")
    emit_metric("pool/scalar_ticks_per_s", scalar)
    emit_metric("pool/vector_ticks_per_s", vector)
    emit("pool/rack_speedup", 0.0, f"vector_over_scalar={vector/scalar:.2f}x")
    f_scalar = _fleet_rack_ticks_per_s("scalar", n_racks=20, ticks=60)
    f_vector = _fleet_rack_ticks_per_s("vector", n_racks=100, ticks=400)
    emit_metric("fleet/scalar_rack_ticks_per_s", f_scalar)
    emit_metric("fleet/vector_rack_ticks_per_s", f_vector)
    emit("fleet/rack_speedup", 0.0,
         f"vector_over_scalar={f_vector/f_scalar:.2f}x")
    d_scalar = _fleet_rack_ticks_per_s("scalar", n_racks=20, ticks=40,
                                       dvfs=True)
    d_vector = _fleet_rack_ticks_per_s("vector", n_racks=100, ticks=300,
                                       dvfs=True)
    emit_metric("fleet_dvfs/scalar_rack_ticks_per_s", d_scalar)
    emit_metric("fleet_dvfs/vector_rack_ticks_per_s", d_vector)
    emit("fleet_dvfs/rack_speedup", 0.0,
         f"vector_over_scalar={d_vector/d_scalar:.2f}x")
    c_vector = _fleet_chaos_rack_ticks_per_s()
    emit_metric("fleet_chaos/vector_rack_ticks_per_s", c_vector)
    emit("fleet_chaos/overhead", 0.0,
         f"chaos_over_plain={c_vector/f_vector:.2f}x")
    g_vector = _fleet_degrade_rack_ticks_per_s()
    emit_metric("fleet_degrade/vector_rack_ticks_per_s", g_vector)
    emit("fleet_degrade/overhead", 0.0,
         f"degrade_over_plain={g_vector/f_vector:.2f}x")
    o_on, o_ratio = _fleet_obs_overhead()
    emit_metric("obs/fleet_probes_on_rack_ticks_per_s", o_on)
    emit_metric("obs/fleet_probe_overhead_ratio", o_ratio)
    try:
        j_sweep = _jax_sweep_scenarios_per_s()
    except ImportError:
        emit("fleet_jax/sweep", 0.0, "skipped (jax unavailable)")
    else:
        emit_metric("fleet_jax/vector_sweep_scenarios_per_s", j_sweep)


if __name__ == "__main__":
    run()
