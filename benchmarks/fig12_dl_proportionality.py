"""Fig 12 — DL-serving energy efficiency under dynamic load: SoC Cluster
(per-unit gating) vs A100 (monolithic), via the unified
``ClusterRuntime`` request-lifecycle loop."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header
from repro.core.cluster import a100_server, soc_cluster
from repro.runtime import ClusterRuntime, DLServingWorkload, ScalePolicy
from repro.workloads.dlserving import PAPER_CLAIMS, point


def run() -> None:
    header("fig12: TpE under varying offered load (resnet-50 fp32)")
    soc = soc_cluster()
    a100 = a100_server()
    r50_soc = point("resnet-50", "fp32", "soc-gpu")
    per_soc_rate = 1000.0 / r50_soc.latency_ms          # samples/s per SoC
    soc_rate = per_soc_rate * soc.n_units
    a100_rate = 64 / 0.115                              # batch64/115ms

    # Paper methodology: SoCs not needed go to a low-power state (0.6 W);
    # the A100 keeps running micro-batches and stays near its serving power.
    import math
    ratios = {}
    for samples_s in (5.0, 0.01 * soc_rate, 0.2 * soc_rate,
                      0.5 * soc_rate, soc_rate):
        active = min(soc.n_units, math.ceil(samples_s / per_soc_rate))
        p_soc = (active * r50_soc.unit_power_w
                 + (soc.n_units - active) * soc.unit.p_idle)
        u_a100 = min(1.0, samples_s / a100_rate)
        # Measured A100 *serving* power is nearly flat with load (batch
        # collection keeps SMs clocked): gamma ~ 0.1, vs 0.45 generic.
        p_a100 = a100.unit.p_idle + (a100.unit.p_peak - a100.unit.p_idle) \
            * (u_a100 ** 0.1)
        tpe_soc = samples_s / p_soc
        tpe_a100 = min(samples_s, a100_rate) / p_a100
        ratios[samples_s] = tpe_soc / tpe_a100
        emit(f"fig12/load_{samples_s:.0f}sps", 0.0,
             f"soc_tpe={tpe_soc:.3f};a100_tpe={tpe_a100:.3f};"
             f"ratio={tpe_soc/tpe_a100:.2f}x")
    emit("fig12/light_load_advantage", 0.0,
         f"soc_vs_a100@5sps={ratios[5.0]:.2f}x;paper="
         f"{PAPER_CLAIMS['light_load_vs_a100']}x")

    header("fig12: runtime-driven (bursty trace, gated concurrency)")
    workload = DLServingWorkload.from_point("resnet-50", "fp32", "soc-gpu")
    runtime = ClusterRuntime(soc, workload,
                             policy=ScalePolicy(cooldown_s=20.0))
    rng = np.random.default_rng(0)
    trace = np.abs(rng.normal(0.1, 0.08, 600)) * soc_rate
    res = runtime.play_trace(trace, dt_s=1.0)
    emit("fig12/runtime_bursty", 0.0,
         f"served={res.served:.0f};tpe={res.tpe:.2f};"
         f"mean_active={res.mean_active:.1f}/60;"
         f"p99_latency_s={res.p99_latency_s:.2f}")
    # static baseline: all units on, each at the trace's mean utilization
    static_j = runtime.static_baseline_energy(
        utilization=float(trace.mean()) / (workload.unit_rate
                                           * soc.n_units))
    emit("fig12/runtime_vs_static", 0.0,
         f"elastic_j={res.energy_j:.0f};static_j={static_j:.0f};"
         f"saving={1 - res.energy_j / static_j:.0%}")


if __name__ == "__main__":
    run()
