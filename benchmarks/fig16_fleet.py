"""Fig 16 (extension) — fleet-scale serving: 120 racks, millions of
users, vectorized.

The paper measures one 60-SoC rack; public edge platforms aggregate
hundreds of such sites behind geo-routed load balancers, and fleet-level
conclusions can flip versus single-rack ones. This benchmark drives a
**mixed 120-rack fleet** (100 SoC-Cluster racks + 20 Xeon edge racks,
~180k req/s aggregate capacity ≈ 4.5M users at 0.02 req/s/user) through
``repro.fleet``:

  1. **Headline sweep** — 24 h diurnal at 50% fleet peak,
     join-shortest-queue vs power-aware routing: power-aware packs load
     onto the energy-cheap SoC racks (J/request ranking) and must beat
     JSQ on energy; both finish the 100+-rack x 24 h sweep in seconds
     on the vectorized engine.
  2. **Flash crowd** — capacity-oblivious round-robin drowns the small
     Xeon racks during an 8x spike; JSQ must hold a (much) lower p95.
     (Round-robin is excluded from the 24 h sweep for the same reason:
     uniform shares overload the small racks for hours of simulated
     time.)
  3. **Backend parity** — the same small fleet run under
     ``backend="scalar"`` and ``"vector"`` must produce bitwise-equal
     energy and power series.
  4. **DVFS fleet** — 100 SoC racks under the full frequency axis
     (schedutil governor over the SD865 OPP table + RC thermal
     network): the 24 h sweep runs on the vector engine, the frequency
     axis beats binary gating alone on energy at comparable p95, and a
     small DVFS fleet matches the scalar engine bitwise (energy, power,
     temperature/throttle/fan series).
  5. **JAX backend** — the jax engine replays the fleets of steps
     1/2/4 and must match the vector oracle within the documented
     tolerance (``JAX_RTOL``; the scalar/vector pair stays bitwise),
     then ``repro.fleet.sweep`` batches 64 fig15-style policy configs
     x 100 racks through one vmapped program, cross-checks a sample
     against dedicated vector runs, and must beat looping the vector
     engine by >= ``MIN_SWEEP_SPEEDUP`` (5x) wall-clock — the
     payoff the jax backend exists for. Skipped cleanly when jax is
     not installed; selectable fleet-wide via ``run.py --backend``.
  6. **Chaos** — correlated fault injection (``repro.fleet.chaos``):
     10% of the mixed fleet's racks are killed at the peak operating
     point and the recovery metrics must be non-vacuous —
     join-shortest-queue re-converges (rolling p95 back within 10% of
     the pre-fault baseline) in fewer ticks than capacity-oblivious
     round-robin, whose uniform shares tip the small Xeon racks over
     capacity while the SoC racks are dark; and on a flash crowd whose
     spike coincides with the kill, straggler hedging cuts the
     recovery-window p99 (the respill surge pushes queue waits past
     ``hedge_after_s`` while scale-up is still cooldown-gated).
  6b. **Degradation** — the graceful-degradation control plane
     (``repro.fleet.degrade``) through the same flash crowd with a
     two-rack kill at its peak: tiered admission + breakers must hold
     the gold tier's p99 within 1.5x of the pre-fault baseline and cut
     re-convergence vs the accept-everything fleet, at a terminal loss
     bounded under 10% of injected mass; scalar/vector stay bitwise on
     every shed/retry/breaker counter and jax matches within
     ``JAX_RTOL``.
  7. **Throughput** — steady-state rack-ticks/s of the vector engine
     must be >= 10x the scalar engine's, both on the binary-gating
     mixed fleet and with the frequency governor + thermal stack
     enabled — the configuration the PR 4 engine rejected outright
     (also registered for the CI perf gate).

Asserts are enforced inline, like fig14/fig15. Under ``run.py --fast``
(the CI tier-1 smoke) the machine-timing assertions of steps 1, 5
and 7 are skipped — on shared runners a noisy neighbor could fail the
*functional* job on wall-clock alone; the dedicated CI perf-gate job
(``benchmarks/perf_gate.py``, 2x headroom) owns performance-regression
detection there. A default (non-fast) run checks everything.
"""
from __future__ import annotations

import itertools
import time
from typing import List, Optional

import numpy as np

from benchmarks.common import emit, emit_metric, header
from repro.core.cluster import edge_server_cpu, soc_cluster
from repro.fleet import (ChaosSchedule, Fleet, FleetTelemetry,
                         JoinShortestQueueRouter, PowerAwareRouter,
                         RackConfig, RoundRobinRouter, Router,
                         diurnal_trace, flash_crowd_trace, hedging_delta,
                         homogeneous_fleet, scale_to_users)
from repro.power import SchedutilGovernor, ThermalParams, sd865_opp_table
from repro.runtime import ScalePolicy

SOC_UNIT_RATE = 30.0      # resnet-50-class req/s per SD865 (Table 7)
CPU_UNIT_RATE = 9.0       # per 8-core Xeon container (Table 3 scale)
DT_S = 60.0
RPS_PER_USER = 0.02       # one request per 50 s per user at daily peak
MIN_SPEEDUP = 10.0
# jax engine contract: tolerance parity (XLA reorders/fuses float ops),
# not bitwise — observed worst-case relative error across the fig16
# scenario set is ~3e-12 (latency percentiles); 1e-9 leaves headroom
JAX_RTOL = 1e-9
MIN_SWEEP_SPEEDUP = 5.0


def _policy() -> ScalePolicy:
    return ScalePolicy(cooldown_s=300.0, min_units=1)


def _mixed_fleet(n_soc: int, n_cpu: int, backend: str,
                 router: Router) -> Fleet:
    racks: List[RackConfig] = homogeneous_fleet(
        soc_cluster(), n_soc, SOC_UNIT_RATE, policy=_policy())
    racks += homogeneous_fleet(
        edge_server_cpu(), n_cpu, CPU_UNIT_RATE, policy=_policy())
    return Fleet(racks, router=router, dt_s=DT_S, backend=backend)


def _sweep(router: Router, trace: np.ndarray,
           backend: str = "vector", n_soc: int = 100,
           n_cpu: int = 20) -> FleetTelemetry:
    return _mixed_fleet(n_soc, n_cpu, backend, router).play_trace(trace)


def _dvfs_fleet(n_racks: int, backend: str, router: Router,
                dvfs: bool = True) -> Fleet:
    """Homogeneous SoC fleet; ``dvfs=True`` puts the full frequency
    axis on every rack (schedutil over the SD865 table + RC thermal
    network), ``dvfs=False`` is the binary-gating baseline — the only
    configuration the PR 4 vector engine could sweep."""
    policy = ScalePolicy(
        cooldown_s=300.0, min_units=1,
        freq_governor=SchedutilGovernor() if dvfs else None)
    racks = homogeneous_fleet(
        soc_cluster(), n_racks, SOC_UNIT_RATE, policy=policy,
        opp_table=sd865_opp_table() if dvfs else None,
        thermal=ThermalParams() if dvfs else None)
    return Fleet(racks, router=router, dt_s=DT_S, backend=backend)


def _engine_rack_ticks_per_s(backend: str, ticks: int, reps: int = 3,
                             load_frac: float = 0.5,
                             dvfs: bool = False) -> float:
    """Best-of-``reps`` steady-state rack-ticks/s of a fleet engine on
    the full 120-rack mixed fleet (or, with ``dvfs=True``, a 120-rack
    schedutil + thermal SoC fleet)."""
    best = 0.0
    for _ in range(reps):
        fleet = _dvfs_fleet(120, backend, JoinShortestQueueRouter()) \
            if dvfs else _mixed_fleet(100, 20, backend,
                                      JoinShortestQueueRouter())
        total = load_frac * fleet.capacity_rps
        for _ in range(10):
            assign = fleet.router.route(total, fleet.view())
            fleet.engine.tick(np.asarray(assign, float), DT_S)
        t0 = time.perf_counter()
        for _ in range(ticks):
            assign = fleet.router.route(total, fleet.view())
            fleet.engine.tick(np.asarray(assign, float), DT_S)
        best = max(best, fleet.n_racks * ticks / (time.perf_counter() - t0))
    return best


def _maxrel(a: np.ndarray, b: np.ndarray) -> float:
    a = np.asarray(a, float)
    b = np.asarray(b, float)
    if a.shape != b.shape:
        return float("inf")
    if a.size == 0:
        return 0.0
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-9)))


def _jax_section(perf: bool, short: np.ndarray, crowd: np.ndarray,
                 dvfs_short: np.ndarray, d_v: FleetTelemetry) -> None:
    """jax engine: tolerance parity over the fig16 scenario set, then
    the batched ``sweep()`` against a looped vector engine."""
    try:
        import jax  # noqa: F401
    except Exception:
        emit("fig16/jax_parity", 0.0, "skipped (jax unavailable)")
        return
    from repro.fleet import SweepConfig, sweep

    pairs = []
    for router_cls in (JoinShortestQueueRouter, PowerAwareRouter):
        pairs.append((
            f"mixed_{router_cls().name}",
            _sweep(router_cls(), short, backend="vector", n_soc=8, n_cpu=2),
            _sweep(router_cls(), short, backend="jax", n_soc=8, n_cpu=2)))
    pairs.append((
        "flash_rr",
        _sweep(RoundRobinRouter(), crowd, backend="vector", n_soc=8,
               n_cpu=2),
        _sweep(RoundRobinRouter(), crowd, backend="jax", n_soc=8, n_cpu=2)))
    pairs.append((
        "dvfs_jsq", d_v,
        _dvfs_fleet(6, "jax", JoinShortestQueueRouter())
        .play_trace(dvfs_short)))
    worst = 0.0
    for label, tv, tj in pairs:
        assert tv.ticks == tj.ticks and tv.drained == tj.drained, \
            f"fig16 jax parity: {label} tick/drain mismatch"
        for series in ("energy_j", "power_w", "active_units", "queued",
                       "p50_latency_s", "p95_latency_s", "p99_latency_s"):
            r = _maxrel(getattr(tv, series), getattr(tj, series))
            worst = max(worst, r)
            assert r <= JAX_RTOL, (
                f"fig16 jax parity: {label}/{series} relative error "
                f"{r:.2e} > {JAX_RTOL:g}")
    emit("fig16/jax_parity", 0.0,
         f"scenarios={len(pairs)};max_relerr={worst:.2e};rtol={JAX_RTOL:g}")

    if not perf:
        emit("fig16/jax_sweep_speedup", 0.0, "skipped (--fast)")
        return
    # batched policy sweep: 64 fig15-style configs x 100 racks x 24 h in
    # one XLA program vs looping the numpy vector engine config by
    # config. The loop cost is measured over 8 configs and extrapolated
    # linearly (it is embarrassingly per-config); the jax time is a
    # warmed steady-state call — compile amortizes across sweeps.
    n_cfg, n_racks, n_vec = 64, 100, 8
    policy = _policy()
    sw_racks = homogeneous_fleet(soc_cluster(), n_racks, SOC_UNIT_RATE,
                                 policy=policy)
    sw_capacity = sum(rc.spec.n_units * SOC_UNIT_RATE for rc in sw_racks)
    sw_trace = 0.5 * sw_capacity * diurnal_trace(
        peak_rps=1.0, hours=24, dt_s=300.0, seed=16)
    cfgs = [
        SweepConfig(router=rt, headroom_scale=hr, trace_scale=ts,
                    name=f"c{i}")
        for i, (rt, hr, ts) in enumerate(itertools.islice(
            itertools.product(("round-robin", "join-shortest-queue",
                               "power-aware"),
                              (0.85, 1.0, 1.15, 1.3),
                              (0.7, 0.85, 1.0, 1.15, 1.3, 1.45)), n_cfg))
    ]
    sweep(sw_racks, cfgs, sw_trace, dt_s=300.0)  # compile + warm
    t0 = time.perf_counter()
    rows = sweep(sw_racks, cfgs, sw_trace, dt_s=300.0)
    t_jax = time.perf_counter() - t0
    t0 = time.perf_counter()
    for cfg, row in zip(cfgs[:n_vec], rows[:n_vec]):
        v_policy = ScalePolicy(
            cooldown_s=policy.cooldown_s, min_units=policy.min_units,
            headroom=policy.headroom * cfg.headroom_scale)
        fleet = Fleet(
            homogeneous_fleet(soc_cluster(), n_racks, SOC_UNIT_RATE,
                              policy=v_policy),
            router={"round-robin": RoundRobinRouter,
                    "join-shortest-queue": JoinShortestQueueRouter,
                    "power-aware": PowerAwareRouter}[cfg.router](),
            dt_s=300.0, backend="vector")
        tel = fleet.play_trace(cfg.trace_scale * sw_trace)
        # the batched rows must agree with the per-config vector run
        assert tel.drained and row["drained"], cfg.name
        for key in ("served", "energy_kwh", "p95_latency_s"):
            r = _maxrel(np.asarray(tel.summary()[key]),
                        np.asarray(row[key]))
            assert r <= JAX_RTOL, (
                f"fig16 jax sweep: {cfg.name}/{key} relative error "
                f"{r:.2e} > {JAX_RTOL:g}")
    t_vec = (time.perf_counter() - t0) / n_vec * n_cfg
    speedup = t_vec / t_jax
    emit_metric("fig16/jax_sweep_scenarios_per_s", n_cfg / t_jax)
    emit_metric("fig16/vector_loop_scenarios_per_s", n_cfg / t_vec)
    emit("fig16/jax_sweep_speedup", 0.0,
         f"configs={n_cfg};racks={n_racks};jax_s={t_jax:.2f};"
         f"vector_est_s={t_vec:.1f};speedup={speedup:.1f}x")
    assert speedup >= MIN_SWEEP_SPEEDUP, (
        f"batched jax sweep must be >= {MIN_SWEEP_SPEEDUP:.0f}x a looped "
        f"vector engine (measured {speedup:.1f}x)")


def _chaos_section() -> None:
    """Correlated rack kills (10% of racks) at the peak operating
    point; both sub-scenarios are deterministic (steady plateau /
    seeded crowd) so the recovery asserts are exact."""
    def chaos_racks(hedge: Optional[float] = None) -> List[RackConfig]:
        pol = ScalePolicy(cooldown_s=300.0, min_units=1,
                          hedge_after_s=hedge)
        racks = homogeneous_fleet(soc_cluster(), 16, SOC_UNIT_RATE,
                                  policy=pol)
        racks += homogeneous_fleet(edge_server_cpu(), 4, CPU_UNIT_RATE,
                                   policy=pol)
        return racks

    # (a) JSQ vs capacity-oblivious RR through the same kill. The
    # plateau sits at the load RR can run this fleet at all (uniform
    # shares just under the Xeon racks' capacity — see the section-2
    # note); the kill tips the live-rack share over it, so RR strands
    # backlog on the small racks while JSQ routes around the hole.
    plateau = np.full(360, 1700.0)

    def kill_sched() -> ChaosSchedule:
        sched = ChaosSchedule(on_kill="respill")
        sched.kill_rack(0, start_s=120 * DT_S, end_s=180 * DT_S)
        sched.kill_rack(1, start_s=120 * DT_S, end_s=180 * DT_S)
        return sched

    recov = {}
    for router_cls in (JoinShortestQueueRouter, RoundRobinRouter):
        fleet = Fleet(chaos_racks(), router=router_cls(), dt_s=DT_S,
                      backend="vector", chaos=kill_sched(), sanitize=True)
        tel = fleet.play_trace(plateau)
        rec = tel.recovery
        assert tel.drained and rec is not None
        recov[router_cls.name] = rec
        emit(f"fig16/chaos_{router_cls.name}", 0.0,
             f"reconvergence_ticks={rec.reconvergence_ticks};"
             f"p99_blowup={rec.p99_blowup:.2f};"
             f"baseline_p95_s={rec.baseline_p95_s:.1f}")
    jsq_r, rr_r = (recov["join-shortest-queue"], recov["round-robin"])
    assert rr_r.reconvergence_ticks is not None \
        and rr_r.reconvergence_ticks > 0 and rr_r.p99_blowup > 1.0, \
        "the rack kill must visibly degrade round-robin (non-vacuous)"
    assert jsq_r.reconvergence_ticks is not None \
        and jsq_r.reconvergence_ticks < rr_r.reconvergence_ticks, \
        "JSQ must re-converge faster than round-robin after a rack kill"

    # (b) hedging benefit: the kill lands at a flash crowd's peak, the
    # dead racks' queues respill onto already-loaded survivors, and
    # waits cross hedge_after_s while cooldown still gates scale-up —
    # exactly the window hedged borrowing exists for.
    chaos_cap = sum(rc.spec.n_units * rc.unit_rate for rc in chaos_racks())
    crowd = flash_crowd_trace(base_rps=0.3 * chaos_cap, spike_mult=4.0,
                              hours=2.0, dt_s=DT_S, seed=16)
    peak_tick = int(np.argmax(crowd))

    def crowd_sched() -> ChaosSchedule:
        sched = ChaosSchedule(on_kill="respill")
        sched.kill_rack(0, start_s=peak_tick * DT_S,
                        end_s=(peak_tick + 30) * DT_S)
        sched.kill_rack(1, start_s=peak_tick * DT_S,
                        end_s=(peak_tick + 30) * DT_S)
        return sched

    tel = Fleet(chaos_racks(hedge=180.0), router=JoinShortestQueueRouter(),
                dt_s=DT_S, backend="vector", chaos=crowd_sched(),
                sanitize=True).play_trace(crowd)
    assert tel.respilled_requests > 0, \
        "kill at the crowd peak must evacuate a non-empty queue"
    delta = hedging_delta(chaos_racks(hedge=180.0), crowd, crowd_sched(),
                          dt_s=DT_S, router=JoinShortestQueueRouter())
    emit("fig16/chaos_hedging", 0.0,
         f"respilled={tel.respilled_requests};"
         f"with_hedge_p99_s={delta['recovery_p99_with_hedge_s']:.1f};"
         f"without_hedge_p99_s={delta['recovery_p99_without_hedge_s']:.1f};"
         f"benefit_s={delta['hedging_benefit_s']:.1f}")
    assert delta["hedging_benefit_s"] > 0.0, \
        "hedging must cut the recovery-window p99 (non-vacuously)"


def _degrade_section() -> None:
    """Graceful degradation (``repro.fleet.degrade``): the flash crowd
    of the chaos section with a two-rack kill at its peak, run through
    the degrade control plane (tiered admission + deadline shedding +
    breakers + seeded retry) vs the same fleet accepting everything.
    The payoff claims: gold-tier p99 holds within tolerance of the
    pre-fault baseline, re-convergence beats the accept-everything
    fleet, and the price is a bounded shed rate — plus the standing
    parity contract on every degrade counter."""
    from repro.distributed.fault import RetryPolicy
    from repro.fleet import (BreakerConfig, DegradePolicy, TierSpec,
                             tier_latency_percentiles)

    def degrade_racks() -> List[RackConfig]:
        pol = ScalePolicy(cooldown_s=300.0, min_units=1)
        racks = homogeneous_fleet(soc_cluster(), 16, SOC_UNIT_RATE,
                                  policy=pol)
        racks += homogeneous_fleet(edge_server_cpu(), 4, CPU_UNIT_RATE,
                                   policy=pol)
        return racks

    cap = sum(rc.spec.n_units * rc.unit_rate for rc in degrade_racks())
    crowd = flash_crowd_trace(base_rps=0.3 * cap, spike_mult=4.0,
                              hours=2.0, dt_s=DT_S, seed=16)
    peak_tick = int(np.argmax(crowd))  # spike peaks ~1.28x capacity

    def kill_sched() -> ChaosSchedule:
        sched = ChaosSchedule(on_kill="respill")
        sched.kill_rack(0, start_s=peak_tick * DT_S,
                        end_s=(peak_tick + 30) * DT_S)
        sched.kill_rack(1, start_s=peak_tick * DT_S,
                        end_s=(peak_tick + 30) * DT_S)
        return sched

    def degrade_policy() -> DegradePolicy:
        return DegradePolicy(
            tiers=(TierSpec("gold", 0.2, 600.0),
                   TierSpec("silver", 0.3, 300.0),
                   TierSpec("bulk", 0.5, 120.0)),
            queue_deadline_s=600.0,
            breaker=BreakerConfig(open_after_s=300.0, close_below_s=120.0,
                                  cooldown_s=600.0, probe_fraction=0.25,
                                  fail_timeout_s=120.0),
            retry=RetryPolicy(max_attempts=3, backoff_s=120.0, jitter=0.5),
            seed=16)

    def run_fleet(backend: str, degrade: Optional[DegradePolicy],
                  chaos: Optional[ChaosSchedule]) -> FleetTelemetry:
        return Fleet(degrade_racks(), router=JoinShortestQueueRouter(),
                     dt_s=DT_S, backend=backend, chaos=chaos,
                     degrade=degrade, sanitize=True).play_trace(crowd)

    base = run_fleet("vector", degrade_policy(), None)   # pre-fault
    deg = run_fleet("vector", degrade_policy(), kill_sched())
    raw = run_fleet("vector", None, kill_sched())        # accept all
    assert deg.drained and raw.drained and base.drained

    # (a) the gold tier is protected: its p99 under the fault stays
    # within tolerance of the pre-fault baseline, while the
    # accept-everything fleet's overall p99 blows past it — and the
    # admission order means the bulk tier, not gold, pays for it
    gold_base = tier_latency_percentiles(base, "gold")[99.0]
    gold_deg = tier_latency_percentiles(deg, "gold")[99.0]
    assert gold_base > 0.0 and gold_deg > 0.0, \
        "vacuous: the gold tier completed nothing"
    assert gold_deg <= 1.5 * gold_base, (
        f"gold-tier p99 must hold within 1.5x of the pre-fault baseline "
        f"({gold_deg:.1f}s vs {gold_base:.1f}s)")
    assert gold_deg < raw.p99_latency_s, \
        "gold p99 under degradation must beat the accept-everything p99"
    assert deg.shed_by_tier["gold"] == 0.0 \
        and deg.shed_by_tier["bulk"] > 0.0, \
        "admission must shed the loosest tier first, never gold here"

    # (b) shedding + breakers buy back recovery time (non-vacuous:
    # the kill visibly degrades both arms first)
    deg_rec, raw_rec = deg.recovery, raw.recovery
    assert deg_rec is not None and raw_rec is not None
    assert raw_rec.p99_blowup > 1.0 and deg_rec.p99_blowup > 1.0
    assert deg_rec.reconvergence_ticks is not None \
        and raw_rec.reconvergence_ticks is not None \
        and deg_rec.reconvergence_ticks < raw_rec.reconvergence_ticks, (
        f"degradation must re-converge faster than accept-everything "
        f"({deg_rec.reconvergence_ticks} vs "
        f"{raw_rec.reconvergence_ticks} ticks)")

    # (c) the price is bounded: terminal loss (deadline expiry + retry
    # budget exhaustion + chaos drops) stays under 10% of injected mass
    injected = float(np.sum(crowd)) * DT_S
    loss = deg.expired_cost + deg.retry_dropped_cost + deg.dropped_cost
    assert deg.shed_cost > 0.0 and deg.breaker_opens > 0 \
        and deg.retried_cost > 0.0, "vacuous: no mechanism fired"
    assert loss / injected <= 0.10, (
        f"terminal loss must stay bounded ({loss / injected:.1%})")
    emit("fig16/degrade", 0.0,
         f"gold_p99_s={gold_deg:.1f};gold_baseline_p99_s={gold_base:.1f};"
         f"raw_p99_s={raw.p99_latency_s:.1f};"
         f"reconvergence_ticks={deg_rec.reconvergence_ticks};"
         f"raw_reconvergence_ticks={raw_rec.reconvergence_ticks};"
         f"shed_frac={deg.shed_cost / injected:.3f};"
         f"loss_frac={loss / injected:.3f};"
         f"breaker_opens={deg.breaker_opens}")

    # (d) parity: the degrade counters are part of the bitwise contract
    t_s = run_fleet("scalar", degrade_policy(), kill_sched())
    bitwise = (
        t_s.energy_j == deg.energy_j
        and t_s.served == deg.served
        and np.array_equal(t_s.power_w, deg.power_w)
        and t_s.p99_latency_s == deg.p99_latency_s
        and t_s.shed_cost == deg.shed_cost
        and t_s.shed_by_tier == deg.shed_by_tier
        and t_s.expired_cost == deg.expired_cost
        and t_s.retried_cost == deg.retried_cost
        and t_s.retry_dropped_cost == deg.retry_dropped_cost
        and t_s.breaker_opens == deg.breaker_opens
        and np.array_equal(t_s.breaker_state_t, deg.breaker_state_t))
    emit("fig16/degrade_backend_parity", 0.0,
         f"bitwise={bitwise};shed={deg.shed_cost:.1f}")
    assert bitwise, \
        "scalar/vector must stay bitwise-equal with degradation active"
    try:
        import jax  # noqa: F401
    except Exception:
        emit("fig16/degrade_jax_parity", 0.0, "skipped (jax unavailable)")
        return
    t_j = run_fleet("jax", degrade_policy(), kill_sched())
    worst = 0.0
    for series in ("served", "energy_j", "shed_cost", "retried_cost",
                   "retry_dropped_cost", "expired_cost", "p99_latency_s",
                   "shed_cost_t", "offered_rps"):
        r = _maxrel(getattr(deg, series), getattr(t_j, series))
        worst = max(worst, r)
        assert r <= JAX_RTOL, (
            f"fig16 degrade jax parity: {series} relative error "
            f"{r:.2e} > {JAX_RTOL:g}")
    assert t_j.breaker_opens == deg.breaker_opens \
        and np.array_equal(t_j.breaker_state_t, deg.breaker_state_t), \
        "breaker tick state must match exactly across engines"
    emit("fig16/degrade_jax_parity", 0.0,
         f"max_relerr={worst:.2e};rtol={JAX_RTOL:g}")


def run(perf: bool = True, backend: Optional[str] = None) -> None:
    """``backend`` overrides the engine of the sweep sections (1, 2, 4);
    the parity sections always pin their own engine pairs."""
    bk = backend or "vector"
    header(f"fig16: fleet-scale serving — 120 racks, 24 h diurnal, "
           f"{bk} engine")
    probe = _mixed_fleet(100, 20, "vector", RoundRobinRouter())
    capacity = probe.capacity_rps
    users = 0.5 * capacity / RPS_PER_USER
    trace = scale_to_users(
        diurnal_trace(peak_rps=1.0, hours=24, dt_s=DT_S, seed=16),
        users=users, rps_per_user=RPS_PER_USER)

    # --- 1. headline 24 h sweep: JSQ vs power-aware routing ---------------
    results = {}
    for router in (JoinShortestQueueRouter(), PowerAwareRouter()):
        tel = _sweep(router, trace, backend=bk)
        results[tel.router] = tel
        s = tel.summary()
        emit(f"fig16/{tel.router}", 0.0,
             f"energy_kwh={s['energy_kwh']:.1f};"
             f"p95_s={s['p95_latency_s']:.1f};"
             f"mean_active={s['mean_active_units']:.0f};"
             f"proportionality={s['proportionality']:.3f};"
             f"usd_month={s['monthly_electricity_usd']:.0f};"
             f"wall_s={s['wall_s']:.2f}")
        assert tel.ticks >= 24 * 60, "sweep must cover 24 simulated hours"
        if perf:
            assert s["wall_s"] < 60.0, \
                "vectorized 24 h fleet sweep must finish in seconds"
    jsq, pa = (results["join-shortest-queue"], results["power-aware"])
    emit("fig16/routing_energy", 0.0,
         f"jsq_kwh={jsq.energy_kwh:.1f};power_aware_kwh={pa.energy_kwh:.1f};"
         f"saving={1 - pa.energy_j / jsq.energy_j:.1%};"
         f"users={users/1e6:.1f}M")
    assert pa.energy_j < jsq.energy_j, \
        "power-aware routing must beat JSQ on energy on a mixed fleet"

    # --- 2. flash crowd: JSQ vs capacity-oblivious round-robin ------------
    # The spike peaks *below* fleet capacity (~64%), so a
    # capacity-aware router rides it out — but uniform round-robin
    # shares exceed the small Xeon racks' capacity 6x over, and the
    # arrival-driven unit governors drain the stranded backlog slowly
    # long after the crowd is gone.
    small_cap = _mixed_fleet(10, 10, "vector", RoundRobinRouter()) \
        .capacity_rps
    crowd = flash_crowd_trace(base_rps=0.08 * small_cap, spike_mult=8.0,
                              hours=2.0, dt_s=DT_S, seed=16)
    rr = _sweep(RoundRobinRouter(), crowd, backend=bk, n_soc=10, n_cpu=10)
    jsq_c = _sweep(JoinShortestQueueRouter(), crowd, backend=bk,
                   n_soc=10, n_cpu=10)
    emit("fig16/flash_crowd", 0.0,
         f"rr_p95_s={rr.p95_latency_s:.1f};"
         f"jsq_p95_s={jsq_c.p95_latency_s:.1f};"
         f"rr_peak_queue={int(rr.queued.max())};"
         f"jsq_peak_queue={int(jsq_c.queued.max())}")
    assert jsq_c.p95_latency_s < rr.p95_latency_s, \
        "JSQ must beat round-robin on p95 under a flash crowd"

    # --- 3. scalar <-> vector backend parity ------------------------------
    short = scale_to_users(
        diurnal_trace(peak_rps=1.0, hours=2, dt_s=DT_S, seed=7),
        users=users / 10, rps_per_user=RPS_PER_USER)
    t_s = _sweep(JoinShortestQueueRouter(), short, backend="scalar",
                 n_soc=8, n_cpu=2)
    t_v = _sweep(JoinShortestQueueRouter(), short, backend="vector",
                 n_soc=8, n_cpu=2)
    bitwise = (t_s.energy_j == t_v.energy_j
               and np.array_equal(t_s.power_w, t_v.power_w)
               and np.array_equal(t_s.active_units, t_v.active_units)
               and t_s.p95_latency_s == t_v.p95_latency_s)
    emit("fig16/backend_parity", 0.0,
         f"bitwise={bitwise};energy_j={t_v.energy_j:.1f}")
    assert bitwise, "vector fleet engine must match scalar bitwise"

    # --- 4. DVFS fleet: the frequency axis at fleet scale -----------------
    # PR 3's schedutil governor is what moves the sd865 proportionality
    # index (0.907 -> 0.941); the stacked engine now runs it — plus the
    # RC thermal network — on the array path. 100 racks x 24 h.
    gating_fleet = _dvfs_fleet(100, bk, JoinShortestQueueRouter(),
                               dvfs=False)
    dvfs_trace = 0.5 * gating_fleet.capacity_rps * diurnal_trace(
        peak_rps=1.0, hours=24, dt_s=DT_S, seed=16)
    gating = gating_fleet.play_trace(dvfs_trace)
    sched = _dvfs_fleet(100, bk, JoinShortestQueueRouter()) \
        .play_trace(dvfs_trace)
    saving = 1 - sched.energy_j / gating.energy_j
    emit("fig16/dvfs_fleet", 0.0,
         f"gating_only_kwh={gating.energy_kwh:.1f};"
         f"schedutil_kwh={sched.energy_kwh:.1f};saving={saving:.1%};"
         f"gating_p95_s={gating.p95_latency_s:.1f};"
         f"schedutil_p95_s={sched.p95_latency_s:.1f};"
         f"wall_s={sched.wall_s:.2f}")
    assert gating.drained and sched.drained
    assert saving > 0.05, \
        "the frequency axis must save fleet energy over binary gating alone"
    assert sched.p95_latency_s <= 1.25 * gating.p95_latency_s, \
        "the DVFS saving may not come out of the latency budget"
    # small-fleet bitwise parity with the governor + thermal enabled
    dvfs_short = dvfs_trace[:120] / 10.0
    d_s = _dvfs_fleet(6, "scalar", JoinShortestQueueRouter()) \
        .play_trace(dvfs_short)
    d_v = _dvfs_fleet(6, "vector", JoinShortestQueueRouter()) \
        .play_trace(dvfs_short)
    dvfs_bitwise = (
        d_s.energy_j == d_v.energy_j
        and np.array_equal(d_s.power_w, d_v.power_w)
        and np.array_equal(d_s.active_units, d_v.active_units)
        and d_s.p95_latency_s == d_v.p95_latency_s
        and all(np.array_equal(a.max_temp_c, b.max_temp_c)
                and np.array_equal(a.throttled_units, b.throttled_units)
                and np.array_equal(a.fan_power_w, b.fan_power_w)
                for a, b in zip(d_s.per_rack, d_v.per_rack)))
    emit("fig16/dvfs_backend_parity", 0.0,
         f"bitwise={dvfs_bitwise};energy_j={d_v.energy_j:.1f}")
    assert dvfs_bitwise, \
        "vector fleet engine must match scalar bitwise under DVFS+thermal"

    # --- 5. jax backend: tolerance parity + batched config sweep ----------
    _jax_section(perf, short, crowd, dvfs_short, d_v)

    # --- 6. chaos: correlated rack kills at peak --------------------------
    _chaos_section()

    # --- 6b. graceful degradation under fault + flash crowd ---------------
    _degrade_section()

    # --- 7. vectorized engine throughput ----------------------------------
    if not perf:
        emit("fig16/speedup", 0.0, "skipped (--fast)")
        return
    v_tps = _engine_rack_ticks_per_s("vector", ticks=150)
    s_tps = _engine_rack_ticks_per_s("scalar", ticks=40)
    speedup = v_tps / s_tps
    emit_metric("fig16/vector_rack_ticks_per_s", v_tps)
    emit_metric("fig16/scalar_rack_ticks_per_s", s_tps)
    emit("fig16/speedup", 0.0, f"vector_over_scalar={speedup:.1f}x")
    assert speedup >= MIN_SPEEDUP, (
        f"vectorized fleet engine must be >= {MIN_SPEEDUP:.0f}x the "
        f"scalar backend (measured {speedup:.1f}x)")
    dv_tps = _engine_rack_ticks_per_s("vector", ticks=150, dvfs=True)
    ds_tps = _engine_rack_ticks_per_s("scalar", ticks=30, dvfs=True)
    dvfs_speedup = dv_tps / ds_tps
    emit_metric("fig16/dvfs_vector_rack_ticks_per_s", dv_tps)
    emit_metric("fig16/dvfs_scalar_rack_ticks_per_s", ds_tps)
    emit("fig16/dvfs_speedup", 0.0,
         f"vector_over_scalar={dvfs_speedup:.1f}x")
    assert dvfs_speedup >= MIN_SPEEDUP, (
        f"the >= {MIN_SPEEDUP:.0f}x vector speedup must hold with a "
        f"frequency governor enabled (measured {dvfs_speedup:.1f}x)")


if __name__ == "__main__":
    run()
