"""Table 4 — TCO breakdown: CapEx, OpEx, monthly TCO per server."""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.tco import (PAPER_TABLE4, edge_server_nogpu_tco,
                            edge_server_tco, soc_cluster_tco,
                            tpu_v5e_pod_tco)


def run() -> None:
    header("table4: total cost of ownership")
    for model in (edge_server_tco(), edge_server_nogpu_tco(),
                  soc_cluster_tco()):
        ref = PAPER_TABLE4[model.name]
        emit(f"table4/{model.name}", 0.0,
             f"capex={model.capex.total:.0f}(paper {ref['total_capex']:.0f})"
             f";capex_mo={model.capex.monthly:.0f}"
             f"(paper {ref['capex_monthly']:.0f})"
             f";elec_mo={model.monthly_electricity():.0f}"
             f"(paper {ref['electricity_monthly']:.0f})"
             f";tco_mo={model.monthly_tco():.0f}"
             f"(paper {ref['tco_monthly']:.0f})")
    pod = tpu_v5e_pod_tco(256)
    emit("table4/tpu-v5e-256(extension)", 0.0,
         f"capex={pod.capex.total:.0f};tco_mo={pod.monthly_tco():.0f}")
    soc = soc_cluster_tco()
    emit("table4/opex_share_soc", 0.0,
         f"opex/tco={soc.monthly_electricity()/soc.monthly_tco():.3f}"
         f";capex_dominates=True(paper)")


if __name__ == "__main__":
    run()
