"""Per-arch smoke-scale step timings (train + decode) on this host."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header
from repro.config import ServeConfig, TrainConfig, get_config, smoke_config
from repro.models import model as lm
from repro.serving.engine import ServingEngine
from repro.training.optimizer import init_opt_state
from repro.training.train_loop import make_train_step

ARCHS = ["internlm2-1.8b", "granite-moe-1b-a400m", "mamba2-130m",
         "jamba-1.5-large-398b", "musicgen-large", "internvl2-1b"]


def run(archs=None) -> None:
    header("steps: smoke-scale train/decode timings")
    for arch in archs or ARCHS:
        cfg = smoke_config(get_config(arch))
        tcfg = TrainConfig(remat="none", scan_layers=True)
        step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
        params = lm.init_params(cfg, jax.random.key(0))
        opt = init_opt_state(params, tcfg)
        b, s = 4, 64
        batch = {"tokens": jnp.ones((b, s), jnp.int32),
                 "labels": jnp.ones((b, s), jnp.int32),
                 "mask": jnp.ones((b, s), jnp.float32)}
        if cfg.frontend_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (b, cfg.frontend_tokens, cfg.frontend_dim or cfg.d_model),
                jnp.float32)

        def run_step(p, o):
            p2, o2, m = step(p, o, batch)
            return m["loss"]

        # avoid donation invalidation during timing: copy each iter
        import time as _t
        p, o, m = step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        t0 = _t.perf_counter()
        p, o, m = step(p, o, batch)
        jax.block_until_ready(m["loss"])
        us = (_t.perf_counter() - t0) * 1e6
        tokens = b * (s + cfg.frontend_tokens)
        emit(f"step/train_{arch}", us,
             f"tokens_s={tokens/(us*1e-6):.0f}")

        eng = ServingEngine(cfg, ServeConfig(max_seq_len=64))
        eng.init_random(0)
        lg, caches = eng.prefill_fn(eng.params,
                                    {"tokens": jnp.ones((2, 16), jnp.int32)})
        tok = jnp.ones((2, 1), jnp.int32)
        lg2, caches = eng.decode_fn(eng.params, tok, caches, 16)
        jax.block_until_ready(lg2)
        t0 = _t.perf_counter()
        lg2, caches = eng.decode_fn(eng.params, tok, caches, 17)
        jax.block_until_ready(lg2)
        us = (_t.perf_counter() - t0) * 1e6
        emit(f"step/decode_{arch}", us, f"tokens_s={2/(us*1e-6):.0f}")


if __name__ == "__main__":
    run()
