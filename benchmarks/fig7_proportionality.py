"""Fig 7 — live-streaming energy efficiency under partial load (1..20
streams): the SoC Cluster / Intel CPU keep near-constant streams/W while
the A40 pays its idle-power floor.

Workload power follows the paper's per-platform measurement methodology
(§3 Setups, "excludes idle power"):
  * SoC Cluster — whole-server BMC delta: engaged SoCs at load + their
    standby draw;
  * Intel CPU — turbostat core-power delta (container idle excluded);
  * A40 — nvidia-smi total GPU power (the GPU's idle floor is charged as
    soon as it is engaged — the effect Fig 7 is about).
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.cluster import edge_server_cpu, edge_server_gpu, soc_cluster
from repro.core.energy import proportionality_index

# V4 (1080p presentation): max streams per unit (paper Table 3 / §4.1).
SOC_STREAMS_PER_UNIT = 9       # per SoC (CPU transcode)
INTEL_STREAMS_PER_UNIT = 9     # per 8-core container
A40_STREAMS_PER_UNIT = 16      # per GPU (NVENC sessions)


def soc_power(n: int) -> float:
    u = soc_cluster().unit
    import math
    engaged = math.ceil(n / SOC_STREAMS_PER_UNIT)
    frac = n / (engaged * SOC_STREAMS_PER_UNIT)
    return engaged * (u.p_idle + (u.p_peak - u.p_idle) * frac)


def intel_power(n: int) -> float:
    u = edge_server_cpu().unit
    # turbostat delta: active core power only
    return n / INTEL_STREAMS_PER_UNIT * (u.p_peak - u.p_idle)


def a40_power(n: int) -> float:
    u = edge_server_gpu().unit
    import math
    engaged = math.ceil(n / A40_STREAMS_PER_UNIT)
    frac = n / (engaged * A40_STREAMS_PER_UNIT)
    # NVENC transcoding scales ~linearly above the GPU's idle floor
    return engaged * u.p_idle + frac * engaged * (165.0)


def run() -> None:
    header("fig7: TpE vs number of live streams (V4, 1080p)")
    for name, pfn in (("soc-cpu", soc_power), ("intel", intel_power),
                      ("a40", a40_power)):
        tpes = [n / pfn(n) for n in (1, 5, 10, 20)]
        emit(f"fig7/{name}", 0.0,
             f"streams_per_watt@1={tpes[0]:.4f};@5={tpes[1]:.4f};"
             f"@10={tpes[2]:.4f};@20={tpes[3]:.4f}")
    a40_1 = 1.0 / a40_power(1)
    soc_1 = 1.0 / soc_power(1)
    intel_1 = 1.0 / intel_power(1)
    emit("fig7/a40_single_stream", 0.0,
         f"streams_per_watt={a40_1:.4f};paper=0.018")
    emit("fig7/soc_vs_a40_at_1", 0.0,
         f"ratio={soc_1/a40_1:.1f}x;paper=40.8x")
    emit("fig7/intel_vs_a40_at_1", 0.0,
         f"ratio={intel_1/a40_1:.1f}x;paper=14.9x")
    emit("fig7/proportionality_index", 0.0,
         f"soc={proportionality_index(soc_cluster()):.3f};"
         f"intel={proportionality_index(edge_server_cpu()):.3f};"
         f"a40={proportionality_index(edge_server_gpu()):.3f}")


if __name__ == "__main__":
    run()
