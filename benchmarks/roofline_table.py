"""Render the roofline table from results/dryrun/*.json (EXPERIMENTS.md
§Roofline source of truth)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import emit, header

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_results(mesh: str = "pod16x16", tag: Optional[str] = None
                 ) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if len(parts) == 3 and tag is None:
            pass
        elif len(parts) == 4 and tag == parts[3]:
            pass
        else:
            continue
        if parts[2] != mesh:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    return rows


def markdown_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | kind | compute_s | memory_s | collective_s | "
           "bound | MODEL_FLOPs | useful ratio | roofline frac | "
           "mem/dev GiB | note |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rf = r["roofline"]
        mem = r["memory_analysis"].get("total_nonalias_bytes", 0) / 2 ** 30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step_kind']} "
            f"| {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
            f"| {rf['collective_s']:.3f} | **{rf['bound']}** "
            f"| {rf['model_flops_total']:.2e} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {rf['roofline_fraction']:.3f} | {mem:.1f} "
            f"| {rf['note'] or ''} |")
    return "\n".join(out)


def run() -> None:
    header("roofline: per-cell terms (pod16x16)")
    rows = load_results("pod16x16")
    for r in rows:
        rf = r["roofline"]
        emit(f"roofline/{r['arch']}__{r['shape']}", 0.0,
             f"bound={rf['bound']};compute_s={rf['compute_s']:.3f};"
             f"memory_s={rf['memory_s']:.3f};"
             f"collective_s={rf['collective_s']:.3f};"
             f"frac={rf['roofline_fraction']:.3f}")
    if not rows:
        emit("roofline/missing", 0.0,
             "run: python -m repro.launch.dryrun --all")


if __name__ == "__main__":
    run()
