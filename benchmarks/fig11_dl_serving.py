"""Fig 11 — DL serving latency + energy efficiency.

Executable half: the paper's four workloads run as real JAX models on this
host (ResNet-50/152, YOLOv5x-style at reduced input, BERT-base), giving
measured per-sample latencies; the per-platform table then combines the
paper's measured points with our energy model to reproduce Fig 11b's TpE
ratios.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, time_fn
from repro.config import get_config
from repro.core.cluster import (a100_server, edge_server_cpu,
                                edge_server_gpu, soc_cluster)
from repro.models import model as lm
from repro.models.resnet import resnet_apply, resnet_init
from repro.models.yolo import yolo_apply, yolo_init
from repro.runtime import ClusterRuntime, DLServingWorkload, ScalePolicy
from repro.workloads.dlserving import PAPER_CLAIMS, PAPER_POINTS, point

# Platform name (ServingPoint) -> calibrated ClusterSpec for the runtime.
_PLATFORM_SPECS = {
    "soc-gpu": soc_cluster,
    "soc-dsp": soc_cluster,
    "intel-cpu": edge_server_cpu,
    "a40": edge_server_gpu,
    "a100": a100_server,
}


def _measure_host() -> None:
    rng = jax.random.key(0)
    # ResNet-50 / 152 @ 224
    x = jnp.zeros((1, 224, 224, 3), jnp.float32)
    for variant in ("resnet-50", "resnet-152"):
        params = resnet_init(rng, variant)
        f = jax.jit(lambda p, a, v=variant: resnet_apply(p, a, v))
        us = time_fn(f, params, x, iters=3, warmup=1)
        emit(f"fig11/host_{variant}", us, f"batch=1;ms={us/1e3:.1f}")
    # YOLOv5x-style at 320 (quarter-res keeps the CPU run tractable)
    yp = yolo_init(rng)
    xy = jnp.zeros((1, 320, 320, 3), jnp.float32)
    fy = jax.jit(yolo_apply)
    us = time_fn(fy, yp, xy, iters=2, warmup=1)
    emit("fig11/host_yolov5x_320", us, f"batch=1;ms={us/1e3:.1f}")
    # BERT-base fwd, seq 128 (the paper's 4th workload; encoder-only)
    cfg = get_config("bert-base")
    params = lm.init_params(cfg, rng)
    toks = jnp.ones((1, 128), jnp.int32)
    fb = jax.jit(lambda p, t: lm.forward(p, cfg, t, mode="train")[0])
    us = time_fn(fb, params, {"tokens": toks}, iters=3, warmup=1)
    emit("fig11/host_bert-base", us, f"batch=1;seq=128;ms={us/1e3:.1f}")


def run(measure: bool = True) -> None:
    header("fig11a: inference latency (paper points + host-measured)")
    if measure:
        _measure_host()
    for p in PAPER_POINTS:
        emit(f"fig11a/{p.model}_{p.precision}_{p.platform}", 0.0,
             f"latency_ms={p.latency_ms};batch={p.batch}")

    header("fig11b: energy efficiency (samples/J)")
    r50_gpu = point("resnet-50", "fp32", "soc-gpu")
    r50_intel = point("resnet-50", "fp32", "intel-cpu")
    r50_a40 = point("resnet-50", "fp32", "a40")
    r50_a100 = point("resnet-50", "fp32", "a100")
    for p in PAPER_POINTS:
        emit(f"fig11b/{p.model}_{p.precision}_{p.platform}", 0.0,
             f"samples_per_joule={p.samples_per_joule:.2f}")
    emit("fig11b/r50_soc_vs_intel", 0.0,
         f"ratio={r50_gpu.samples_per_joule/r50_intel.samples_per_joule:.2f}"
         f"x;paper={PAPER_CLAIMS['r50_gpu_vs_intel']}x")
    emit("fig11b/r50_soc_vs_a40", 0.0,
         f"ratio={r50_gpu.samples_per_joule/r50_a40.samples_per_joule:.2f}"
         f"x;paper={PAPER_CLAIMS['r50_gpu_vs_a40']}x")
    emit("fig11b/r50_soc_vs_a100", 0.0,
         f"ratio={r50_gpu.samples_per_joule/r50_a100.samples_per_joule:.2f}"
         f"x;paper={PAPER_CLAIMS['r50_gpu_vs_a100']}x")
    r152_dsp = point("resnet-152", "int8", "soc-dsp")
    r152_intel = point("resnet-152", "fp32", "intel-cpu")
    emit("fig11b/r152_dsp_vs_intel", 0.0,
         f"ratio={r152_dsp.samples_per_joule/r152_intel.samples_per_joule:.1f}"
         f"x;paper={PAPER_CLAIMS['r152_dsp_vs_intel']}x")

    header("fig11c: ClusterRuntime cross-check (resnet-50 @ 50% load)")
    # Same serving points driven through the unified runtime loop: each
    # platform serves half its peak rate for 10 min; TpE comes from the
    # calibrated ClusterSpec power model with per-unit gating.
    for platform in ("soc-gpu", "intel-cpu", "a40", "a100"):
        spec = _PLATFORM_SPECS[platform]()
        workload = DLServingWorkload.from_point("resnet-50", "fp32",
                                                platform)
        runtime = ClusterRuntime(spec, workload,
                                 policy=ScalePolicy(cooldown_s=30.0))
        trace = np.full(600, 0.5 * workload.unit_rate * spec.n_units)
        tel = runtime.play_trace(trace, dt_s=1.0)
        emit(f"fig11c/resnet-50_{platform}", 0.0,
             f"tpe={tel.tpe:.3f};mean_active={tel.mean_active:.1f}"
             f"/{spec.n_units};energy_j={tel.energy_j:.0f}")


if __name__ == "__main__":
    run()
