"""The RPL rule set: AST checks for the twin-engine parity contract.

=======  ====================================================================
RPL001   Unordered float reduction in a parity-critical module
         (``np.sum`` / ``.sum()`` / ``np.add.reduceat`` / ``np.dot`` /
         ``np.mean`` ...). numpy reduces floats pairwise or via segment
         trees, not left-to-right, so the scalar and vector engines can
         diverge by an ulp. Allowed idioms: weighted ``np.bincount``,
         explicit ascending-order loops, builtin ``sum`` (strictly
         left-to-right), ``math.fsum``.
RPL002   Mutation of a pool count cache (``_n_alloc``-style field)
         outside the owning pool class. The caches shadow recomputable
         bincount ground truth; foreign writers silently corrupt the
         O(1) hot-path queries.
RPL003   Append/extend to a ``responses`` attribute whose payload does
         not come from ``Workload.drain()``. ``drain()`` is the single
         exactly-once delivery channel into ``Telemetry.responses``; a
         second path double-counts completions.
RPL004   Unseeded randomness: stdlib ``random`` module calls or legacy
         ``np.random.*`` draws. Simulations must thread a seeded
         ``np.random.default_rng`` / ``random.Random`` so runs replay.
RPL005   Unpinned selection tie-break in a governor/router/placement
         module: ``argsort`` without ``kind="stable"``, ``argmin`` /
         ``argmax`` over (potentially) float keys, or ``==`` against a
         float expression. A one-ulp key difference between backends
         must not flip which rack/OPP/unit wins; pin a composite
         integer key, use a stable sort, or compare with an epsilon
         margin.
=======  ====================================================================

Every rule is waivable per line with a rationale comment::

    x = arr.sum()  # reprolint: ok[RPL001] integer dtype: reduction exact

A waiver without rationale text is itself reported (RPL000).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import List, Optional

from reprolint.config import (CACHE_OWNERS, COUNT_CACHE_FIELDS, LintConfig,
                              ORDER_SENSITIVE_UFUNCS, SEEDABLE_RANDOM_CTORS,
                              UNORDERED_METHOD_REDUCTIONS,
                              UNORDERED_NP_REDUCTIONS)

RULES = {
    "RPL000": "waiver comment missing a rationale",
    "RPL001": "unordered float reduction in a parity-critical module",
    "RPL002": "pool count cache mutated outside its owning class",
    "RPL003": "responses delivered outside the drain() channel",
    "RPL004": "unseeded random draw",
    "RPL005": "selection tie-break without a pinned key",
}

# jnp included: jax.numpy reductions are *always* unordered under XLA
# fusion, which is exactly why the jax engine's parity contract is
# tolerance-based — every hit in repro/fleet/jax_engine.py needs a
# "# reprolint: ok[RPL001] jax tolerance-parity ..." waiver naming the
# tolerance that covers it (see CONTRIBUTING.md).
_NP_NAMES = {"np", "numpy", "jnp"}
_MUTATING_METHODS = {"pop", "clear", "update", "setdefault", "popitem"}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"{self.rule} {self.message}"


def _attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``np.add.reduceat`` -> ["np", "add", "reduceat"]; None when the
    chain bottoms out in anything but a bare name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def _contains_attr(node: ast.AST, attr: str) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == attr
               for n in ast.walk(node))


def _contains_call_named(node: ast.AST, name: str) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            f = n.func
            if (isinstance(f, ast.Attribute) and f.attr == name) or \
                    (isinstance(f, ast.Name) and f.id == name):
                return True
    return False


def _is_float_annotation(ann: Optional[ast.AST]) -> bool:
    return (isinstance(ann, ast.Name) and ann.id == "float") or \
        (isinstance(ann, ast.Constant) and ann.value == "float")


def _is_float_like(node: ast.AST, float_names: frozenset = frozenset()
                   ) -> bool:
    """Heuristic: does this expression *syntactically* produce a float
    (true division anywhere inside, a float literal, a ``float(...)``
    call, or a name annotated ``: float`` in the enclosing function)?"""
    for n in ast.walk(node):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Div):
            return True
        if isinstance(n, ast.Constant) and isinstance(n.value, float):
            return True
        if isinstance(n, ast.Name) and n.id in float_names:
            return True
        if isinstance(n, ast.Call):
            f = n.func
            if isinstance(f, ast.Name) and f.id == "float":
                return True
    return False


class _RuleVisitor(ast.NodeVisitor):
    def __init__(self, path: str, parity: bool, selection: bool):
        self.path = path
        self.parity = parity
        self.selection = selection
        self.findings: List[Finding] = []
        self._class_stack: List[str] = []
        self._float_names_stack: List[frozenset] = [frozenset()]

    # -- bookkeeping -------------------------------------------------------
    def _report(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            rule=rule, path=self.path, line=node.lineno,
            col=getattr(node, "col_offset", 0), message=message))

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_func(self, node) -> None:
        a = node.args
        params = [*a.posonlyargs, *a.args, *a.kwonlyargs]
        floats = {p.arg for p in params
                  if _is_float_annotation(p.annotation)}
        floats.update(
            n.target.id for n in ast.walk(node)
            if isinstance(n, ast.AnnAssign)
            and isinstance(n.target, ast.Name)
            and _is_float_annotation(n.annotation))
        self._float_names_stack.append(frozenset(floats))
        self.generic_visit(node)
        self._float_names_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _in_cache_owner(self) -> bool:
        return any(c in CACHE_OWNERS for c in self._class_stack)

    # -- RPL001 / RPL003 / RPL004 / RPL005: calls --------------------------
    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if self.parity:
            self._check_unordered_reduction(node, chain)
            self._check_responses_append(node)
            self._check_cache_mutator_call(node)
        self._check_unseeded_random(node, chain)
        if self.selection:
            self._check_selection_calls(node, chain)
        self.generic_visit(node)

    def _check_unordered_reduction(self, node: ast.Call,
                                   chain: Optional[List[str]]) -> None:
        if chain:
            # np.sum(x) / numpy.dot(a, b)
            if len(chain) == 2 and chain[0] in _NP_NAMES \
                    and chain[1] in UNORDERED_NP_REDUCTIONS:
                self._report(
                    "RPL001", node,
                    f"np.{chain[1]} reduces floats in unspecified order; "
                    "use a weighted np.bincount or an explicit "
                    "ascending-order accumulation in parity-critical code")
                return
            # np.add.reduceat(...) / np.add.reduce(...)
            if len(chain) == 3 and chain[0] in _NP_NAMES \
                    and chain[1] in ORDER_SENSITIVE_UFUNCS \
                    and chain[2] in ("reduce", "reduceat"):
                self._report(
                    "RPL001", node,
                    f"np.{chain[1]}.{chain[2]} float segment reduction is "
                    "not left-to-right (the PR 5 one-ulp parity bug); use "
                    "a weighted np.bincount group sum")
                return
        # method form: x.sum(), x.mean(axis=0) ... on any receiver
        f = node.func
        if isinstance(f, ast.Attribute) \
                and f.attr in UNORDERED_METHOD_REDUCTIONS \
                and not (isinstance(f.value, ast.Name)
                         and f.value.id in _NP_NAMES):
            self._report(
                "RPL001", node,
                f".{f.attr}() reduction order is unspecified for float "
                "arrays; pin the order or waive with the receiver's "
                "dtype/role rationale")

    def _check_responses_append(self, node: ast.Call) -> None:
        f = node.func
        if not (isinstance(f, ast.Attribute)
                and f.attr in ("append", "extend", "insert")):
            return
        if not _contains_attr(f.value, "responses"):
            return
        if any(_contains_call_named(arg, "drain") for arg in node.args):
            return
        self._report(
            "RPL003", node,
            "responses must be delivered exactly once, via "
            "Workload.drain(); appending anything else double-counts "
            "completions in Telemetry.responses")

    def _check_unseeded_random(self, node: ast.Call,
                               chain: Optional[List[str]]) -> None:
        if not chain:
            return
        # stdlib: random.random(), random.randint(...), random.shuffle(...)
        if len(chain) == 2 and chain[0] == "random" \
                and chain[1] not in ("Random", "SystemRandom", "seed",
                                     "getstate", "setstate"):
            self._report(
                "RPL004", node,
                f"random.{chain[1]} draws from the unseeded module-level "
                "generator; thread a seeded random.Random / "
                "np.random.default_rng instead")
            return
        # numpy legacy: np.random.rand(...), np.random.randint(...)
        if len(chain) == 3 and chain[0] in _NP_NAMES \
                and chain[1] == "random":
            if chain[2] in SEEDABLE_RANDOM_CTORS:
                # default_rng() with no/None seed is still unseeded
                if chain[2] == "default_rng" and (
                        not node.args
                        or (isinstance(node.args[0], ast.Constant)
                            and node.args[0].value is None)):
                    self._report(
                        "RPL004", node,
                        "np.random.default_rng() without a seed is "
                        "OS-entropy seeded; pass an explicit seed so "
                        "simulations replay")
                return
            self._report(
                "RPL004", node,
                f"np.random.{chain[2]} uses the legacy global "
                "RandomState; use a seeded np.random.default_rng "
                "generator")

    def _check_selection_calls(self, node: ast.Call,
                               chain: Optional[List[str]]) -> None:
        f = node.func
        name = None
        if chain and len(chain) == 2 and chain[0] in _NP_NAMES:
            name = chain[1]
        elif isinstance(f, ast.Attribute):
            name = f.attr
        if name == "argsort":
            kind = next((kw.value for kw in node.keywords
                         if kw.arg == "kind"), None)
            if not (isinstance(kind, ast.Constant)
                    and kind.value in ("stable", "mergesort")):
                self._report(
                    "RPL005", node,
                    "argsort without kind=\"stable\": equal float keys "
                    "land in unspecified order, so a one-ulp difference "
                    "between backends can reorder the selection; use a "
                    "stable sort or prove the keys unique")
        elif name in ("argmin", "argmax"):
            self._report(
                "RPL005", node,
                f"{name} breaks float ties by array position only; pin a "
                "composite (value, tiebreak-index) integer key or an "
                "epsilon-margin comparison so a one-ulp key difference "
                "cannot flip the winner")

    def _check_cache_mutator_call(self, node: ast.Call) -> None:
        """``pool._active_idx.pop(...)`` — mutation through a method."""
        f = node.func
        if not (isinstance(f, ast.Attribute)
                and f.attr in _MUTATING_METHODS):
            return
        recv = f.value
        if isinstance(recv, ast.Subscript):
            recv = recv.value
        if not (isinstance(recv, ast.Attribute)
                and recv.attr in COUNT_CACHE_FIELDS):
            return
        if isinstance(recv.value, ast.Name) and recv.value.id == "self" \
                and self._in_cache_owner():
            return
        self._report(
            "RPL002", node,
            f"{recv.attr}.{f.attr}() mutates a pool count cache outside "
            "its owning class; go through "
            "wake/release/advance/force_active instead")

    # -- RPL002: cache mutation sites --------------------------------------
    def _cache_store_target(self, target: ast.AST) -> Optional[str]:
        """The cache field name a store targets, if any: matches
        ``X._n_alloc``, ``X._n_active_of[tid]``, ``X._free_g[...]``."""
        node = target
        if isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) \
                and node.attr in COUNT_CACHE_FIELDS:
            return node.attr
        return None

    def _check_cache_store(self, target: ast.AST, node: ast.AST) -> None:
        field = self._cache_store_target(target)
        if field is None:
            return
        base = target
        if isinstance(base, ast.Subscript):
            base = base.value
        assert isinstance(base, ast.Attribute)
        is_self = isinstance(base.value, ast.Name) \
            and base.value.id == "self"
        if is_self and self._in_cache_owner():
            return
        self._report(
            "RPL002", node,
            f"{field} is an exact integer cache owned by the pool "
            "backend; mutate through wake/release/advance/force_active "
            "so the cache and the bincount ground truth stay in lockstep")

    def visit_Assign(self, node: ast.Assign) -> None:
        if self.parity:
            for t in node.targets:
                self._check_cache_store(t, node)
            self._check_responses_assign(node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self.parity:
            self._check_cache_store(node.target, node)
            if self._cache_store_target(node.target) is None \
                    and isinstance(node.target, ast.Attribute) \
                    and node.target.attr == "responses" \
                    and not _contains_call_named(node.value, "drain"):
                self._report(
                    "RPL003", node,
                    "responses must be delivered exactly once, via "
                    "Workload.drain()")
        self.generic_visit(node)

    def _check_responses_assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            base = t.value if isinstance(t, ast.Subscript) else t
            if isinstance(base, ast.Attribute) and base.attr == "responses":
                # rebinding .responses wholesale is allowed only from the
                # drain channel or to a fresh empty list (reset)
                v = node.value
                empty = isinstance(v, (ast.List, ast.ListComp)) or (
                    isinstance(v, ast.Call)
                    and isinstance(v.func, ast.Name)
                    and v.func.id == "list" and not v.args)
                if not empty and not _contains_call_named(v, "drain"):
                    self._report(
                        "RPL003", node,
                        "responses may only be (re)bound from "
                        "Workload.drain() or reset to empty")

    # -- RPL005: float equality in selection code --------------------------
    def visit_Compare(self, node: ast.Compare) -> None:
        if self.selection and any(isinstance(op, (ast.Eq, ast.NotEq))
                                  for op in node.ops):
            operands = [node.left, *node.comparators]
            floats = self._float_names_stack[-1]
            if any(_is_float_like(o, floats) for o in operands):
                self._report(
                    "RPL005", node,
                    "float == in selection code: one-ulp backend "
                    "differences make exact float ties unstable; compare "
                    "with an epsilon margin or an integer key")
        self.generic_visit(node)


def run_rules(tree: ast.AST, path: str, *, parity: bool,
              selection: bool) -> List[Finding]:
    v = _RuleVisitor(path, parity, selection)
    v.visit(tree)
    return v.findings


def lint_tree(tree: ast.AST, path: str, relpath: str, source: str,
              config: Optional[LintConfig] = None) -> List[Finding]:
    cfg = config or LintConfig()
    return run_rules(
        tree, path,
        parity=cfg.is_parity_critical(relpath, source),
        selection=cfg.is_selection(relpath, source))
