"""File walking, waiver parsing, and finding suppression.

Waivers are ruff-style per-line comments::

    x = counts.sum()  # reprolint: ok[RPL001] int64 counts: reduction exact
    y = a.dot(b)      # reprolint: ok[RPL001, RPL005] shared by both engines

A waiver suppresses the named rules for every statement whose source
span covers the comment's line (so a waiver on the closing line of a
multi-line call works). The rationale text after the bracket is
mandatory: the waiver is the documentation, and a bare ``ok[RPL001]``
is reported as RPL000 instead of suppressing anything.
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Set, Tuple

from reprolint.config import DEFAULT_EXCLUDE_DIRS, LintConfig
from reprolint.rules import Finding, run_rules

_WAIVER_RE = re.compile(
    r"#\s*reprolint:\s*ok\[([A-Za-z0-9_,\s]+)\]\s*(.*)$")


@dataclass(frozen=True)
class Waiver:
    line: int
    rules: Tuple[str, ...]
    rationale: str


def parse_waivers(source: str) -> List[Waiver]:
    waivers: List[Waiver] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _WAIVER_RE.search(tok.string)
            if not m:
                continue
            rules = tuple(r.strip().upper() for r in m.group(1).split(",")
                          if r.strip())
            waivers.append(Waiver(line=tok.start[0], rules=rules,
                                  rationale=m.group(2).strip()))
    except tokenize.TokenError:
        pass
    return waivers


def _node_spans(tree: ast.AST) -> List[Tuple[int, int]]:
    """(lineno, end_lineno) for every statement/expression node."""
    spans = []
    for node in ast.walk(tree):
        lineno = getattr(node, "lineno", None)
        if lineno is not None:
            spans.append((lineno, getattr(node, "end_lineno", lineno)))
    return spans


def _waived_lines(finding: Finding, tree: ast.AST,
                  line_index: Dict[int, List[Tuple[int, int]]]
                  ) -> Set[int]:
    """Lines on which a waiver comment suppresses this finding: every
    line of every node span that starts on the finding's line."""
    lines: Set[int] = set()
    for start, end in line_index.get(finding.line, []):
        lines.update(range(start, end + 1))
    lines.add(finding.line)
    return lines


def apply_waivers(findings: List[Finding], waivers: List[Waiver],
                  tree: ast.AST, path: str) -> List[Finding]:
    """Drop waived findings; emit RPL000 for rationale-less waivers."""
    line_index: Dict[int, List[Tuple[int, int]]] = {}
    for start, end in _node_spans(tree):
        line_index.setdefault(start, []).append((start, end))

    out: List[Finding] = []
    used: Set[int] = set()
    for f in findings:
        span = _waived_lines(f, tree, line_index)
        waived = False
        for i, w in enumerate(waivers):
            if f.rule in w.rules and w.line in span:
                used.add(i)
                if w.rationale:
                    waived = True
                # rationale-less waivers do NOT suppress; RPL000 below
        if not waived:
            out.append(f)

    for w in waivers:
        if not w.rationale:
            out.append(Finding(
                rule="RPL000", path=path, line=w.line, col=0,
                message="waiver without rationale: write *why* the "
                        "flagged construct is safe after the bracket, "
                        "e.g. `# reprolint: ok[RPL001] int64: exact`"))
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def lint_source(source: str, path: str = "<string>",
                relpath: Optional[str] = None,
                config: Optional[LintConfig] = None) -> List[Finding]:
    """Lint one source string; returns unwaived findings."""
    cfg = config or LintConfig()
    rel = (relpath if relpath is not None else path).replace(os.sep, "/")
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="RPL999", path=path, line=e.lineno or 1,
                        col=(e.offset or 1) - 1,
                        message=f"syntax error: {e.msg}")]
    findings = run_rules(
        tree, path,
        parity=cfg.is_parity_critical(rel, source),
        selection=cfg.is_selection(rel, source))
    return apply_waivers(findings, parse_waivers(source), tree, path)


def iter_python_files(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in DEFAULT_EXCLUDE_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_paths(paths: Iterable[str],
               config: Optional[LintConfig] = None) -> List[Finding]:
    cfg = config or LintConfig()
    findings: List[Finding] = []
    for fp in iter_python_files(paths):
        try:
            with open(fp, encoding="utf-8") as fh:
                source = fh.read()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding(
                rule="RPL999", path=fp, line=1, col=0,
                message=f"cannot read file: {e}"))
            continue
        rel = os.path.relpath(fp).replace(os.sep, "/")
        findings.extend(lint_source(source, path=fp, relpath=rel,
                                    config=cfg))
    return findings
