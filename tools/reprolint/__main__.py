"""CLI entry point: ``PYTHONPATH=tools python -m reprolint src/``.

Exit status: 0 clean, 1 findings, 2 usage/internal error.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from reprolint.engine import lint_paths
from reprolint.rules import RULES


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="AST lint for the twin-engine parity contract "
                    "(RPL001-RPL005; waive per line with "
                    "`# reprolint: ok[RULE] rationale`)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to report "
                         "(default: all)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in sorted(RULES):
            print(f"{code}  {RULES[code]}")
        return 0

    findings = lint_paths(args.paths or ["src"])
    if args.select:
        keep = {c.strip().upper() for c in args.select.split(",")}
        findings = [f for f in findings if f.rule in keep]

    if args.format == "json":
        print(json.dumps([f.__dict__ for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        n = len(findings)
        print(f"reprolint: {n} finding{'s' if n != 1 else ''}"
              if n else "reprolint: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
