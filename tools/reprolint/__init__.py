"""reprolint: static analysis for the twin-engine parity contract.

Public API::

    from reprolint import lint_paths, lint_source, Finding, LintConfig

CLI::

    PYTHONPATH=tools python -m reprolint src/
"""
from reprolint.config import LintConfig
from reprolint.engine import lint_paths, lint_source
from reprolint.rules import RULES, Finding

__all__ = ["Finding", "LintConfig", "RULES", "lint_paths", "lint_source"]
