"""Repo-specific lint configuration: which files carry which contracts.

The twin-engine parity contract (scalar reference vs stacked-array
vector engine, bitwise-identical telemetry) only binds a handful of
modules — the ones whose floating-point arithmetic lands in telemetry
that ``tests/test_vector_parity.py`` compares bit for bit. Those
modules are *parity-critical*: every float reduction in them must be
order-pinned (weighted ``np.bincount``, explicit ascending loops,
left-to-right builtin ``sum``), because numpy's pairwise ``np.sum`` /
``np.add.reduceat`` reductions are not guaranteed left-to-right and
have produced real one-ulp parity breaks (PR 5).

Scopes are fnmatch patterns against the POSIX-style relative path. A
file can also opt in from its own text with a marker comment anywhere
in the file::

    # reprolint: parity-critical
    # reprolint: selection

which is how the fixture corpus exercises the rules regardless of
where the repo checkout lives.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatch
from typing import List


#: Modules whose float arithmetic is compared bitwise across the twin
#: engines (RPL001/RPL002/RPL003 scope).
PARITY_CRITICAL = [
    "*repro/fleet/fleet.py",
    "*repro/fleet/telemetry.py",
    "*repro/fleet/router.py",
    "*repro/fleet/engine_state.py",
    # Chaos masks feed straight into the engines' served/energy
    # accumulators and the recovery metrics compared across backends,
    # so fault lowering and the respill/drop accounting carry the same
    # order-pinning contract as the engines themselves.
    "*repro/fleet/chaos.py",
    # The degradation control plane's admission/shed/retry arithmetic
    # is the *same Python objects* for both host engines (one shared
    # DegradeDriver per run) and its counters are bitwise-compared in
    # tests and fig16, so its float sums carry the engines'
    # order-pinning contract too.
    "*repro/fleet/degrade.py",
    # The jax engine is parity-critical with a *tolerance* contract
    # (XLA reorders reductions by design): reductions there are waived
    # line by line with "# reprolint: ok[RPL001] jax tolerance-parity
    # <which documented tolerance covers this>" instead of being
    # order-pinned. Keeping the file in scope forces every new
    # reduction to name its tolerance budget explicitly.
    "*repro/fleet/jax_engine.py",
    "*repro/runtime/pool.py",
    "*repro/power/thermal.py",
    # The energy ledger replays engine accumulation bitwise (its sums
    # must mirror the engines' exact expression trees), so it carries
    # the same order-pinning contract. The rest of repro/obs (probes,
    # exporters, SLO roll-ups, report) is deliberately NOT listed:
    # those only read telemetry for display/alerting and never feed
    # back into the parity-compared numbers.
    "*repro/obs/attribution.py",
]

#: Modules that *select* between alternatives scored by floats —
#: governor OPP choices, router rack rankings, pool placement order
#: (RPL005 scope). A one-ulp difference in a float key must not be able
#: to flip the winner, so selections need pinned integer/composite keys,
#: stable sorts, or epsilon-margin comparisons.
SELECTION = [
    "*repro/power/governor.py",
    "*repro/fleet/router.py",
    "*repro/fleet/fleet.py",
    "*repro/runtime/pool.py",
]

#: Integer count caches of the pool backends: fields that shadow
#: recomputable ground truth and therefore may only be mutated by the
#: owning class's methods (RPL002).
COUNT_CACHE_FIELDS = frozenset({
    "_n_alloc",
    "_n_waking_total",
    "_n_active_of",
    "_n_waking_of",
    "_free_g",
    "_mine_g",
    "_act_g",
    "_active_idx",
    "_free_count",
})

#: Classes allowed to mutate the count caches (their methods own them).
CACHE_OWNERS = frozenset({"UnitPool", "VectorUnitPool"})

#: ``np.random`` attributes that are legitimate without an inline seed
#: (they construct seedable generators rather than draw numbers).
SEEDABLE_RANDOM_CTORS = frozenset({
    "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
})

#: numpy call names whose float reduction order is not guaranteed
#: left-to-right (pairwise summation, reduceat segment trees, BLAS
#: dispatch) — RPL001 targets.
UNORDERED_NP_REDUCTIONS = frozenset({
    "sum", "nansum", "cumsum", "nancumsum", "dot", "vdot", "inner",
    "matmul", "einsum", "mean", "nanmean", "std", "var", "prod",
    "nanprod", "trace",
})

#: ndarray method names flagged by RPL001 (over-approximate: static
#: analysis cannot prove the receiver is an ndarray; waive with a
#: rationale when the receiver is integer-typed or roll-up-only).
UNORDERED_METHOD_REDUCTIONS = frozenset({
    "sum", "dot", "mean", "std", "var", "prod", "cumsum", "trace",
})

#: ufuncs whose ``reduce``/``reduceat`` is order-sensitive on floats.
ORDER_SENSITIVE_UFUNCS = frozenset({"add", "subtract", "multiply", "divide"})

DEFAULT_EXCLUDE_DIRS = frozenset({
    ".git", "__pycache__", ".venv", "venv", "node_modules",
    ".mypy_cache", ".ruff_cache", ".pytest_cache", "build", "dist",
})

PARITY_MARKER = "# reprolint: parity-critical"
SELECTION_MARKER = "# reprolint: selection"


@dataclass
class LintConfig:
    """Scope + pattern knobs; defaults encode this repo's contract."""

    parity_critical: List[str] = field(
        default_factory=lambda: list(PARITY_CRITICAL))
    selection: List[str] = field(default_factory=lambda: list(SELECTION))

    def is_parity_critical(self, relpath: str, source: str) -> bool:
        p = relpath.replace("\\", "/")
        return (any(fnmatch(p, pat) for pat in self.parity_critical)
                or PARITY_MARKER in source)

    def is_selection(self, relpath: str, source: str) -> bool:
        p = relpath.replace("\\", "/")
        return (any(fnmatch(p, pat) for pat in self.selection)
                or SELECTION_MARKER in source)
