"""Checkpointing: atomicity, retention, bitwise resume, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config, smoke_config
from repro.training import checkpoint as ck
from repro.training.data import DataConfig, PrefetchingLoader
from repro.training.train_loop import Trainer


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, 5), jnp.int32),
                   "c": [jnp.asarray(rng.standard_normal(3), jnp.bfloat16)]},
    }


def test_save_restore_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    ck.save(str(tmp_path), 3, tree)
    out = ck.restore(str(tmp_path), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_pointer_and_retention(tmp_path, rng):
    tree = _tree(rng)
    for step in [1, 2, 3, 4, 5]:
        ck.save(str(tmp_path), step, tree, keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    assert ck.list_steps(str(tmp_path)) == [4, 5]


def test_atomic_save_leaves_no_partial_state(tmp_path, rng):
    tree = _tree(rng)
    ck.save(str(tmp_path), 1, tree)
    # simulate a crashed writer: stale tmp dir must not confuse restore
    os.makedirs(tmp_path / ".tmp-step_00000002")
    with open(tmp_path / ".tmp-step_00000002" / "garbage", "w") as f:
        f.write("junk")
    assert ck.latest_step(str(tmp_path)) == 1
    out = ck.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))


def test_async_save(tmp_path, rng):
    tree = _tree(rng)
    h = ck.save_async(str(tmp_path), 7, tree)
    h.wait()
    assert ck.latest_step(str(tmp_path)) == 7


def test_missing_leaf_raises(tmp_path, rng):
    tree = _tree(rng)
    ck.save(str(tmp_path), 1, tree)
    bigger = dict(tree)
    bigger["extra"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), bigger)


def test_trainer_resume_bitwise(tmp_path):
    """Run 8 steps w/ checkpoint@4; a resumed run from 4 must produce the
    exact same params as the uninterrupted run."""
    cfg = smoke_config(get_config("mamba2-130m"))
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=8,
                       remat="none")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

    full = Trainer(cfg, tcfg).run(PrefetchingLoader(dcfg), steps=8,
                                  log_every=100)

    d = str(tmp_path / "ck")
    t1 = Trainer(cfg, tcfg, ckpt_dir=d, ckpt_every=4)
    t1.run(PrefetchingLoader(dcfg), steps=4, log_every=100)
    t2 = Trainer(cfg, tcfg, ckpt_dir=d, ckpt_every=100)
    resumed = t2.run(PrefetchingLoader(dcfg), steps=8, log_every=100)

    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))


def test_elastic_restore_into_new_sharding(tmp_path, rng):
    """Restore accepts a shardings tree (here: single-device placements) —
    the elastic-remesh path."""
    tree = _tree(rng)
    ck.save(str(tmp_path), 1, tree)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    out = ck.restore(str(tmp_path), tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]))
