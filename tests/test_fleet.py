"""Fleet layer: routers, traces, scalar<->vector engine parity, and a
100-rack smoke test with energy/TCO roll-up invariants."""
import numpy as np
import pytest

from repro.core.cluster import edge_server_cpu, soc_cluster
from repro.fleet import (Fleet, FleetView, JoinShortestQueueRouter,
                         PowerAwareRouter, RackConfig, RoundRobinRouter,
                         diurnal_trace, flash_crowd_trace, homogeneous_fleet,
                         replay_trace, save_trace, scale_to_users)
from repro.power import SchedutilGovernor
from repro.runtime import ScalePolicy


def small_fleet(backend="vector", router=None, n_soc=4, n_cpu=2):
    racks = homogeneous_fleet(soc_cluster(), n_soc, unit_rate=30.0,
                              policy=ScalePolicy(cooldown_s=300.0))
    racks += homogeneous_fleet(edge_server_cpu(), n_cpu, unit_rate=9.0)
    return Fleet(racks, router=router or JoinShortestQueueRouter(),
                 dt_s=60.0, backend=backend)


def view_of(fleet):
    return fleet.view()


# ---------------------------------------------------------------------------
# Routers.
# ---------------------------------------------------------------------------
def test_round_robin_uniform():
    fleet = small_fleet()
    assign = RoundRobinRouter().route(600.0, view_of(fleet))
    assert np.allclose(assign, 100.0)


def test_jsq_conserves_and_prefers_short_queues():
    fleet = small_fleet()
    v = view_of(fleet)
    v.queued_cost = np.array([0.0, 5000.0, 0.0, 0.0, 0.0, 0.0])
    assign = JoinShortestQueueRouter().route(1000.0, v)
    assert assign.min() >= 0.0
    # water-fill conserves the offered load
    assert np.isclose(assign.sum(), 1000.0)
    # the backlogged rack gets strictly less than its empty twins
    assert assign[1] < assign[0]


def test_jsq_zero_backlog_splits_by_capacity():
    fleet = small_fleet()
    v = view_of(fleet)
    v.queued_cost = np.zeros(v.n_racks)
    assign = JoinShortestQueueRouter().route(900.0, v)
    assert np.isclose(assign.sum(), 900.0)
    expect = 900.0 * v.capacity_rps / v.capacity_rps.sum()
    assert np.allclose(assign, expect)


def test_power_aware_packs_efficient_racks_first():
    fleet = small_fleet()
    v = view_of(fleet)
    router = PowerAwareRouter(util_target=0.8)
    # soc racks are cheaper per request than the Xeon racks
    soc_cap = float(v.capacity_rps[0])
    assign = router.route(0.5 * soc_cap, v)
    assert np.isclose(assign.sum(), 0.5 * soc_cap)
    assert np.count_nonzero(assign) == 1        # fits in one efficient rack
    # saturating demand spills but still conserves
    total = 0.95 * float(v.capacity_rps.sum())
    assign = router.route(total, v)
    assert np.isclose(assign.sum(), total)
    assert (assign <= v.capacity_rps + 1e-9).all()


# ---------------------------------------------------------------------------
# Traces.
# ---------------------------------------------------------------------------
def test_flash_crowd_shape():
    tr = flash_crowd_trace(base_rps=100.0, spike_mult=8.0, hours=2.0,
                           dt_s=60.0, noise=0.0)
    assert len(tr) == 120
    assert np.isclose(tr[0], 100.0)
    assert np.isclose(tr.max(), 800.0)
    assert np.isclose(tr[-1], 100.0)


def test_replay_round_trip(tmp_path):
    tr = diurnal_trace(peak_rps=500.0, hours=1, dt_s=60.0, seed=3)
    path = tmp_path / "trace.csv"
    save_trace(path, tr)
    back = replay_trace(path)
    assert np.allclose(back, tr, atol=1e-5)
    assert np.allclose(replay_trace(path, scale=2.0), 2 * back)


def test_replay_csv_last_column(tmp_path):
    path = tmp_path / "lb_export.csv"
    path.write_text("# t,rps\n0,10.5\n60,20.25\n\n120,30.0\n")
    assert list(replay_trace(path)) == [10.5, 20.25, 30.0]
    with pytest.raises(ValueError):
        empty = tmp_path / "empty.csv"
        empty.write_text("# nothing\n")
        replay_trace(empty)


def test_scale_to_users():
    tr = scale_to_users(diurnal_trace(peak_rps=7.0, hours=2), users=2e6,
                        rps_per_user=0.01)
    assert np.isclose(tr.max(), 2e6 * 0.01)


# ---------------------------------------------------------------------------
# Engines.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("router_cls", [RoundRobinRouter,
                                        JoinShortestQueueRouter,
                                        PowerAwareRouter])
def test_fleet_backend_parity_bitwise(router_cls):
    trace = scale_to_users(
        diurnal_trace(peak_rps=1.0, hours=2, dt_s=60.0, seed=9),
        users=3e5, rps_per_user=0.02)
    ts = small_fleet("scalar", router_cls()).play_trace(trace)
    tv = small_fleet("vector", router_cls()).play_trace(trace)
    assert ts.ticks == tv.ticks
    assert np.array_equal(ts.power_w, tv.power_w)
    assert np.array_equal(ts.active_units, tv.active_units)
    assert np.array_equal(ts.assigned_rps, tv.assigned_rps)
    assert np.array_equal(ts.queued, tv.queued)
    assert ts.energy_j == tv.energy_j
    assert ts.served == tv.served
    assert (ts.p50_latency_s, ts.p95_latency_s, ts.p99_latency_s) \
        == (tv.p50_latency_s, tv.p95_latency_s, tv.p99_latency_s)
    for a, b in zip(ts.per_rack, tv.per_rack):
        assert a.energy_j == b.energy_j
        assert a.served == b.served
        assert a.scale_events == b.scale_events
        assert np.array_equal(a.utilization, b.utilization)


def test_vector_engine_accepts_dvfs_and_hedging_policies():
    # DVFS + hedging configs used to be scalar-only; they now construct
    # (and run) on the vector engine
    from repro.power import sd865_opp_table
    racks = homogeneous_fleet(
        soc_cluster(), 2, 30.0,
        policy=ScalePolicy(freq_governor=SchedutilGovernor(),
                           hedge_after_s=10.0),
        opp_table=sd865_opp_table())
    tel = Fleet(racks, backend="vector", dt_s=60.0).play_trace([600.0] * 4)
    assert tel.served > 0
    with pytest.raises(ValueError, match="backend"):
        Fleet(homogeneous_fleet(soc_cluster(), 2, 30.0), backend="quantum")


def test_mixed_specs_and_rack_names():
    fleet = Fleet([
        RackConfig(soc_cluster(), 30.0, name="edge-site-a"),
        RackConfig(edge_server_cpu(), 9.0),
    ], dt_s=60.0)
    assert fleet.rack_names[0] == "edge-site-a"
    assert fleet.rack_names[1] == "edge-cpu/1"
    assert fleet.n_racks == 2


# ---------------------------------------------------------------------------
# 100-rack fleet smoke + roll-up invariants.
# ---------------------------------------------------------------------------
def test_hundred_rack_smoke():
    racks = homogeneous_fleet(soc_cluster(), 100, unit_rate=30.0,
                              policy=ScalePolicy(cooldown_s=300.0))
    fleet = Fleet(racks, router=JoinShortestQueueRouter(), dt_s=60.0,
                  backend="vector")
    trace = scale_to_users(
        diurnal_trace(peak_rps=1.0, hours=6, dt_s=60.0, seed=5),
        users=2e6, rps_per_user=0.045)
    tel = fleet.play_trace(trace)
    assert tel.n_racks == 100
    assert tel.wall_s < 30.0, "vectorized 100-rack sweep must be fast"
    # every queue drained, all offered work served
    assert int(tel.queued[:, -1].sum()) == 0
    offered_work = float(np.sum(trace) * 60.0)
    assert tel.served == pytest.approx(offered_work, rel=1e-6)
    # fleet roll-up is the sum of per-rack integrals
    assert tel.energy_j == sum(t.energy_j for t in tel.per_rack)
    assert np.array_equal(tel.total_power_w,
                          tel.power_w.sum(axis=0))
    # elastic fleet: power tracks the diurnal swing
    assert tel.proportionality() > 0.6
    # energy/TCO bridges
    rep = tel.energy_report()
    assert rep.joules == tel.energy_j
    assert rep.peak_power_w == tel.peak_power_w
    assert tel.monthly_electricity_usd() > 0
    s = tel.summary()
    for key in ("racks", "energy_kwh", "tpe", "p95_latency_s",
                "proportionality", "monthly_electricity_usd"):
        assert key in s


def test_play_trace_twice_returns_consistent_cumulative_telemetry():
    trace = scale_to_users(
        diurnal_trace(peak_rps=1.0, hours=1, dt_s=60.0, seed=2),
        users=2e5, rps_per_user=0.02)
    fleet = small_fleet("vector")
    t1 = fleet.play_trace(trace)
    t2 = fleet.play_trace(trace)
    # the second roll-up covers the whole history, arrays in lockstep
    assert t2.ticks > t1.ticks
    assert len(t2.offered_rps) == t2.ticks
    assert t2.assigned_rps.shape == t2.power_w.shape == t2.queued.shape
    assert t2.served == pytest.approx(2 * t1.served, rel=1e-6)
    assert t2.proportionality() > 0          # broadcast-safe
    assert t2.summary()["ticks"] == t2.ticks


def test_vector_pool_views_are_immutable():
    from repro.runtime import UnitState, make_unit_pool
    pool = make_unit_pool(soc_cluster(), backend="vector")
    pool.wake("a", 3, ready_t=0.0)
    pool.advance(0.0, 1.0)
    assert pool.state[pool.units_of("a")[0]] is UnitState.ACTIVE
    with pytest.raises(TypeError):
        pool.state[0] = UnitState.ACTIVE
    with pytest.raises(TypeError):
        pool.owner[0] = "b"


def test_fleet_view_exposes_live_state():
    fleet = small_fleet()
    v = view_of(fleet)
    assert isinstance(v, FleetView)
    assert v.n_racks == 6
    assert (v.active_units >= 1).all()          # min_units floors active
    assert (v.full_load_j_per_req > 0).all()
    # Xeon racks cost more energy per request than SoC racks
    assert v.full_load_j_per_req[-1] > v.full_load_j_per_req[0]
