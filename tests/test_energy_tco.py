"""Paper-reproduction validation: TCO Table 4, energy proportionality
(Fig 7/12), Fig 13 collaborative-inference calibration, scheduler."""
import numpy as np
import pytest

from repro.core.cluster import (a100_server, edge_server_cpu,
                                edge_server_gpu, soc_cluster)
from repro.core.collaborative import (PAPER_FIG13, RESNET50_PROFILE,
                                      SOC_TCP, TPU_ICI, fig13_table,
                                      latency_breakdown)
from repro.core.energy import (account_trace, cluster_power_at_load,
                               proportionality_index)
from repro.core.scheduler import ElasticScheduler, ScalePolicy, diurnal_trace
from repro.core.tco import (PAPER_TABLE4, edge_server_nogpu_tco,
                            edge_server_tco, soc_cluster_tco)


# ---------------------------------------------------------------------------
# Table 4 (TCO): the model must reproduce the paper's published numbers.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model,key", [
    (edge_server_tco, "edge-server-8xA40"),
    (edge_server_nogpu_tco, "edge-server-no-gpu"),
    (soc_cluster_tco, "soc-cluster"),
])
def test_table4_reproduced(model, key):
    m = model()
    ref = PAPER_TABLE4[key]
    assert m.capex.total == pytest.approx(ref["total_capex"], rel=1e-6)
    assert m.capex.monthly == pytest.approx(ref["capex_monthly"], abs=1.0)
    assert m.monthly_electricity() == pytest.approx(
        ref["electricity_monthly"], abs=1.0)
    assert m.monthly_tco() == pytest.approx(ref["tco_monthly"], abs=2.0)


def test_soc_cluster_peak_power_calibration():
    # Table 4: measured avg peak 589 W.
    assert soc_cluster().peak_power == pytest.approx(589.0, abs=1.0)


# ---------------------------------------------------------------------------
# Energy proportionality (Fig 7 / Fig 12).
# ---------------------------------------------------------------------------
def test_soc_cluster_most_proportional():
    pi_soc = proportionality_index(soc_cluster())
    pi_cpu = proportionality_index(edge_server_cpu())
    pi_gpu = proportionality_index(edge_server_gpu())
    pi_a100 = proportionality_index(a100_server())
    assert pi_soc > pi_cpu > pi_gpu > pi_a100
    assert pi_soc > 0.85


def test_low_load_advantage_matches_fig12():
    """Fig 12: at light load the SoC Cluster is ~5.7x more energy-efficient
    than the A100. TpE ratio at 5% load should be >> 1 and larger than at
    full load."""
    soc, a100 = soc_cluster(), a100_server()
    # same normalized workload capacity for both (ratio-only comparison)
    p_soc_low = cluster_power_at_load(soc, 0.05)
    p_a100_low = cluster_power_at_load(a100, 0.05)
    p_soc_full = cluster_power_at_load(soc, 1.0)
    p_a100_full = cluster_power_at_load(a100, 1.0)
    adv_low = (0.05 / p_soc_low) / (0.05 / p_a100_low)
    adv_full = (1.0 / p_soc_full) / (1.0 / p_a100_full)
    assert adv_low > 2.0 * adv_full
    assert 2.0 < adv_low < 12.0   # paper: ~5.7x


def test_gating_saves_energy_on_diurnal_trace():
    spec = soc_cluster()
    trace = diurnal_trace(peak_rps=60.0, hours=24, dt_s=60.0) / 60.0
    gated = account_trace(spec, trace, 60.0, items_per_s_at_peak=60.0,
                          idle_units_off=True)
    ungated = account_trace(spec, trace, 60.0, items_per_s_at_peak=60.0,
                            idle_units_off=False)
    assert gated.joules < ungated.joules
    assert gated.tpe > ungated.tpe


# ---------------------------------------------------------------------------
# Fig 13 (collaborative inference).
# ---------------------------------------------------------------------------
def test_fig13_baseline_matches_paper():
    r5 = latency_breakdown(RESNET50_PROFILE, 5, SOC_TCP)
    assert r5["comm_share"] == pytest.approx(
        PAPER_FIG13["comm_share_at_5"], abs=0.02)
    assert r5["speedup"] == pytest.approx(
        PAPER_FIG13["total_speedup_at_5"], abs=0.05)
    r1 = latency_breakdown(RESNET50_PROFILE, 1, SOC_TCP)
    assert r1["total_ms"] == pytest.approx(80.0, abs=0.5)


def test_fig13_pipelining_matches_paper():
    r5 = latency_breakdown(RESNET50_PROFILE, 5, SOC_TCP, pipelined=True)
    assert r5["comm_share"] == pytest.approx(
        PAPER_FIG13["comm_share_at_5_pipelined"], abs=0.03)
    base = latency_breakdown(RESNET50_PROFILE, 5, SOC_TCP)
    assert r5["total_ms"] < base["total_ms"]


def test_fig13_tpu_ring_nearly_eliminates_comm():
    r5 = latency_breakdown(RESNET50_PROFILE, 5, TPU_ICI, ring_overlap=True)
    assert r5["comm_share"] < 0.01
    assert r5["speedup"] > 2.0   # ~compute-bound speedup


def test_fig13_table_monotone_compute():
    rows = fig13_table()
    comps = [r["baseline"]["compute_ms"] for r in rows]
    assert all(a > b for a, b in zip(comps, comps[1:]))


# ---------------------------------------------------------------------------
# Elastic scheduler.
# ---------------------------------------------------------------------------
def test_scheduler_tracks_load_and_saves_energy():
    spec = soc_cluster()
    sched = ElasticScheduler(spec, unit_rate=1.0,
                             policy=ScalePolicy(cooldown_s=10.0))
    trace = diurnal_trace(peak_rps=50.0, hours=24.0, dt_s=60.0)
    res = sched.simulate(trace, dt_s=60.0)
    # activation follows load
    peak_active = res.active_units.max()
    min_active = res.active_units.min()
    assert peak_active > 3 * max(min_active, 1)
    # serving nearly everything
    assert res.served > 0.95 * np.sum(trace * 10.0)
    # static provisioning at peak would burn more
    static_energy = spec.power(int(peak_active), 1.0) * len(trace) * 60.0
    assert res.energy_j < 0.8 * static_energy


def test_scheduler_hedging_bounds_latency():
    spec = soc_cluster()
    base = ElasticScheduler(spec, unit_rate=1.0,
                            policy=ScalePolicy(cooldown_s=1e9,
                                               wake_latency_s=20.0))
    hedged = ElasticScheduler(
        spec, unit_rate=1.0,
        policy=ScalePolicy(cooldown_s=1e9, wake_latency_s=20.0,
                           hedge_after_s=2.0))
    trace = np.concatenate([np.full(30, 2.0), np.full(30, 30.0)])
    r0 = base.simulate(trace, dt_s=1.0)
    r1 = hedged.simulate(trace, dt_s=1.0)
    assert r1.hedged > 0
    assert r1.p99_latency_s <= r0.p99_latency_s
