"""Optimizer: schedules, clipping, int8 state fidelity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.training.optimizer import (QTensor, QTensorLog,
                                      adamw_update, global_norm,
                                      init_opt_state, lr_schedule,
                                      opt_state_bytes)


def _params(rng, n=4):
    ks = jax.random.split(jax.random.key(0), n)
    return {f"w{i}": jax.random.normal(ks[i], (16, 32)) * 0.1
            for i in range(n)}


def test_lr_schedule_warmup_and_decay():
    cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert lrs[99] < lrs[50] < lrs[10]
    assert lrs[99] >= 0.1 * 1e-3 * 0.99  # cosine floor


def test_grad_clip_applied():
    cfg = TrainConfig(grad_clip=1.0, learning_rate=1.0, warmup_steps=0,
                      total_steps=10)
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    state = init_opt_state(params, cfg)
    new_params, _, metrics = adamw_update(grads, state, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # clipped update magnitude bounded by lr * O(1)
    assert np.all(np.abs(np.asarray(new_params["w"])) < 10.0)


def test_int8_state_tracks_fp32_trajectory():
    rng = np.random.default_rng(0)
    params32 = {"w": jnp.asarray(rng.standard_normal((32, 64)) * 0.1,
                                 jnp.float32)}
    params8 = jax.tree.map(lambda x: x, params32)
    cfg32 = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=50,
                        opt_state_dtype="fp32")
    cfg8 = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=50,
                       opt_state_dtype="int8")
    s32 = init_opt_state(params32, cfg32)
    s8 = init_opt_state(params8, cfg8)
    assert isinstance(s8.m["w"], QTensor)
    assert isinstance(s8.v["w"], QTensorLog)
    for _step in range(20):
        g = {"w": jnp.asarray(rng.standard_normal((32, 64)) * 0.05,
                              jnp.float32)}
        params32, s32, _ = adamw_update(g, s32, params32, cfg32)
        params8, s8, _ = adamw_update(g, s8, params8, cfg8)
    diff = np.abs(np.asarray(params32["w"]) - np.asarray(params8["w"]))
    scale = np.abs(np.asarray(params32["w"])).mean()
    assert diff.mean() < 0.08 * scale, (diff.mean(), scale)


def test_qtensor_log_relative_error_bounded():
    rng = np.random.default_rng(1)
    # second moments span many decades
    v = jnp.asarray(10.0 ** rng.uniform(-12, 0, (8, 256)), jnp.float32)
    from repro.training.optimizer import _quant_rowwise_log
    q = _quant_rowwise_log(v)
    back = np.asarray(q.dequant())
    rel = np.abs(back - np.asarray(v)) / np.asarray(v)
    assert rel.max() < 0.15  # bounded relative error even at 1e-12


def test_opt_state_bytes_int8_smaller():
    params = _params(jax.random.key(0))
    big = opt_state_bytes(params, TrainConfig(opt_state_dtype="fp32"))
    small = opt_state_bytes(params, TrainConfig(opt_state_dtype="int8"))
    assert small < 0.4 * big


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,)) * 2.0}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))
