"""Observability layer: ledger bitwise replay on the fig14/fig15/fig16
scenarios, probe parity across engines, obs-on == obs-off telemetry,
chrome-trace validity, SLO alerting, and the exporters/report CLI.

The ledger assertions use ``==`` (bitwise), not ``approx`` — that is
the contract: on the scalar and vector backends every recorded joule
replays to the exact ``energy_j`` float the engines integrated. The
jax backend is compared within its documented 1e-9 relative parity
budget (see ``tests/test_jax_parity.py``)."""
import json

import numpy as np
import pytest

from repro.core.cluster import edge_server_cpu, soc_cluster
from repro.fleet import (Fleet, JoinShortestQueueRouter, diurnal_trace,
                         flash_crowd_trace, homogeneous_fleet,
                         scale_to_users)
from repro.obs import (CAUSES, EnergyLedger, FleetObs, LatencyBurnRule,
                       MemorySink, ProbeRegistry, QueueBlowupRule, SloPolicy,
                       ThrottleStormRule, build_chrome_trace,
                       validate_chrome_trace)
from repro.power import (FixedFreqGovernor, SchedutilGovernor, ThermalParams,
                         sd865_opp_table)
from repro.runtime import (ClusterRuntime, MultiTenantRuntime, QueueWorkload,
                           ScalePolicy, Tenant)

UNIT_RATE = 30.0
DT_S = 60.0
JAX_RTOL = 1e-9


def fig16_racks(dvfs=False, hedge=False, n_soc=4, n_cpu=2):
    """The fig16 mixed fleet: SD865 racks + Xeon racks."""
    policy = ScalePolicy(
        cooldown_s=300.0, min_units=1,
        freq_governor=SchedutilGovernor() if dvfs else None,
        hedge_after_s=4 * DT_S if hedge else None)
    kwargs = dict(opp_table=sd865_opp_table(),
                  thermal=ThermalParams()) if dvfs else {}
    racks = homogeneous_fleet(soc_cluster(), n_soc, unit_rate=UNIT_RATE,
                              policy=policy, **kwargs)
    racks += homogeneous_fleet(edge_server_cpu(), n_cpu, unit_rate=9.0)
    return racks


def fig16_trace(hours=2.0, frac=0.5, seed=9):
    return scale_to_users(
        diurnal_trace(peak_rps=1.0, hours=hours, dt_s=DT_S, seed=seed),
        users=frac * 3e5, rps_per_user=0.02)


def fresh_obs(slo=None):
    return FleetObs(probes=ProbeRegistry([MemorySink()]),
                    ledger=EnergyLedger(), slo=slo)


# ---------------------------------------------------------------------------
# Pool surface: fig15-style ClusterRuntime scenarios.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["scalar", "vector"])
def test_pool_ledger_bitwise_fig15_dvfs_thermal(backend):
    """Sustained near-peak load, schedutil + OPP table + RC thermal:
    the ledger replay equals the pool integral bitwise."""
    spec = soc_cluster()
    rt = ClusterRuntime(
        spec, QueueWorkload(unit_rate=10.0),
        policy=ScalePolicy(cooldown_s=30.0,
                           freq_governor=SchedutilGovernor()),
        opp_table=sd865_opp_table(), thermal=ThermalParams(),
        dt_s=1.0, backend=backend)
    ledger = EnergyLedger()
    rt.pool.attach_ledger(ledger)
    offered = 0.8 * 10.0 * spec.n_units
    for _ in range(120):
        rt.submit(cost=offered, count=offered)
        rt.tick()
    assert ledger.rack_energy_j()[spec.name] == rt.pool.energy_j
    assert ledger.total_energy_j() == rt.pool.energy_j
    assert ledger.n_ticks == 120


def test_pool_ledger_bitwise_under_throttling():
    """fig15's sustained-peak throttling run: trip latches fire and the
    throttle_floor cause appears, while the replay stays bitwise."""
    spec = soc_cluster()
    rt = ClusterRuntime(
        spec, QueueWorkload(unit_rate=10.0),
        policy=ScalePolicy(min_units=spec.n_units, cooldown_s=1e9,
                           freq_governor=FixedFreqGovernor()),
        opp_table=sd865_opp_table(),
        # default fan_t_low_c (45C) sits just above this scenario's PCB
        # steady state (~43.6C): dies trip but the fan never spins. Drop
        # the fan-on threshold so the fan cause appears in the split.
        thermal=ThermalParams(fan_t_low_c=40.0), dt_s=1.0)
    ledger = EnergyLedger()
    rt.pool.attach_ledger(ledger)
    offered = 2.0 * 10.0 * spec.n_units
    for _ in range(400):
        rt.submit(cost=offered, count=offered)
        rt.tick()
    assert max(rt.pool.throttled_hist) > 0, "scenario must actually trip"
    assert ledger.rack_energy_j()[spec.name] == rt.pool.energy_j
    split = ledger.by_cause()
    assert split.get("throttle_floor", 0.0) > 0.0
    assert split.get("fan", 0.0) > 0.0
    total = sum(split.values())
    assert total == pytest.approx(ledger.total_energy_j(), rel=1e-9)


def test_pool_ledger_bitwise_mid_run_attach():
    """Attaching after some energy has accrued still replays bitwise
    (the ledger seeds from the pool's integral at attach time)."""
    spec = soc_cluster()
    rt = ClusterRuntime(spec, QueueWorkload(unit_rate=10.0),
                        policy=ScalePolicy(cooldown_s=30.0), dt_s=1.0)
    for _ in range(25):
        rt.submit(cost=100.0, count=100.0)
        rt.tick()
    ledger = EnergyLedger()
    rt.pool.attach_ledger(ledger, rack="late")
    for _ in range(25):
        rt.submit(cost=100.0, count=100.0)
        rt.tick()
    assert ledger.rack_energy_j()["late"] == rt.pool.energy_j
    assert ledger.n_ticks == 25


def test_pool_ledger_bitwise_fig14_multi_tenant():
    """fig14's colocated tenants (hedging on) meter into one ledger;
    the replay is bitwise and each tenant shows up in the split."""
    spec = soc_cluster()
    names = ("transcoding", "dl-serving", "lm-serving")
    policy = ScalePolicy(cooldown_s=120.0, min_units=2,
                         hedge_after_s=4 * DT_S)
    runtime = MultiTenantRuntime(
        spec,
        [Tenant(m, QueueWorkload(unit_rate=8.0, name=m), policy=policy)
         for m in names],
        dt_s=DT_S)
    ledger = EnergyLedger()
    runtime.pool.attach_ledger(ledger)
    n = 48
    traces = {
        m: np.roll(diurnal_trace(peak_rps=8.0 * spec.n_units * 0.45,
                                 hours=n * DT_S / 3600.0, dt_s=DT_S,
                                 seed=i), i * n // 3)
        for i, m in enumerate(names)
    }
    runtime.play_traces(traces, dt_s=DT_S)
    assert ledger.rack_energy_j()[spec.name] == runtime.pool.energy_j
    by_tenant = ledger.by_tenant()
    for m in names:
        assert by_tenant.get(m, 0.0) > 0.0
    # tenant attribution matches the pool's own per-tenant meter
    for m in names:
        assert by_tenant[m] <= runtime.pool.energy_j


# ---------------------------------------------------------------------------
# Fleet surface: fig16 scenarios on all three engines.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["scalar", "vector"])
@pytest.mark.parametrize("dvfs", [False, True])
def test_fleet_ledger_bitwise_fig16(backend, dvfs):
    fleet = Fleet(fig16_racks(dvfs=dvfs, hedge=dvfs),
                  router=JoinShortestQueueRouter(), dt_s=DT_S,
                  backend=backend, obs=fresh_obs())
    tel = fleet.play_trace(fig16_trace())
    ledger = fleet.obs.ledger
    racks = ledger.rack_energy_j()
    for name, rack_tel in zip(tel.rack_names, tel.per_rack):
        assert racks[name] == rack_tel.energy_j, name
    assert ledger.total_energy_j() == tel.energy_j
    assert ledger.n_ticks == tel.ticks


def test_fleet_ledger_bitwise_flash_crowd():
    trace = flash_crowd_trace(base_rps=900.0, spike_mult=6.0, hours=1.0,
                              dt_s=DT_S, noise=0.0)
    for backend in ("scalar", "vector"):
        fleet = Fleet(fig16_racks(), router=JoinShortestQueueRouter(),
                      dt_s=DT_S, backend=backend, obs=fresh_obs())
        tel = fleet.play_trace(trace)
        assert fleet.obs.ledger.total_energy_j() == tel.energy_j


def test_fleet_ledger_jax_within_tolerance():
    pytest.importorskip("jax")
    fleet = Fleet(fig16_racks(dvfs=True), router=JoinShortestQueueRouter(),
                  dt_s=DT_S, backend="jax", obs=fresh_obs())
    tel = fleet.play_trace(fig16_trace())
    ledger = fleet.obs.ledger
    assert ledger.tolerance == JAX_RTOL  # set by Fleet._wire_obs
    racks = ledger.rack_energy_j()
    for name, rack_tel in zip(tel.rack_names, tel.per_rack):
        assert racks[name] == pytest.approx(rack_tel.energy_j,
                                            rel=JAX_RTOL), name
    assert ledger.total_energy_j() == pytest.approx(tel.energy_j,
                                                    rel=JAX_RTOL)
    assert ledger.n_ticks == tel.ticks


def test_fleet_by_cause_partitions_energy():
    fleet = Fleet(fig16_racks(dvfs=True), router=JoinShortestQueueRouter(),
                  dt_s=DT_S, backend="vector", obs=fresh_obs())
    tel = fleet.play_trace(fig16_trace())
    ledger = fleet.obs.ledger
    split = ledger.by_cause()
    assert set(split) <= set(CAUSES)
    assert sum(split.values()) == pytest.approx(tel.energy_j, rel=1e-9)
    assert split["shared"] > 0 and split["active"] > 0
    # gated-off units draw p_off = 0 W in these specs: the idle cause
    # is metered (key present) but carries no energy
    assert split["idle"] == 0.0
    # per-(rack, tenant, cause) cells also re-sum to the total
    cells = [j for tenants in ledger.by_rack_tenant_cause().values()
             for causes in tenants.values() for j in causes.values()]
    assert sum(cells) == pytest.approx(tel.energy_j, rel=1e-9)


# ---------------------------------------------------------------------------
# Probes: cross-engine parity and the no-perturbation contract.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dvfs", [False, True])
def test_probe_history_scalar_vector_bitwise(dvfs):
    trace = fig16_trace(hours=1.0)
    hists, times = {}, {}
    for backend in ("scalar", "vector"):
        obs = fresh_obs()
        fleet = Fleet(fig16_racks(dvfs=dvfs),
                      router=JoinShortestQueueRouter(), dt_s=DT_S,
                      backend=backend, obs=obs)
        fleet.play_trace(trace)
        sink = obs.probes._sinks[0]
        hists[backend] = sink.history()
        times[backend] = sink.times()
    assert np.array_equal(times["scalar"], times["vector"])
    assert set(hists["scalar"]) == set(hists["vector"])
    for metric, rows in hists["scalar"].items():
        assert np.array_equal(rows, hists["vector"][metric],
                              equal_nan=True), metric


def test_probe_history_jax_matches_vector():
    pytest.importorskip("jax")
    trace = fig16_trace(hours=1.0)
    hists = {}
    for backend in ("vector", "jax"):
        obs = fresh_obs()
        fleet = Fleet(fig16_racks(), router=JoinShortestQueueRouter(),
                      dt_s=DT_S, backend=backend, obs=obs)
        fleet.play_trace(trace)
        hists[backend] = obs.probes._sinks[0].history()
    for metric in ("active_units", "queued", "hedge_units", "waking_units"):
        assert np.array_equal(hists["vector"][metric], hists["jax"][metric]), \
            metric
    for metric in ("power_w", "utilization"):
        np.testing.assert_allclose(hists["jax"][metric],
                                   hists["vector"][metric], rtol=JAX_RTOL)


@pytest.mark.parametrize("backend", ["scalar", "vector", "jax"])
def test_obs_on_does_not_perturb_telemetry(backend):
    if backend == "jax":
        pytest.importorskip("jax")
    trace = fig16_trace(hours=1.0)
    plain = Fleet(fig16_racks(dvfs=True), router=JoinShortestQueueRouter(),
                  dt_s=DT_S, backend=backend).play_trace(trace)
    obs = Fleet(fig16_racks(dvfs=True), router=JoinShortestQueueRouter(),
                dt_s=DT_S, backend=backend,
                obs=fresh_obs()).play_trace(trace)
    assert np.array_equal(plain.power_w, obs.power_w)
    assert np.array_equal(plain.active_units, obs.active_units)
    assert np.array_equal(plain.queued, obs.queued)
    assert plain.energy_j == obs.energy_j
    assert plain.served == obs.served


def test_probe_registry_without_sinks_is_inactive():
    reg = ProbeRegistry()
    assert not reg.active
    sink = reg.add_sink(MemorySink())
    assert reg.active
    reg.bind(["r0"])
    reg.emit_tick(0.0, 1.0, {"power_w": np.array([5.0])})
    assert sink.n_ticks == 1
    assert sink.rack_names == ["r0"]
    assert sink.history()["power_w"].shape == (1, 1)


# ---------------------------------------------------------------------------
# Request traces: chrome trace-event JSON.
# ---------------------------------------------------------------------------
def test_chrome_trace_validates_and_round_trips():
    obs = fresh_obs()
    fleet = Fleet(fig16_racks(dvfs=True), router=JoinShortestQueueRouter(),
                  dt_s=DT_S, backend="vector", obs=obs)
    tel = fleet.play_trace(fig16_trace(hours=1.0))
    sink = obs.probes._sinks[0]
    trace = build_chrome_trace(tel, probes=sink)
    assert validate_chrome_trace(trace) == []
    # survives JSON serialization (what Perfetto actually loads)
    back = json.loads(json.dumps(trace))
    assert back["displayTimeUnit"] == "ms"
    events = back["traceEvents"]
    phases = {ev["ph"] for ev in events}
    assert "M" in phases and "X" in phases and "C" in phases
    spans = [ev for ev in events if ev["ph"] == "X"]
    assert spans, "sampled request spans must be present"
    for ev in spans:
        assert ev["ts"] >= 0 and ev["dur"] >= 0
    counters = [ev for ev in events if ev["ph"] == "C"]
    assert {"power_w"} <= {ev["name"].split("/")[0] for ev in counters}


def test_chrome_trace_validator_flags_garbage():
    assert validate_chrome_trace({"traceEvents": "nope"})
    bad = {"traceEvents": [{"ph": "X", "name": "x", "ts": -5}]}
    assert validate_chrome_trace(bad)


# ---------------------------------------------------------------------------
# SLO burn-rate alerts on FleetTelemetry.alerts.
# ---------------------------------------------------------------------------
def test_queue_blowup_alert_fires_under_overload():
    # play_trace submits ONE aggregated request per rack per trace tick,
    # so queue depth counts pending tick-batches: 2 racks x 3 overload
    # ticks peaks at a fleet-wide total of 6
    slo = SloPolicy([QueueBlowupRule(max_queued=3)])
    fleet = Fleet(fig16_racks(n_soc=2, n_cpu=0),
                  router=JoinShortestQueueRouter(), dt_s=DT_S,
                  backend="vector", obs=FleetObs(slo=slo))
    tel = fleet.play_trace([5.0 * fleet.capacity_rps] * 3)
    assert tel.alerts, "sustained overload must raise queue_blowup"
    alert = tel.alerts[0]
    assert alert.rule == "queue_blowup"
    assert alert.worst_value > alert.threshold
    assert alert.t_end > alert.t_start
    assert tel.summary()["alerts"] == float(len(tel.alerts))
    rec = alert.to_record()
    assert rec["rule"] == "queue_blowup"


def test_latency_burn_alert_merges_windows():
    # aggregated trace-tick batches mean few completions: a 2h window
    # and min_count=2 keep the rolling p95 populated across the drain
    slo = SloPolicy([LatencyBurnRule(target_s=30.0, window_s=7200.0,
                                     min_count=2)])
    fleet = Fleet(fig16_racks(n_soc=2, n_cpu=0),
                  router=JoinShortestQueueRouter(), dt_s=DT_S,
                  backend="vector", obs=FleetObs(slo=slo))
    trace = [2.0 * fleet.capacity_rps] * 4 + [0.0] * 4
    tel = fleet.play_trace(trace)
    burn = [a for a in tel.alerts if a.rule == "latency_burn"]
    assert burn, "queue-built latency must breach a 30s p95 target"
    # consecutive violating ticks merged: windows don't overlap
    for a, b in zip(burn, burn[1:]):
        assert a.t_end <= b.t_start


def test_quiet_run_produces_no_alerts():
    slo = SloPolicy([QueueBlowupRule(max_queued=10_000),
                     ThrottleStormRule(max_throttled_units=0),
                     LatencyBurnRule(target_s=3600.0)])
    fleet = Fleet(fig16_racks(), router=JoinShortestQueueRouter(),
                  dt_s=DT_S, backend="vector", obs=FleetObs(slo=slo))
    tel = fleet.play_trace(fig16_trace(hours=0.5, frac=0.3))
    assert tel.alerts == []


def test_slo_evaluate_is_deterministic():
    slo = SloPolicy([QueueBlowupRule(max_queued=3)])
    fleet = Fleet(fig16_racks(n_soc=2, n_cpu=0),
                  router=JoinShortestQueueRouter(), dt_s=DT_S,
                  backend="vector")
    tel = fleet.play_trace([5.0 * fleet.capacity_rps] * 3)
    first = [a.to_record() for a in slo.evaluate(tel)]
    second = [a.to_record() for a in slo.evaluate(tel)]
    assert first == second and first


# ---------------------------------------------------------------------------
# Exporters + the report CLI.
# ---------------------------------------------------------------------------
def test_exporters_write_all_formats(tmp_path):
    from repro.obs.export import (metric_records, prometheus_text,
                                  write_attribution_json, write_chrome_trace,
                                  write_metrics_jsonl)
    obs = fresh_obs()
    fleet = Fleet(fig16_racks(), router=JoinShortestQueueRouter(),
                  dt_s=DT_S, backend="vector", obs=obs)
    tel = fleet.play_trace(fig16_trace(hours=0.5))
    sink = obs.probes._sinks[0]

    records = list(metric_records(sink))
    assert records and all("metric" in r and "t" in r for r in records)

    jl = tmp_path / "metrics.jsonl"
    write_metrics_jsonl(jl, sink)
    lines = jl.read_text().strip().splitlines()
    assert len(lines) == len(records)
    assert json.loads(lines[0])["metric"]

    prom = prometheus_text(sink)
    assert "# TYPE repro_fleet_power_w gauge" in prom
    assert 'rack="' in prom

    tr = tmp_path / "trace.json"
    write_chrome_trace(tr, build_chrome_trace(tel, probes=sink))
    assert json.loads(tr.read_text())["traceEvents"]

    attr = tmp_path / "attribution.json"
    write_attribution_json(attr, obs.ledger)
    data = json.loads(attr.read_text())
    assert data["total_energy_j"] == tel.energy_j
    assert any(row["cause"] == "active" for row in data["records"])


def test_report_cli_smoke(tmp_path):
    from repro.obs.report import main
    rc = main(["--backend", "vector", "--soc", "2", "--cpu", "1",
               "--hours", "0.5", "--out-dir", str(tmp_path)])
    assert rc == 0
    for name in ("report.md", "report.html", "trace.json", "metrics.jsonl",
                 "prometheus.txt", "attribution.json", "summary.json"):
        assert (tmp_path / name).exists(), name
    summary = json.loads((tmp_path / "summary.json").read_text())
    assert summary["served"] > 0 and summary["drained"] == 1.0
    assert validate_chrome_trace(
        json.loads((tmp_path / "trace.json").read_text())) == []
