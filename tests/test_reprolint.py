"""reprolint: every RPL rule fires on its known-bad fixture, stays
quiet on the known-good twin, and the real tree lints clean."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOOLS = os.path.join(REPO, "tools")
SRC = os.path.join(REPO, "src")
FIXTURES = os.path.join(REPO, "tests", "reprolint_fixtures")

sys.path.insert(0, TOOLS)

from reprolint import RULES, lint_paths, lint_source  # noqa: E402
from reprolint.engine import parse_waivers  # noqa: E402

RULE_CODES = ("RPL001", "RPL002", "RPL003", "RPL004", "RPL005")


def lint_fixture(name: str):
    return lint_paths([os.path.join(FIXTURES, name)])


# ---------------------------------------------------------------------------
# fixture corpus: bad fires, good passes


@pytest.mark.parametrize("rule", RULE_CODES)
def test_rule_fires_on_bad_fixture(rule):
    findings = lint_fixture(f"{rule.lower()}_bad.py")
    assert findings, f"{rule} found nothing in its known-bad fixture"
    assert {f.rule for f in findings} == {rule}


@pytest.mark.parametrize("rule", RULE_CODES)
def test_rule_passes_good_fixture(rule):
    findings = lint_fixture(f"{rule.lower()}_good.py")
    assert findings == [], [f.format() for f in findings]


def test_jax_engine_glob_fires_without_marker():
    """PR 7 scope extension: ``repro/fleet/jax_engine.py`` and
    ``engine_state.py`` are parity-critical *by path glob* (the
    fixtures carry no marker comment), and ``jnp`` reductions count.
    The bad twin has an unwaived ``jnp.sum``; the good twin uses the
    ``ok[RPL001] jax tolerance-parity`` waiver convention."""
    bad = lint_fixture(os.path.join("repro", "fleet", "jax_engine.py"))
    assert bad, "glob did not put the jax_engine fixture in scope"
    assert {f.rule for f in bad} == {"RPL001"}
    assert any("jnp" in f.message or "sum" in f.message for f in bad)
    good = lint_fixture(os.path.join("repro", "fleet", "engine_state.py"))
    assert good == [], [f.format() for f in good]


def test_pr5_reduceat_bug_reconstruction_flagged():
    """The PR 5 one-ulp parity bug — a float ``np.add.reduceat`` group
    sum — must be flagged by RPL001, and its bincount fix must pass."""
    bad = lint_fixture("rpl001_bad.py")
    reduceat = [f for f in bad if "reduceat" in f.message]
    assert reduceat, "float add.reduceat not flagged"
    assert all(f.rule == "RPL001" for f in reduceat)
    good = lint_fixture("rpl001_good.py")
    assert good == [], [f.format() for f in good]


# ---------------------------------------------------------------------------
# waiver semantics


PARITY_SNIPPET = """\
# reprolint: parity-critical
import numpy as np

def total(x):
    return float(np.sum(x)){waiver}
"""


def test_waiver_with_rationale_suppresses():
    src = PARITY_SNIPPET.format(
        waiver="  # reprolint: ok[RPL001] int64 input: exact")
    assert lint_source(src) == []


def test_waiver_without_rationale_is_rpl000():
    src = PARITY_SNIPPET.format(waiver="  # reprolint: ok[RPL001]")
    rules = sorted(f.rule for f in lint_source(src))
    # the bare waiver does NOT suppress, and is itself reported
    assert rules == ["RPL000", "RPL001"]


def test_waiver_wrong_rule_does_not_suppress():
    src = PARITY_SNIPPET.format(
        waiver="  # reprolint: ok[RPL005] wrong rule entirely")
    assert [f.rule for f in lint_source(src)] == ["RPL001"]


def test_waiver_on_multiline_call():
    src = (
        "# reprolint: parity-critical\n"
        "import numpy as np\n"
        "def f(a, b):\n"
        "    return np.dot(\n"
        "        a, b)  # reprolint: ok[RPL001] test: waiver on last line\n"
    )
    assert lint_source(src) == []


def test_parse_waivers_multiple_rules():
    ws = parse_waivers(
        "x = 1  # reprolint: ok[RPL001, RPL005] both are fine here\n")
    assert len(ws) == 1
    assert ws[0].rules == ("RPL001", "RPL005")
    assert ws[0].rationale


def test_scoping_rules_silent_outside_scope():
    # no parity marker, not a parity-critical path: RPL001 stays quiet,
    # RPL004 (global) still fires
    src = ("import numpy as np\n"
           "import random\n"
           "def f(x):\n"
           "    return np.sum(x) + random.random()\n")
    assert [f.rule for f in lint_source(src)] == ["RPL004"]


# ---------------------------------------------------------------------------
# the real tree


def test_src_tree_lints_clean():
    findings = lint_paths([SRC])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_module_runs_clean_on_src():
    env = dict(os.environ)
    env["PYTHONPATH"] = TOOLS + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "reprolint", SRC],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout


def test_cli_exit_code_on_findings():
    env = dict(os.environ)
    env["PYTHONPATH"] = TOOLS + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "reprolint",
         os.path.join(FIXTURES, "rpl001_bad.py")],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=120)
    assert r.returncode == 1
    assert "RPL001" in r.stdout


def test_rule_catalogue_documents_every_code():
    for code in ("RPL000", *RULE_CODES):
        assert code in RULES and RULES[code]
