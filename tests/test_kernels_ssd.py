"""Mamba-2 SSD kernel vs the sequential-recurrence oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ssd_scan import ssd_scan


def _inputs(rng, b, s, h, p, n, dtype=jnp.float32):
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.3, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), dtype)
    C = jnp.asarray(rng.standard_normal((b, s, n)), dtype)
    D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 128, 3, 32, 64, 32),
    (1, 256, 4, 64, 128, 128),
])
def test_ssd_matches_recurrence(b, s, h, p, n, chunk, rng):
    x, dt, A, B, C, D = _inputs(rng, b, s, h, p, n)
    y_ref, st_ref = ref.ssd_ref(x, dt, A, B, C, D)
    y, st = ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance(rng):
    """The chunked algorithm must be exactly chunk-size independent."""
    x, dt, A, B, C, D = _inputs(rng, 1, 128, 2, 16, 32)
    y32, st32 = ssd_scan(x, dt, A, B, C, D, chunk=32, interpret=True)
    y64, st64 = ssd_scan(x, dt, A, B, C, D, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y64),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st32), np.asarray(st64),
                               rtol=1e-4, atol=1e-4)


def test_ssd_decode_continues_prefill(rng):
    """Prefill state + one decode step == full sequence at s+1."""
    b, s, h, p, n = 1, 64, 2, 16, 16
    x, dt, A, B, C, D = _inputs(rng, b, s + 1, h, p, n)
    y_full, _ = ref.ssd_ref(x, dt, A, B, C, D)
    _, state = ssd_scan(x[:, :s], dt[:, :s], A, B[:, :s], C[:, :s], D,
                        chunk=32, interpret=True)
    y1, _ = ref.ssd_decode_ref(x[:, s], dt[:, s], A, B[:, s], C[:, s], D,
                               state)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, s]),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_decay(rng):
    """With large dt*|A| the carried state must vanish (no leakage across
    chunks)."""
    b, s, h, p, n = 1, 64, 1, 8, 8
    x, dt, A, B, C, D = _inputs(rng, b, s, h, p, n)
    A_big = jnp.full((h,), -50.0)
    dt_big = jnp.full_like(dt, 5.0)
    _, state = ssd_scan(x, dt_big, A_big, B, C, D, chunk=16, interpret=True)
    # state = sum over j of exp(L_last - L_j) dt B x; only the last step
    # survives: bounded by dt * |B| * |x|
    assert np.isfinite(np.asarray(state)).all()
