"""Serving engine + continuous batcher + quantized serving + autoscaler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, get_config, smoke_config
from repro.core.cluster import tpu_v5e_pod
from repro.core.scheduler import ScalePolicy
from repro.models import model as lm
from repro.serving.autoscaler import ServingAutoscaler
from repro.serving.batcher import ContinuousBatcher
from repro.serving.engine import (ServingEngine, dequantize_params,
                                  quantize_params_int8)


@pytest.fixture(scope="module")
def engine():
    cfg = smoke_config(get_config("internlm2-1.8b")).replace(dtype="float32")
    eng = ServingEngine(cfg, ServeConfig(max_seq_len=64))
    eng.init_random(0)
    return eng


def test_generate_shapes(engine):
    out = engine.generate(jnp.ones((2, 8), jnp.int32), 5)
    assert out.shape == (2, 5)
    assert np.isfinite(np.asarray(out)).all()


def test_continuous_batcher_matches_generate(engine):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, engine.cfg.vocab_size, 6).astype(np.int32)
               for _ in range(5)]
    refs = [np.asarray(engine.generate(jnp.asarray(p[None]), 5))[0]
            for p in prompts]
    bat = ContinuousBatcher(engine, slots=2)
    for p in prompts:
        bat.submit(p, max_new_tokens=5)
    tracked = list(bat.queue)
    for _ in range(100):
        if not bat.queue and all(a is None for a in bat.active):
            break
        bat.step()
    for req, r in zip(tracked, refs):
        assert req.generated[:5] == [int(t) for t in r[:5]], \
            (req.generated, r)


def test_int8_weight_serving_close_to_fp(engine):
    cfg = engine.cfg
    qp = quantize_params_int8(engine.params)
    # quantized payloads present for big mats
    leaves = jax.tree.leaves(qp, is_leaf=lambda l: isinstance(l, dict)
                             and "__int8__" in l)
    assert any(isinstance(l, dict) and "__int8__" in l for l in leaves)
    dq = dequantize_params(qp)
    lg_fp, _, _ = lm.forward(engine.params, cfg,
                             {"tokens": jnp.ones((1, 8), jnp.int32)})
    lg_q, _, _ = lm.forward(
        jax.tree.map(lambda x: x.astype(jnp.float32), dq), cfg,
        {"tokens": jnp.ones((1, 8), jnp.int32)})
    # int8 weights: logits correlated with fp (loose check)
    a = np.asarray(lg_fp, np.float32).ravel()
    b = np.asarray(lg_q, np.float32).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.99


def test_autoscaler_scales_and_accounts_energy():
    sc = ServingAutoscaler(tpu_v5e_pod(16), unit_rate_rps=2.0,
                           policy=ScalePolicy(min_units=1, cooldown_s=5.0),
                           window_s=5.0)
    t = 0.0
    for step in range(60):
        t = float(step)
        n = 8 if 20 <= step < 40 else 1
        sc.record_arrival(t, n)
        sc.tick(t, served_this_tick=n)
    rep = sc.report()
    assert rep.scale_events >= 2          # up and back down
    assert 1.0 < rep.mean_active < 16.0
    assert rep.energy_j > 0
